from .sharding import (LOGICAL_RULES, spec_for, shardings_for_tree,  # noqa: F401
                       batch_specs, zero1_shardings, cache_specs,
                       data_axis_names)
