"""Logical-axis → mesh sharding rules (divisibility-aware).

The cross-chip half of the paper's cache-aware scheduling story (DESIGN.md
§2): on TPU the 'clusters' are chips and placing the model axes so that the
heavy collectives stay on the short mesh dimension is the analogue of keeping
an XCD's working set inside its L2.

Rules (MaxText-style):
  batch      -> ('pod', 'data')     data parallel (hierarchical across pods)
  vocab      -> 'model'             embedding/LM-head sharding
  heads      -> 'model'             TP over attention heads (dim = H*hd)
  kv_heads   -> 'model'
  ffn        -> 'model'             TP over MLP hidden
  expert     -> 'model'             EP (MoE expert dim)
  embed      -> None                activations replicated over model axis
  layers     -> None                scan axis

A mesh axis is dropped for a given tensor dim when the dim is not divisible
by the axis size (e.g. whisper's vocab 51865 on 16-way model) — replicate
rather than fail, and report it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_COLLECTIVES = ("none", "all_to_all", "all_gather", "reduce_scatter",
                "all_reduce")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Sharding as a first-class plan dimension (DESIGN.md §16).

    What the autotuner needs to know about one *sharded* launch: which mesh
    axes participate, how the op's operands are partitioned over them, and
    which collective the chain pays on the wire. Frozen and hashable so it
    joins :class:`repro.core.autotune.OpSignature` and every memo key —
    installing a different sharding invalidates cached plans the same way a
    different dtype would.

    ``mesh``       ((axis_name, size), ...) — participating axes only.
    ``partition``  ((operand_dim_name, axis_name), ...) — which logical
                   operand dim is split over which mesh axis ('expert',
                   'ffn', 'tokens', 'rows', 'contract', ...).
    ``collective`` the wire pattern of the chain: one of
                   none | all_to_all | all_gather | reduce_scatter |
                   all_reduce.
    """

    mesh: tuple = ()
    partition: tuple = ()
    collective: str = "none"

    def __post_init__(self):
        if self.collective not in _COLLECTIVES:
            raise ValueError(f"unknown collective {self.collective!r} "
                             f"(one of {_COLLECTIVES})")
        names = set()
        for entry in self.mesh:
            name, size = entry
            if not isinstance(name, str) or int(size) < 1:
                raise ValueError(f"bad mesh entry {entry!r}")
            names.add(name)
        for entry in self.partition:
            dim, axis = entry
            if axis is not None and axis not in names:
                raise ValueError(
                    f"partition {entry!r} names axis {axis!r} not in mesh "
                    f"{self.mesh!r}")

    @property
    def n_shards(self) -> int:
        return math.prod(int(size) for _, size in self.mesh) if self.mesh \
            else 1

    def axis_size(self, name: str) -> int:
        for axis, size in self.mesh:
            if axis == name:
                return int(size)
        raise KeyError(name)

    def describe(self) -> str:
        """Stable compact token for memo/pretuned keys and plan audits."""
        mesh = ",".join(f"{a}={s}" for a, s in self.mesh)
        part = ",".join(f"{d}@{a}" for d, a in self.partition)
        return f"{mesh}|{part}|{self.collective}"

    @classmethod
    def for_axis(cls, mesh: Mesh, axis: str, *, dim: str,
                 collective: str) -> "ShardSpec":
        """One-axis spec from a live jax Mesh (the shard_map common case)."""
        return cls(mesh=((axis, int(mesh.shape[axis])),),
                   partition=((dim, axis),), collective=collective)

def train_shard_spec(cfg, mesh: Optional[Mesh],
                     *, model_axis: str = "model") -> Optional[ShardSpec]:
    """The ShardSpec a training step's plan decisions should carry for this
    (cfg, mesh) — None when there is no model-parallel extent. Mirrors the
    moe_forward impl dispatch: EP (all_to_all) when the expert dim divides
    the axis, TP (all_reduce) otherwise; dense models price the Megatron
    MLP all_reduce."""
    if (mesh is None or model_axis not in mesh.axis_names
            or mesh.shape[model_axis] == 1):
        return None
    moe = getattr(cfg, "moe", None)
    if (moe is not None and getattr(moe, "shard", "expert") == "expert"
            and moe.num_experts % mesh.shape[model_axis] == 0):
        return ShardSpec.for_axis(mesh, model_axis, dim="expert",
                                  collective="all_to_all")
    return ShardSpec.for_axis(mesh, model_axis, dim="ffn",
                              collective="all_reduce")


LOGICAL_RULES: dict[Optional[str], tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "expert": ("model",),
    "embed": (),
    "layers": (),
    None: (),
}


def mesh_axes_for(logical: Optional[str], mesh: Mesh) -> tuple[str, ...]:
    axes = LOGICAL_RULES.get(logical, ())
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for(shape: tuple[int, ...], logical_axes: tuple, mesh: Mesh,
             *, report: Optional[list] = None) -> P:
    parts = []
    for dim, logical in zip(shape, logical_axes):
        axes = mesh_axes_for(logical, mesh)
        size = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if axes and dim % size == 0:
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            if axes and report is not None:
                report.append((shape, logical, dim, size))
            parts.append(None)
    return P(*parts)


def shardings_for_tree(axes_tree, shape_tree, mesh: Mesh,
                       *, report: Optional[list] = None):
    """axes_tree: tree of logical-axes tuples; shape_tree: matching arrays or
    ShapeDtypeStructs. Returns a tree of NamedShardings."""
    def one(axes, arr):
        return NamedSharding(mesh, spec_for(arr.shape, axes, mesh,
                                            report=report))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def divisible_axes(dim: int, mesh: Mesh, axes) -> Optional[tuple]:
    """THE shared divisibility rule: the given mesh axes iff they all exist
    on the mesh and their product divides ``dim``; None otherwise (the
    caller replicates). ``batch_specs``, the shard_map MoE batch spec, and
    ``cache_specs`` all route through this so 'when do we shard a batch-like
    dim' has exactly one answer."""
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    size = math.prod(mesh.shape[a] for a in axes)
    return axes if dim % size == 0 else None


def leaf_nbytes(arr) -> int:
    """Byte size of an array / ShapeDtypeStruct leaf (the zero1/fsdp sizing
    rule — one definition, not a per-closure numpy reimport)."""
    return int(math.prod(arr.shape)) * np.dtype(arr.dtype).itemsize


def batch_specs(batch_tree, mesh: Mesh) -> dict:
    """Shard dim0 (global batch) over ('pod','data'); rest replicated.
    Falls back to replication when the batch is smaller than the DP degree
    (e.g. the long_500k single-sequence decode cell)."""
    def one(arr):
        axes = divisible_axes(arr.shape[0], mesh, ("pod", "data"))
        if axes:
            return NamedSharding(mesh, P(axes, *([None] * (arr.ndim - 1))))
        return NamedSharding(mesh, P(*([None] * arr.ndim)))
    return jax.tree.map(one, batch_tree)


def data_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard_free_dim(sh, shape, mesh: Mesh, axis: str = "data"):
    """Add ``axis`` on the largest unsharded, divisible dim (None if none —
    including when the axis is already used by another dim)."""
    size = mesh.shape[axis]
    spec = list(sh.spec) + [None] * (len(shape) - len(sh.spec))
    if any(axis == s or (isinstance(s, tuple) and axis in s) for s in spec):
        return None
    candidates = [(shape[i], i) for i in range(len(shape))
                  if spec[i] is None and shape[i] % size == 0
                  and shape[i] >= size]
    if not candidates:
        return None
    _, dim = max(candidates)
    spec[dim] = axis
    return NamedSharding(mesh, P(*spec))


def zero1_shardings(param_shardings, shape_tree, mesh: Mesh):
    """ZeRO-1: additionally shard optimizer moments over the data axis —
    on the largest unsharded divisible dim of each param (not just dim0, so
    stacked MoE tensors like (24, 128, 5120, 8192) still shard). Cuts
    optimizer-state memory |data|-fold; XLA inserts gathers on use."""
    if "data" not in mesh.axis_names:
        return param_shardings

    def one(sh, arr):
        out = _shard_free_dim(sh, arr.shape, mesh)
        return out if out is not None else sh
    return jax.tree.map(one, param_shardings, shape_tree)


def fsdp_shardings(param_shardings, shape_tree, mesh: Mesh,
                   min_bytes: int = 2**20):
    """FSDP/ZeRO-3: shard the *parameters themselves* over the data axis.
    GSPMD inserts per-layer all-gathers inside the scan (weights are
    re-gathered per use and freed — the standard scan+fsdp pattern).
    Required for models whose TP-sharded params exceed HBM (llama4:
    400B fp32 / 16-way model = 100 GB/chip without this). Small params
    (< min_bytes) stay as-is — gathering them isn't worth the latency."""
    if "data" not in mesh.axis_names:
        return param_shardings

    def one(sh, arr):
        if leaf_nbytes(arr) < min_bytes:
            return sh
        out = _shard_free_dim(sh, arr.shape, mesh)
        return out if out is not None else sh
    return jax.tree.map(one, param_shardings, shape_tree)


def cache_specs(cache_tree, mesh: Mesh, *, stacked: bool) -> dict:
    """KV/state caches: shard the batch dim over ('pod','data') and — for
    attention KV — the *sequence* dim over 'model' (sequence-parallel cache:
    the decode einsum's softmax over the sharded kv axis lowers to a partial
    softmax + small all-reduce, while cutting per-chip KV memory |model|-fold;
    kv_heads are often < |model| so head-sharding can't do it).

    Layouts (``stacked`` ⇒ leading layers dim): attn k/v (L?, B, Hkv, S, hd);
    ssm conv (L?, B, K, C), state (L?, B, H, P, N); rglru conv/h.
    """
    daxes = data_axis_names(mesh)
    lead = 1 if stacked else 0

    def one(arr):
        nd = arr.ndim
        spec = [None] * nd
        bdim = lead if nd > lead else 0
        baxes = divisible_axes(arr.shape[bdim], mesh, daxes)
        if baxes:
            spec[bdim] = baxes if len(baxes) > 1 else baxes[0]
        # attention KV caches are the 4(+1)-dim leaves: (..., Hkv, S, hd)
        if nd == 4 + lead and "model" in mesh.axis_names:
            sdim = nd - 2
            if arr.shape[sdim] % mesh.shape["model"] == 0:
                spec[sdim] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, cache_tree)
