"""Encoder-decoder transformer (Whisper backbone; conv frontend is a STUB —
``input_specs`` provides precomputed frame embeddings per the assignment).

Encoder: bidirectional self-attention blocks over (B, S_enc, D) embeddings
with sinusoidal positions. Decoder: causal self-attention + cross-attention +
MLP, learned positions. LayerNorm + GELU, tied embedding/LM head (Whisper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (ParamDef, apply_norm, cast_params, cross_entropy_loss,
                     mlp_defs, mlp_forward, norm_defs, norm_params)
from .attention import (attn_defs, attention_layer, decode_attention_layer,
                        init_attn_cache, prefill_attn_cache, project_qkv,
                        project_qkv_heads, _merge_heads)
from repro.kernels.attention import attention as attention_op


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angles = pos / jnp.power(10000.0, 2 * idx / dim)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def encdec_param_defs(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    dt = cfg.param_dtype
    defs = {
        "embed": ParamDef((v, d), ("vocab", "embed"), dtype=dt),
        "dec_pos": ParamDef((cfg.max_seq_len, d), (None, "embed"),
                            scale=0.02, dtype=dt),
    }
    enc = cfg.encoder_layers
    defs.update(attn_defs(cfg, "enc/attn", stack=enc))
    defs.update(mlp_defs(cfg, "enc/mlp", stack=enc))
    defs.update(norm_defs(cfg, "enc/ln1", stack=enc))
    defs.update(norm_defs(cfg, "enc/ln2", stack=enc))
    defs.update(norm_defs(cfg, "enc_final_norm"))

    dec = cfg.num_layers
    defs.update(attn_defs(cfg, "dec/attn", stack=dec))
    defs.update(attn_defs(cfg, "dec/xattn", stack=dec, cross=True))
    defs.update(mlp_defs(cfg, "dec/mlp", stack=dec))
    defs.update(norm_defs(cfg, "dec/ln1", stack=dec))
    defs.update(norm_defs(cfg, "dec/lnx", stack=dec))
    defs.update(norm_defs(cfg, "dec/ln2", stack=dec))
    defs.update(norm_defs(cfg, "final_norm"))
    return defs


def encode(cfg, params, enc_embeds, *, mode="reference", remat=False):
    """enc_embeds: (B, S_enc, D) stub-frontend output -> (B, S_enc, D)."""
    s = enc_embeds.shape[1]
    x = enc_embeds.astype(cfg.compute_dtype) + \
        sinusoidal_positions(s, cfg.d_model).astype(cfg.compute_dtype)

    def body(h, layer_params):
        p = layer_params
        # pre-norm stream routed straight in: the pallas modes fold ln1/ln2
        # into the QKV / MLP-up GEMM prologues where fusable (DESIGN.md §10)
        a = attention_layer(cfg, p["attn"], h, causal=False, mode=mode,
                            use_rope=False, prenorm=norm_params(p, "ln1"))
        h = h + a
        h = mlp_forward(cfg, p["mlp"], h, mode=mode, residual=h,
                        prenorm=norm_params(p, "ln2"))
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    from repro.util import scan_unroll
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=scan_unroll())
    return apply_norm(cfg, x, params, "enc_final_norm")


def _dec_block(cfg, p, x, enc_out, *, mode="reference"):
    a = attention_layer(cfg, p["attn"], x, causal=True, mode=mode,
                        use_rope=False, prenorm=norm_params(p, "ln1"))
    x = x + a
    c = attention_layer(cfg, p["xattn"], x, causal=False, kv_input=enc_out,
                        mode=mode, use_rope=False,
                        prenorm=norm_params(p, "lnx"))
    x = x + c
    x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                    prenorm=norm_params(p, "ln2"))
    return x


def encdec_forward(cfg, params, batch, *, mode="reference", remat=False,
                   mesh=None, data_axes=("data",)):
    """batch: {'encoder_embeds': (B,S_enc,D), 'inputs': (B,S)} -> logits."""
    params = cast_params(params, cfg.compute_dtype)
    enc_out = encode(cfg, params, batch["encoder_embeds"], mode=mode,
                     remat=remat)
    tokens = batch["inputs"]
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = x + params["dec_pos"][:s].astype(cfg.compute_dtype)

    def body(h, layer_params):
        return _dec_block(cfg, layer_params, h, enc_out, mode=mode), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    from repro.util import scan_unroll
    x, _ = jax.lax.scan(body, x, params["dec"], unroll=scan_unroll())
    x = apply_norm(cfg, x, params, "final_norm")
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def encdec_loss(cfg, params, batch, *, mode="reference", remat=True,
                mesh=None, data_axes=("data",), aux_weight=0.0):
    logits, _ = encdec_forward(cfg, params, batch, mode=mode, remat=remat)
    ce = cross_entropy_loss(logits, batch["targets"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# Serving: encoder runs once; decoder self-KV grows, cross-KV is static.
# ---------------------------------------------------------------------------

def encdec_init_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    self_c = init_attn_cache(cfg, batch, max_len, None, dtype)
    cross_shape = (batch, cfg.num_kv_heads, cfg.encoder_seq, cfg.head_dim)
    cross_c = {"k": jnp.zeros(cross_shape, dtype),
               "v": jnp.zeros(cross_shape, dtype)}
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), t)
    return {"self": stack(self_c), "cross": stack(cross_c)}


def encdec_prefill(cfg, params, batch, cache, *, mode="reference"):
    """Encode + decoder prefill on batch['inputs']. Returns (cache, logits)."""
    params = cast_params(params, cfg.compute_dtype)
    enc_out = encode(cfg, params, batch["encoder_embeds"], mode=mode)
    tokens = batch["inputs"]
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = x + params["dec_pos"][:s].astype(cfg.compute_dtype)

    def body(h, xs):
        p, self_c, cross_c = xs
        # rope-free self-attention routes through the same fused-QKV plan
        # ladder as the dense LM prefill (DESIGN.md §12): ln1 folds into
        # the packed q|k GEMM's prologue when the 'qkv' chain plan wins
        q, k, v = project_qkv_heads(cfg, p["attn"], h, mode=mode,
                                    prenorm=norm_params(p, "ln1"),
                                    use_rope=False)
        o = attention_op(q, k, v, causal=True, mode=mode,
                         softcap=getattr(cfg, "attn_logit_softcap", None))
        self_c = prefill_attn_cache(cfg, self_c, k, v, s, None)
        h = h + _merge_heads(o) @ p["attn"]["wo"]
        hn = apply_norm(cfg, h, p, "lnx")
        qx, kx, vx = project_qkv(cfg, p["xattn"], hn, kv_input=enc_out)
        ox = attention_op(qx, kx, vx, causal=False, mode=mode,
                          softcap=getattr(cfg, "attn_logit_softcap", None))
        cross_c = {"k": kx, "v": vx}
        h = h + _merge_heads(ox) @ p["xattn"]["wo"]
        h = mlp_forward(cfg, p["mlp"], h, mode=mode, residual=h,
                        prenorm=norm_params(p, "ln2"))
        return h, (self_c, cross_c)

    from repro.util import scan_unroll
    x, (self_c, cross_c) = jax.lax.scan(body, x, (params["dec"],
                                                  cache["self"],
                                                  cache["cross"]),
                                        unroll=scan_unroll())
    x = apply_norm(cfg, x, params, "final_norm")
    logits = x[:, -1].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return {"self": self_c, "cross": cross_c}, logits


def encdec_decode_step(cfg, params, token, cache, pos, *, mode="reference",
                       mesh=None, data_axes=("data",)):
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][token].astype(cfg.compute_dtype)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0
                                         ).astype(cfg.compute_dtype)

    def body(h, xs):
        p, self_c, cross_c = xs
        hn = apply_norm(cfg, h, p, "ln1")
        a, self_c = decode_attention_layer(cfg, p["attn"], hn, self_c, pos,
                                           use_rope=False, mode=mode)
        h = h + a
        hn = apply_norm(cfg, h, p, "lnx")
        c, _ = decode_attention_layer(cfg, p["xattn"], hn, cross_c, pos,
                                      cross=True, update_cache=False,
                                      use_rope=False, mode=mode)
        h = h + c
        h = mlp_forward(cfg, p["mlp"], h, mode=mode, residual=h,
                        prenorm=norm_params(p, "ln2"))
        return h, (self_c, cross_c)

    from repro.util import scan_unroll
    x, (self_c, cross_c) = jax.lax.scan(body, x, (params["dec"],
                                                  cache["self"],
                                                  cache["cross"]),
                                        unroll=scan_unroll())
    x = apply_norm(cfg, x, params, "final_norm")
    logits = x[:, 0].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return {"self": self_c, "cross": cross_c}, logits
