"""Unified model API: one object per architecture family.

``build_model(cfg)`` returns a :class:`Model` whose methods close over the
kernel mode / mesh, giving every arch the same surface:
  init, param_defs, loss, forward, init_cache, prefill, decode_step,
  make_batch (ShapeDtypeStructs OR real random arrays for a given ShapeConfig)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import autotune
from . import lm as _lm
from . import encdec as _ed
from . import vlm as _vlm
from .common import abstract_params, init_params, logical_axes


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    defs: dict
    loss: Callable            # (params, batch) -> (loss, metrics)
    forward: Callable         # (params, batch-or-tokens) -> (logits, aux)
    init_cache: Callable      # (batch, max_len) -> cache
    prefill: Callable         # (params, batch, cache) -> (cache, logits)
    decode_step: Callable     # (params, token, cache, pos) -> (cache, logits)
    # paged decode surface (decoder-only LM/VLM backbones; DESIGN.md §8):
    #   init_paged_cache(batch_slots, n_pages, page_size) -> cache
    #   prefill_paged(params, tokens, cache, page_rows, slot, true_len)
    #   prefill_paged_chunk(params, tokens, cache, page_rows, start,
    #                       last_index) — chunked/suffix prefill (§14)
    #   decode_step_paged(params, token, cache, page_table, lengths)
    #     (token (B, T): T > 1 is the speculative verify step)
    init_paged_cache: Optional[Callable] = None
    prefill_paged: Optional[Callable] = None
    prefill_paged_chunk: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None
    # {op: KernelPolicy} resolved at build time for the config's default
    # bucket — inspectable summary of what the kernels will do; exact
    # (batch, seq) buckets re-resolve via the memoized autotuner cache
    # (serve/engine and train/trainer pin those).
    default_policies: dict = dataclasses.field(default_factory=dict)

    def init(self, rng) -> dict:
        return init_params(self.defs, rng)

    # ---- kernel policies -----------------------------------------------
    def resolve_policies(self, shape: Optional[ShapeConfig] = None,
                         *, batch: int = 1,
                         seq_len: Optional[int] = None) -> dict:
        """Resolve (and warm the autotuner cache with) the KernelPolicies
        this model's kernels will use for a (batch, seq) bucket. Called at
        model-build time with the config's max shape; callers with a known
        bucket (dryrun cells, serve buckets, trainer) re-resolve exactly.
        Returns {op_kind: KernelPolicy}."""
        if shape is not None:
            batch, seq_len = shape.global_batch, shape.seq_len
        seq_len = seq_len if seq_len is not None else \
            min(self.cfg.max_seq_len, 4096)
        return autotune.policies_for_model(self.cfg, batch=batch,
                                           seq_len=seq_len)

    def abstract(self) -> dict:
        return abstract_params(self.defs)

    def axes(self) -> dict:
        return logical_axes(self.defs)

    # ---- batch construction --------------------------------------------
    def batch_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct inputs for the dry-run (no allocation)."""
        return make_batch(self.cfg, shape, abstract=True)

    def make_batch(self, shape: ShapeConfig, rng) -> dict:
        return make_batch(self.cfg, shape, abstract=False, rng=rng)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, *, abstract: bool,
               rng=None) -> dict:
    """Inputs for train ({'inputs','targets','loss_mask', frontends...})."""
    b, s = shape.global_batch, shape.seq_len
    out: dict[str, Any] = {}

    def toks(shp):
        if abstract:
            return jax.ShapeDtypeStruct(shp, jnp.int32)
        return jax.random.randint(rng, shp, 0, cfg.vocab_size, jnp.int32)

    def arr(shp):
        if abstract:
            return jax.ShapeDtypeStruct(shp, jnp.dtype(cfg.compute_dtype))
        return jax.random.normal(rng, shp, jnp.dtype(cfg.compute_dtype))

    if cfg.family == "encdec":
        out["encoder_embeds"] = arr((b, cfg.encoder_seq, cfg.d_model))
        out["inputs"] = toks((b, s))
        out["targets"] = toks((b, s))
    elif cfg.family == "vlm":
        p = cfg.num_patches
        out["patch_embeds"] = arr((b, p, cfg.d_model))
        out["inputs"] = toks((b, s - p))
        out["targets"] = toks((b, s - p))
    else:
        out["inputs"] = toks((b, s))
        out["targets"] = toks((b, s))
    mask_shape = out["targets"].shape
    out["loss_mask"] = (jax.ShapeDtypeStruct(mask_shape, jnp.float32)
                        if abstract else jnp.ones(mask_shape, jnp.float32))
    return out


def build_model(cfg: ModelConfig, *, mode: Optional[str] = None, mesh=None,
                data_axes=("data",)) -> Model:
    """Build the model. For kernel modes, also resolve the config's default
    bucket into :attr:`Model.default_policies` — an inspectable summary of
    the tiling strategy; launch-time callers (serve buckets, trainer steps)
    re-resolve their exact (batch, seq) buckets through the same memoized
    autotuner, so this is a preview, not the binding choice."""
    model = _build_model(cfg, mode=mode, mesh=mesh, data_axes=data_axes)
    if mode not in (None, "reference"):
        model.default_policies = model.resolve_policies()
    return model


def _build_model(cfg: ModelConfig, *, mode: Optional[str] = None, mesh=None,
                 data_axes=("data",)) -> Model:
    mode = mode if mode is not None else "reference"
    kw = dict(mode=mode, mesh=mesh, data_axes=data_axes)

    if cfg.family == "encdec":
        defs = _ed.encdec_param_defs(cfg)
        return Model(
            cfg=cfg, defs=defs,
            loss=functools.partial(_ed.encdec_loss, cfg, **kw),
            forward=functools.partial(_ed.encdec_forward, cfg, **kw),
            init_cache=functools.partial(_ed.encdec_init_cache, cfg),
            prefill=functools.partial(_ed.encdec_prefill, cfg, mode=mode),
            decode_step=functools.partial(_ed.encdec_decode_step, cfg,
                                          mode=mode, mesh=mesh,
                                          data_axes=data_axes),
        )
    if cfg.family == "encoder":
        from . import encoder as _enc

        def _no_decode(*a, **k):
            raise NotImplementedError("encoder-only archs have no decode step")

        defs = _enc.encoder_param_defs(cfg)
        return Model(
            cfg=cfg, defs=defs,
            loss=functools.partial(_enc.encoder_loss, cfg, **kw),
            forward=functools.partial(_enc.encoder_forward, cfg, **kw),
            init_cache=_no_decode, prefill=_no_decode, decode_step=_no_decode,
        )
    if cfg.family == "vlm":
        defs = _vlm.vlm_param_defs(cfg)

        def vlm_prefill(params, batch, cache):
            # prepend patch embeds by running lm_prefill over combined tokens
            raise NotImplementedError(
                "vlm serving uses text-only prefill on the LM backbone")

        return Model(
            cfg=cfg, defs=defs,
            loss=functools.partial(_vlm.vlm_loss, cfg, **kw),
            forward=functools.partial(_vlm.vlm_forward, cfg, **kw),
            init_cache=functools.partial(_lm.lm_init_cache, cfg),
            prefill=lambda params, batch, cache: _lm.lm_prefill(
                cfg, params,
                batch["inputs"] if isinstance(batch, dict) else batch,
                cache, **kw),
            decode_step=functools.partial(_lm.lm_decode_step, cfg, **kw),
            init_paged_cache=functools.partial(_lm.lm_init_paged_cache, cfg),
            prefill_paged=functools.partial(_lm.lm_prefill_paged, cfg, **kw),
            prefill_paged_chunk=functools.partial(_lm.lm_prefill_paged_chunk,
                                                  cfg, **kw),
            decode_step_paged=functools.partial(_lm.lm_decode_step_paged,
                                                cfg, **kw),
        )

    defs = _lm.lm_param_defs(cfg)
    return Model(
        cfg=cfg, defs=defs,
        loss=functools.partial(_lm.lm_loss, cfg, **kw),
        forward=lambda params, batch, **k: _lm.lm_forward(
            cfg, params,
            batch["inputs"] if isinstance(batch, dict) else batch, **kw, **k),
        init_cache=functools.partial(_lm.lm_init_cache, cfg),
        prefill=lambda params, tokens, cache: _lm.lm_prefill(
            cfg, params,
            tokens["inputs"] if isinstance(tokens, dict) else tokens,
            cache, **kw),
        decode_step=functools.partial(_lm.lm_decode_step, cfg, **kw),
        init_paged_cache=functools.partial(_lm.lm_init_paged_cache, cfg),
        prefill_paged=functools.partial(_lm.lm_prefill_paged, cfg, **kw),
        prefill_paged_chunk=functools.partial(_lm.lm_prefill_paged_chunk,
                                              cfg, **kw),
        decode_step_paged=functools.partial(_lm.lm_decode_step_paged,
                                            cfg, **kw),
    )
