"""VLM (InternVL2) — LM backbone with a stubbed vision frontend.

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, P, D) which are prepended to the
token embeddings; the LM stack (InternLM2-family GQA transformer) runs over
the combined sequence and loss is taken on text positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import cast_params, cross_entropy_loss
from .lm import (lm_param_defs, lm_forward, lm_init_cache, lm_prefill,
                 lm_decode_step, _logits, _is_uniform, block_forward)


def vlm_param_defs(cfg) -> dict:
    return lm_param_defs(cfg)  # frontend stubbed; projector folded into stub


def _combined_embeds(cfg, params, batch):
    patches = batch["patch_embeds"].astype(cfg.compute_dtype)   # (B, P, D)
    tokens = batch["inputs"]                                    # (B, S_text)
    tok_emb = params["embed"][tokens].astype(cfg.compute_dtype) * cfg.emb_scale
    return jnp.concatenate([patches, tok_emb], axis=1)


def vlm_forward(cfg, params, batch, *, mode="reference", mesh=None,
                data_axes=("data",), remat=False):
    """Returns logits over the *text* positions: (B, S_text, V)."""
    params = cast_params(params, cfg.compute_dtype)
    x = _combined_embeds(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    kind = cfg.layer_kind(0)
    assert _is_uniform(cfg), "vlm backbone assumed uniform"

    def body(carry, layer_params):
        h, aux = carry
        h, aux_l = block_forward(cfg, kind, layer_params, h,
                                 positions=positions, mode=mode, mesh=mesh,
                                 data_axes=data_axes)
        return (h, aux + aux_l), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    from repro.util import scan_unroll
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=scan_unroll())
    logits = _logits(cfg, params, x[:, cfg.num_patches:, :])
    return logits, aux


def vlm_loss(cfg, params, batch, *, mode="reference", mesh=None,
             data_axes=("data",), remat=True, aux_weight=0.0):
    logits, aux = vlm_forward(cfg, params, batch, mode=mode, mesh=mesh,
                              data_axes=data_axes, remat=remat)
    ce = cross_entropy_loss(logits, batch["targets"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": aux}
