"""GQA/MHA attention layer: projections + RoPE + flash kernel + KV cache.

Train/prefill route through the Pallas flash kernel (or its jnp oracle in
'reference' mode — the dry-run path). Single-token decode routes through
``attention_decode`` — the split-KV flash-decode kernel in the pallas
modes, its jnp einsum oracle in 'reference' mode (DESIGN.md §8).
Sliding-window archs (Mixtral SWA, RecurrentGemma local attention) keep a
ring-buffer cache of ``window`` slots so the 500k-decode cell stays O(window).

Two decode cache layouts coexist: the dense per-bucket (B, Hkv, S, D) cache
below, and the paged layout (``paged_*`` functions) whose physical pages
live in a shared pool managed by ``repro.serve.kv_cache`` — that one lets
sequences of different lengths share one compiled decode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.attention import (attention as attention_op,
                                     attention_decode,
                                     attention_decode_paged)
from repro.kernels.rope import rope as rope_op, rope_ref, rope_tables
from .common import ParamDef


def attn_defs(cfg, prefix: str, *, stack: int | None = None,
              cross: bool = False) -> dict:
    """q and k projections are stored PRE-PACKED as one ``wqk`` weight
    (d, (H+Hkv)·hd) — the fused QKV→RoPE megakernel projects q|k through
    one wide GEMM (DESIGN.md §9), and packing at param-build time removes
    the in-graph concat that used to be charged to the fused plan (a
    token-independent cost that made it lose at small token counts). The
    unfused paths slice the q/k halves back out (column slices of a GEMM
    are independent, so the math is unchanged). Same for ``bqk``."""
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lead = (stack,) if stack else ()
    lx = ("layers",) if stack else ()
    dt = cfg.param_dtype
    kv_ax = "kv_heads" if getattr(cfg, "kv_shard", True) else None
    defs = {
        f"{prefix}/wqk": ParamDef(lead + (d, (h + hkv) * hd),
                                  lx + ("embed", "heads"), dtype=dt),
        f"{prefix}/wv": ParamDef(lead + (d, hkv * hd), lx + ("embed", kv_ax), dtype=dt),
        f"{prefix}/wo": ParamDef(lead + (h * hd, d), lx + ("heads", "embed"), dtype=dt),
    }
    if cfg.qkv_bias and not cross:
        defs[f"{prefix}/bqk"] = ParamDef(lead + ((h + hkv) * hd,),
                                         lx + ("heads",), init="zeros", dtype=dt)
        defs[f"{prefix}/bv"] = ParamDef(lead + (hkv * hd,), lx + (kv_ax,), init="zeros", dtype=dt)
    return defs


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def _apply_rope(cfg, q, k, positions, mode: str):
    """q/k: (B, H, S, hd). positions: (S,) absolute positions."""
    if cfg.rope_style == "none":
        return q, k
    # any standalone (non-store-fused) rotation counts here, kernel or jnp
    obs.incr("model.standalone_rope")
    hd = q.shape[-1]
    rot = hd // 2 if cfg.rope_style == "partial" else hd
    sin, cos = rope_tables(positions, rot, cfg.rope_theta)

    def rot_fn(x):
        xr = x[..., :rot]
        # the Pallas rope kernel wants contiguous full-seq blocks; decode and
        # partial-dim cases use the (identical) jnp reference.
        if mode != "reference" and cfg.rope_style == "half" and xr.shape[2] >= 128:
            out = rope_op(xr, sin, cos, mode=mode)
        else:
            out = rope_ref(xr, sin, cos)
        if rot == hd:
            return out
        return jnp.concatenate([out, x[..., rot:]], axis=-1)

    return rot_fn(q), rot_fn(k)


def project_qkv(cfg, p, x, kv_input=None):
    """Unfused projections over the packed ``wqk`` weight: the q/k halves
    are column slices (independent GEMM columns — same math as separate
    wq/wk weights)."""
    nq = cfg.num_heads * cfg.head_dim
    kv_src = x if kv_input is None else kv_input
    if kv_input is None:
        qk = x @ p["wqk"]
        q, k = qk[..., :nq], qk[..., nq:]
    else:  # cross-attention: q and k project different streams
        q = x @ p["wqk"][..., :nq]
        k = kv_src @ p["wqk"][..., nq:]
    v = kv_src @ p["wv"]
    if "bqk" in p:
        q = q + p["bqk"][..., :nq]
        k = k + p["bqk"][..., nq:]
        v = v + p["bv"]
    q = _split_heads(q, cfg.num_heads, cfg.head_dim)
    k = _split_heads(k, cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(v, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def fused_project_qkv_rope(cfg, p, x, positions, mode, prenorm=None):
    """QKV projection with the RoPE rotation fused into the GEMM store
    (DESIGN.md §9) and, with ``prenorm``, the block's pre-norm fused into
    the GEMM's A-tile prologue (DESIGN.md §10): q and k project through ONE
    wide GEMM over the pre-packed ``wqk`` whose A tiles are normalized as
    they stream in and whose output tiles are rotated while still
    VMEM-resident — the normed activation and the rotated q/k never
    round-trip HBM. v projects through a (bias-only) fused GEMM with the
    same prologue.

    Applies only to full-rotation RoPE ('half' style) on per-layer (2-D)
    weights, and only when the autotuner's chain model picks the fused plan
    from modeled dma_bytes; returns None otherwise so callers fall back to
    the unfused oracle path (norm + project_qkv + _apply_rope). When the
    norm-prologue plan loses (or its full-K tile is VMEM-illegal) but the
    plain fused plan wins, the standalone norm runs here and the rest still
    fuses — a non-None return always means ``prenorm`` was consumed.
    """
    from repro.core import autotune
    from repro.kernels.gemm import Epilogue, gemm_fused
    from .common import apply_prenorm, resolve_norm_prologue

    if cfg.rope_style != "half" or p["wqk"].ndim != 2:
        return None
    b, s, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions.shape[0] != s:
        return None
    shape = (b * s, d, h, hkv, hd)
    has_bias = "bqk" in p
    qk_ep = Epilogue(bias=has_bias, rope=True, head_dim=hd)

    resolved = resolve_norm_prologue(
        cfg, prenorm, kind="qkv_rope", plan_shape=shape,
        gemm_shape=(b * s, (h + hkv) * hd, d), dtype=str(x.dtype),
        epilogue=qk_ep)
    if resolved is None:
        plan = autotune.select_fusion("qkv_rope", shape, str(x.dtype))
        if plan["plan"] != "fused":
            return None
        if prenorm is not None:
            x = apply_prenorm(cfg, x, prenorm)  # standalone-norm fallback
        qk_policy, kw = None, {}
    else:
        prologue, pro_kw, qk_policy = resolved
        kw = dict(prologue=prologue, **pro_kw)

    x2 = x.reshape(b * s, d)
    sin, cos = rope_tables(positions, hd, cfg.rope_theta)
    # one table row per flattened (batch, seq) token row of the GEMM
    sin_m = jnp.tile(sin, (b, 1))
    cos_m = jnp.tile(cos, (b, 1))
    qk = gemm_fused(x2, p["wqk"], epilogue=qk_ep, bias=p.get("bqk"),
                    sin=sin_m, cos=cos_m, policy=qk_policy,
                    out_dtype=x.dtype, mode=mode, **kw)
    v = gemm_fused(x2, p["wv"], epilogue=Epilogue(bias=has_bias),
                   bias=p.get("bv"), out_dtype=x.dtype, mode=mode, **kw)
    q = qk[:, : h * hd].reshape(b, s, h * hd)
    k = qk[:, h * hd:].reshape(b, s, hkv * hd)
    return (_split_heads(q, h, hd), _split_heads(k, hkv, hd),
            _split_heads(v.reshape(b, s, hkv * hd), hkv, hd))


def fused_project_qkv(cfg, p, x, mode, prenorm=None):
    """Rope-free fused QKV projection (DESIGN.md §10, §12): the packed q|k
    GEMM and the v GEMM each fold the block's pre-norm into their A-tile
    prologue, so BERT/Whisper/enc-dec self-attention blocks — whose
    ``rope_style`` disqualifies the rope-store fusion — stop paying the
    standalone-norm round trip.

    The rope-free fusion only *wins* through the folded norm (without a
    prenorm the fused and eager plans stream identical bytes), so this
    returns None unless ``prenorm`` is given AND the chain model picks the
    norm-fused 'qkv' plan AND a VMEM-legal prologue policy exists; callers
    then fall back to the standalone norm + ``project_qkv``. A non-None
    return always means ``prenorm`` was consumed.
    """
    from repro.kernels.gemm import Epilogue, gemm_fused
    from .common import resolve_norm_prologue

    if p["wqk"].ndim != 2:
        return None
    b, s, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    has_bias = "bqk" in p
    qk_ep = Epilogue(bias=has_bias)
    resolved = resolve_norm_prologue(
        cfg, prenorm, kind="qkv", plan_shape=(b * s, d, h, hkv, hd),
        gemm_shape=(b * s, (h + hkv) * hd, d), dtype=str(x.dtype),
        epilogue=qk_ep)
    if resolved is None:
        return None
    prologue, pro_kw, qk_policy = resolved
    kw = dict(prologue=prologue, **pro_kw)

    x2 = x.reshape(b * s, d)
    qk = gemm_fused(x2, p["wqk"], epilogue=qk_ep, bias=p.get("bqk"),
                    policy=qk_policy, out_dtype=x.dtype, mode=mode, **kw)
    v = gemm_fused(x2, p["wv"], epilogue=Epilogue(bias=has_bias),
                   bias=p.get("bv"), out_dtype=x.dtype, mode=mode, **kw)
    q = qk[:, : h * hd].reshape(b, s, h * hd)
    k = qk[:, h * hd:].reshape(b, s, hkv * hd)
    return (_split_heads(q, h, hd), _split_heads(k, hkv, hd),
            _split_heads(v.reshape(b, s, hkv * hd), hkv, hd))


def project_qkv_heads(cfg, p, x, positions=None, *, mode: str,
                      prenorm=None, use_rope: bool = True):
    """The self-attention QKV plan ladder (DESIGN.md §12), shared by
    ``attention_layer`` and the block-level prefill paths (lm/encdec):
    always returns rotated (q, k, v) heads and always consumes ``prenorm``.

    Rungs, each guarded by the chain model's modeled dma_bytes:
      1. ``fused_project_qkv_rope`` — norm + packed q|k GEMM + rope store,
         'half'-style rope only;
      2. ``fused_project_qkv`` + ``_apply_rope`` — the norm still folds
         into the packed GEMM when rope can't ride the store ('partial' /
         'none' styles, or the rope plan lost);
      3. standalone ``apply_prenorm`` + ``project_qkv`` + ``_apply_rope``
         (the reference path, and the pallas fallback).
    """
    from .common import apply_prenorm

    if use_rope and positions is None:
        positions = jnp.arange(x.shape[1])
    if mode != "reference":
        if use_rope and cfg.rope_style == "half":
            qkv = fused_project_qkv_rope(cfg, p, x, positions, mode,
                                         prenorm=prenorm)
            if qkv is not None:
                return qkv
        qkv = fused_project_qkv(cfg, p, x, mode, prenorm=prenorm)
        if qkv is not None:
            q, k, v = qkv
            if use_rope:
                q, k = _apply_rope(cfg, q, k, positions, mode)
            return q, k, v
    if prenorm is not None:
        x = apply_prenorm(cfg, x, prenorm)
    q, k, v = project_qkv(cfg, p, x)
    if use_rope:
        q, k = _apply_rope(cfg, q, k, positions, mode)
    return q, k, v


def attention_layer(cfg, p, x, *, causal: bool = True,
                    window: int | None = None, kv_input=None,
                    positions=None, mode: str = "reference",
                    use_rope: bool = True, policy=None, prenorm=None):
    """Full-sequence attention (train/prefill). x: (B, S, D).

    With ``prenorm`` (the enclosing block's (scale, bias) norm params, see
    ``common.norm_params``) ``x`` is the *pre-norm* residual stream: the
    pallas modes fold the norm into the fused QKV GEMM's A-tile prologue
    (DESIGN.md §10) when the chain model picks that plan; otherwise the
    standalone norm runs here before the projections. Self-attention
    resolves through the ``project_qkv_heads`` plan ladder (rope-fused,
    norm-fused rope-free, standalone); cross-attention (``kv_input``)
    keeps the standalone projections.

    ``cfg.attn_logit_softcap`` threads through to the attention op as its
    softcap epilogue stage (gemma2-style tanh cap, DESIGN.md §12).

    Block sizes are no longer hard-coded here: with ``policy=None`` the op
    resolves a KernelPolicy from the analytic autotuner per shape-bucket
    (memoized), so model-build-time resolution (models/api.py) and the
    trace-time call agree (DESIGN.md §5).
    """
    from .common import apply_prenorm

    if kv_input is None:
        q, k, v = project_qkv_heads(cfg, p, x, positions, mode=mode,
                                    prenorm=prenorm, use_rope=use_rope)
    else:
        if prenorm is not None:
            x = apply_prenorm(cfg, x, prenorm)
        q, k, v = project_qkv(cfg, p, x, kv_input)
    out = attention_op(q, k, v, causal=causal, window=window,
                       policy=policy, mode=mode,
                       softcap=getattr(cfg, "attn_logit_softcap", None))
    return _merge_heads(out) @ p["wo"]


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def cache_len(cfg, max_len: int, window: int | None) -> int:
    return min(max_len, window) if window else max_len


def init_attn_cache(cfg, batch: int, max_len: int, window: int | None,
                    dtype) -> dict:
    slots = cache_len(cfg, max_len, window)
    shape = (batch, cfg.num_kv_heads, slots, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill_attn_cache(cfg, cache: dict, k, v, seq_len: int,
                       window: int | None) -> dict:
    """Insert full-prefill k/v (B, Hkv, S, hd) into (possibly ring) cache."""
    slots = cache["k"].shape[2]
    if seq_len <= slots:
        k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=2)
        v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=2)
        return {"k": k_c, "v": v_c}
    # ring: keep the last ``slots`` positions at slot = pos % slots
    tail_k = k[:, :, -slots:]
    tail_v = v[:, :, -slots:]
    pos = jnp.arange(seq_len - slots, seq_len)
    idx = pos % slots
    k_c = cache["k"].at[:, :, idx].set(tail_k)
    v_c = cache["v"].at[:, :, idx].set(tail_v)
    return {"k": k_c, "v": v_c}


def decode_attention_layer(cfg, p, x, cache: dict, pos, *,
                           window: int | None = None, cross: bool = False,
                           update_cache: bool = True,
                           use_rope: bool = True, mode: str = "reference",
                           policy=None):
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current position).

    ``cross=True``: q from x, k/v from the static (cross-attention) cache.
    ``mode`` selects the attention_decode implementation ('reference' is
    the einsum oracle; pallas modes run the split-KV flash-decode kernel).
    Returns (out (B,1,D), new_cache).
    """
    b = x.shape[0]
    if cross:
        nq = cfg.num_heads * cfg.head_dim
        q = x @ p["wqk"][..., :nq]
        if "bqk" in p:
            q = q + p["bqk"][..., :nq]
        q = _split_heads(q, cfg.num_heads, cfg.head_dim)
        k, v = cache["k"], cache["v"]  # static cross-attention cache
        lengths = jnp.full((b,), k.shape[2], jnp.int32)  # all slots valid
        window = None
    else:
        q, k_new, v_new = project_qkv(cfg, p, x)
        if use_rope:
            positions = jnp.asarray(pos).reshape(1)
            q, k_new = _apply_rope(cfg, q, k_new, positions, "reference")
        slots = cache["k"].shape[2]
        pos = jnp.asarray(pos, jnp.int32)
        slot = pos % slots
        if update_cache:
            k_c = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=2)
            v_c = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=2)
            cache = {"k": k_c, "v": v_c}
        k, v = cache["k"], cache["v"]
        lengths = jnp.broadcast_to(pos + 1, (b,))

    out = attention_decode(q, k, v, lengths, window=window, policy=policy,
                           softcap=getattr(cfg, "attn_logit_softcap", None),
                           mode=mode).astype(x.dtype)
    return _merge_heads(out) @ p["wo"], cache


# ---------------------------------------------------------------------------
# Paged KV cache (decode path over a shared page pool; DESIGN.md §8)
# ---------------------------------------------------------------------------

def init_paged_attn_cache(cfg, n_pages: int, page_size: int, dtype) -> dict:
    from repro.serve.kv_cache import init_page_pool
    return init_page_pool(n_pages, cfg.num_kv_heads, page_size,
                          cfg.head_dim, dtype)


def paged_prefill_attn_cache(cfg, cache: dict, k, v, page_rows,
                             start_page=0) -> dict:
    """Write one sequence's prefill k/v (1, Hkv, S, hd) into its pages.

    ``start_page`` (traced ok) offsets the write within the page-table row
    — chunk c of a chunked prefill passes its first page index."""
    from repro.serve.kv_cache import write_prefill_pages
    k_pages, v_pages = write_prefill_pages(cache["k_pages"], cache["v_pages"],
                                           k, v, page_rows,
                                           start_page=start_page)
    return {"k_pages": k_pages, "v_pages": v_pages}


def _apply_rope_positions(cfg, q, k, positions):
    """RoPE with per-batch-element positions (the paged decode step, where
    each sequence sits at its own length). q/k: (B, H, T, hd); positions:
    (B,) for T == 1, or (B, T) when each token carries its own position
    (chunked prefill / speculative verify). Matches ``_apply_rope``'s
    reference path exactly for uniform positions."""
    if cfg.rope_style == "none":
        return q, k
    hd = q.shape[-1]
    rot = hd // 2 if cfg.rope_style == "partial" else hd
    if positions.ndim == 1:
        sin, cos = rope_tables(positions, rot, cfg.rope_theta)
        sin, cos = sin[:, None, None, :], cos[:, None, None, :]
    else:
        b, t = positions.shape
        sin, cos = rope_tables(positions.reshape(-1), rot, cfg.rope_theta)
        sin, cos = (sin.reshape(b, 1, t, rot), cos.reshape(b, 1, t, rot))

    def rot_fn(x):
        out = rope_ref(x[..., :rot], sin, cos)
        if rot == hd:
            return out
        return jnp.concatenate([out, x[..., rot:]], axis=-1)

    return rot_fn(q), rot_fn(k)


def paged_decode_attention_layer(cfg, p, x, cache: dict, page_table, lengths,
                                 *, window: int | None = None,
                                 use_rope: bool = True,
                                 mode: str = "reference", policy=None):
    """Decode (1 or T tokens) over the paged cache. x: (B, T, D);
    ``lengths``: (B,) tokens written so far (token t lands at position
    lengths[b] + t; T > 1 is the speculative verify step). Inactive slots
    (empty page-table rows) write into the reserved null page and read back
    zeros. Returns (out (B,T,D), new_cache)."""
    from repro.serve.kv_cache import append_paged_kv
    t = x.shape[1]
    q, k_new, v_new = project_qkv(cfg, p, x)
    lengths = jnp.asarray(lengths, jnp.int32)
    if use_rope:
        positions = lengths if t == 1 else lengths[:, None] + jnp.arange(t)
        q, k_new = _apply_rope_positions(cfg, q, k_new, positions)
    k_pages, v_pages = append_paged_kv(cache["k_pages"], cache["v_pages"],
                                       k_new, v_new, page_table, lengths)
    cache = {"k_pages": k_pages, "v_pages": v_pages}
    out = attention_decode_paged(q, k_pages, v_pages, page_table, lengths + t,
                                 window=window, policy=policy,
                                 softcap=getattr(cfg, "attn_logit_softcap",
                                                 None),
                                 mode=mode).astype(x.dtype)
    return _merge_heads(out) @ p["wo"], cache
