"""Encoder-only MLM (BERT family — the paper's second §4 validation model).

Bidirectional self-attention blocks (reusing the enc-dec encoder blocks),
learned positions, tied MLM head. No decode step (encoder-only archs skip
the decode shapes per the assignment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (ParamDef, apply_norm, cast_params, cross_entropy_loss,
                     mlp_defs, mlp_forward, norm_defs, norm_params)
from .attention import attn_defs, attention_layer


def encoder_param_defs(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    dt = cfg.param_dtype
    n = cfg.num_layers
    defs = {
        "embed": ParamDef((v, d), ("vocab", "embed"), dtype=dt),
        "pos": ParamDef((cfg.max_seq_len, d), (None, "embed"), scale=0.02,
                        dtype=dt),
    }
    defs.update(attn_defs(cfg, "enc/attn", stack=n))
    defs.update(mlp_defs(cfg, "enc/mlp", stack=n))
    defs.update(norm_defs(cfg, "enc/ln1", stack=n))
    defs.update(norm_defs(cfg, "enc/ln2", stack=n))
    defs.update(norm_defs(cfg, "final_norm"))
    return defs


def encoder_forward(cfg, params, batch, *, mode="reference", remat=False,
                    mesh=None, data_axes=("data",)):
    """batch['inputs']: (B, S) (with [MASK] ids) -> logits (B, S, V)."""
    params = cast_params(params, cfg.compute_dtype)
    tokens = batch["inputs"] if isinstance(batch, dict) else batch
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = x + params["pos"][:s].astype(cfg.compute_dtype)

    def body(h, p):
        # pre-norm stream routed straight in (DESIGN.md §10, §12): the
        # pallas modes fold ln1/ln2 into the QKV / MLP-up GEMM prologues —
        # rope-free blocks now fuse through the 'qkv' plan ladder instead
        # of falling back to the standalone norm
        a = attention_layer(cfg, p["attn"], h, causal=False, mode=mode,
                            use_rope=False, prenorm=norm_params(p, "ln1"))
        h = h + a
        h = mlp_forward(cfg, p["mlp"], h, mode=mode, residual=h,
                        prenorm=norm_params(p, "ln2"))
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    from repro.util import scan_unroll
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=scan_unroll())
    x = apply_norm(cfg, x, params, "final_norm")
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def encoder_loss(cfg, params, batch, *, mode="reference", remat=True,
                 mesh=None, data_axes=("data",), aux_weight=0.0):
    """Masked-LM loss: CE only on positions where loss_mask=1."""
    logits, _ = encoder_forward(cfg, params, batch, mode=mode, remat=remat)
    ce = cross_entropy_loss(logits, batch["targets"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros(())}
