"""Decoder-only LM assembly: dense / MoE / SSM / RG-LRU / local-attn blocks.

Uniform-pattern archs (llama-family, qwen2, mixtral, mamba2, ...) stack their
layer params with a leading 'layers' axis and run under one ``lax.scan`` so
the 80-layer qwen2-72b compiles to a small HLO. Hybrid archs
(recurrentgemma's 2:1 recurrent:attention pattern) unroll a python loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import (ParamDef, apply_norm, cast_params, cross_entropy_loss,
                     init_params, mlp_defs, mlp_forward, norm_defs,
                     norm_params)
from .attention import (attn_defs, attention_layer, decode_attention_layer,
                        init_attn_cache, init_paged_attn_cache,
                        paged_decode_attention_layer, paged_prefill_attn_cache,
                        prefill_attn_cache, project_qkv_heads,
                        _merge_heads)
from repro.kernels.attention import attention as attention_op
from repro.kernels.attention import attention_decode_paged
from .moe import moe_defs, moe_forward
from .ssm import (ssm_defs, ssm_forward, ssm_prefill, ssm_decode_step,
                  init_ssm_cache)
from .rglru import (rglru_defs, rglru_forward, rglru_prefill,
                    rglru_decode_step, init_rglru_cache)


def _layout(cfg) -> tuple:
    """How layers are stacked for scan:
    ('scan', pattern, n_groups) — layers grouped by the block pattern and
    scanned (pattern length 1 = classic uniform stack); ('loop',) — unrolled
    python loop (pattern doesn't divide num_layers, e.g. recurrentgemma's
    26 = 8x3 + 2)."""
    kinds = [cfg.layer_kind(i) for i in range(cfg.num_layers)]
    if len(set(kinds)) == 1:
        return ("scan", (kinds[0],), cfg.num_layers)
    pat = tuple(cfg.block_pattern)
    if cfg.num_layers % len(pat) == 0:
        return ("scan", pat, cfg.num_layers // len(pat))
    return ("loop",)


def _is_uniform(cfg) -> bool:
    return _layout(cfg)[0] == "scan"


def _block_window(cfg, kind: str):
    if kind == "local":
        return (cfg.rglru.local_window if cfg.rglru is not None
                else cfg.attn_window)
    return cfg.attn_window


def block_defs(cfg, kind: str, prefix: str, *, stack=None) -> dict:
    defs = {}
    if kind in ("attn", "local", "moe"):
        defs.update(attn_defs(cfg, f"{prefix}/attn", stack=stack))
        defs.update(norm_defs(cfg, f"{prefix}/ln1", stack=stack))
        defs.update(norm_defs(cfg, f"{prefix}/ln2", stack=stack))
        if kind == "moe":
            defs.update(moe_defs(cfg, f"{prefix}/moe", stack=stack))
        else:
            defs.update(mlp_defs(cfg, f"{prefix}/mlp", stack=stack))
    elif kind == "ssm":
        defs.update(ssm_defs(cfg, f"{prefix}/ssm", stack=stack))
        defs.update(norm_defs(cfg, f"{prefix}/ln1", stack=stack))
    elif kind == "rg":
        defs.update(rglru_defs(cfg, f"{prefix}/rec", stack=stack))
        defs.update(mlp_defs(cfg, f"{prefix}/mlp", stack=stack))
        defs.update(norm_defs(cfg, f"{prefix}/ln1", stack=stack))
        defs.update(norm_defs(cfg, f"{prefix}/ln2", stack=stack))
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return defs


def lm_param_defs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab()
    dt = cfg.param_dtype
    emb_axes = (("vocab", "embed") if cfg.embed_shard == "vocab"
                else (None, "ffn"))  # 'ffn' -> model axis on the d dim
    if cfg.tie_embeddings and cfg.embed_shard != "vocab":
        raise ValueError("embed d-sharding requires an untied LM head "
                         "(tied logits would contract over a sharded dim)")
    defs = {"embed": ParamDef((v, d), emb_axes, dtype=dt)}
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, n_groups = layout
        if len(pattern) == 1:
            defs.update(block_defs(cfg, pattern[0], "blocks", stack=n_groups))
        else:
            for i, kind in enumerate(pattern):
                defs.update(block_defs(cfg, kind, f"blocks_{i}",
                                       stack=n_groups))
    else:
        for i in range(cfg.num_layers):
            defs.update(block_defs(cfg, cfg.layer_kind(i), f"layer_{i:03d}"))
    defs.update(norm_defs(cfg, "final_norm"))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"), dtype=dt)
    return defs


def _scan_params(cfg, params, layout):
    """xs pytree for lax.scan: tuple over pattern positions."""
    _, pattern, _ = layout
    if len(pattern) == 1:
        return (params["blocks"],)
    return tuple(params[f"blocks_{i}"] for i in range(len(pattern)))


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def block_forward(cfg, kind: str, p, x, *, positions=None,
                  mode: str = "reference", mesh=None, data_axes=("data",)):
    """Returns (x, aux_loss).

    The pre-norm residual stream routes *unnormed* into attention_layer /
    mlp_forward / moe_forward (``prenorm=`` carries the norm params): the
    pallas modes fold the ln1/ln2 norms into the QKV / MLP-up GEMM A-tile
    prologues (DESIGN.md §10), and the shard_map MoE paths norm the
    per-rank token slice inside the shard and run the fused expert FFN
    under collective tracing (DESIGN.md §16); reference mode applies the
    identical standalone norm inside the layer. Recurrent cores keep the
    standalone norm (non-GEMM chains, see ROADMAP deferred items).
    """
    aux = jnp.zeros((), jnp.float32)
    rs = cfg.residual_scale
    if kind in ("attn", "local", "moe"):
        a = attention_layer(cfg, p["attn"], x, causal=True,
                            window=_block_window(cfg, kind),
                            positions=positions, mode=mode,
                            prenorm=norm_params(p, "ln1"))
        x = x + rs * a
        if kind == "moe":
            m, aux = moe_forward(cfg, p["moe"], x, mesh=mesh,
                                 data_axes=data_axes, mode=mode,
                                 prenorm=norm_params(p, "ln2"))
            x = x + rs * m
        else:
            x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                            residual_scale=rs, prenorm=norm_params(p, "ln2"))
    elif kind == "ssm":
        h = apply_norm(cfg, x, p, "ln1")
        x = x + rs * ssm_forward(cfg, p["ssm"], h)
    elif kind == "rg":
        h = apply_norm(cfg, x, p, "ln1")
        x = x + rs * rglru_forward(cfg, p["rec"], h)
        x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                        residual_scale=rs, prenorm=norm_params(p, "ln2"))
    return x, aux


def _logits(cfg, params, x):
    x = apply_norm(cfg, x, params, "final_norm")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    if cfg.padded_vocab() != cfg.vocab_size:
        # mask the padding columns so they carry no probability mass
        pad_mask = jnp.arange(cfg.padded_vocab()) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits / cfg.logit_scale_div


def _remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, prevent_cse=False, policy=policy)


def lm_forward(cfg, params, tokens, *, mode: str = "reference", mesh=None,
               data_axes=("data",), remat: bool = False,
               return_hidden: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V) fp32 (or hidden states)."""
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(cfg.compute_dtype) * cfg.emb_scale
    positions = jnp.arange(tokens.shape[1])

    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, _ = layout

        def body(carry, group_params):
            h, aux = carry
            for kind, layer_params in zip(pattern, group_params):
                h, aux_l = block_forward(cfg, kind, layer_params,
                                         h, positions=positions, mode=mode,
                                         mesh=mesh, data_axes=data_axes)
                aux = aux + aux_l
            return (h, aux), None

        if remat:
            body = _remat(cfg, body)
        from repro.util import scan_unroll
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   _scan_params(cfg, params, layout),
                                   unroll=scan_unroll())
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            kind = cfg.layer_kind(i)
            fn = functools.partial(block_forward, cfg, kind,
                                   positions=positions, mode=mode, mesh=mesh,
                                   data_axes=data_axes)
            if remat:
                fn = _remat(cfg, fn)
            x, aux_l = fn(params[f"layer_{i:03d}"], x)
            aux = aux + aux_l
    if return_hidden:
        return x, aux
    return _logits(cfg, params, x), aux


def _chunked_ce(cfg, params, hidden, targets, mask, chunk: int):
    """CE over sequence chunks — the (B, S, V) logits are never materialized
    (per-chunk remat keeps the backward bounded too). §Perf lever."""
    from repro.util import scan_unroll
    b, s, d = hidden.shape
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        nll_sum, m_sum = carry
        h, t, m = inp
        logits = _logits(cfg, params, h)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, t[..., None], axis=-1)[..., 0]
        mf = m.astype(jnp.float32)
        return (nll_sum + jnp.sum((lse - gold) * mf), m_sum + jnp.sum(mf)), None

    (nll, msum), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                  (hs, ts, ms), unroll=scan_unroll())
    return nll / jnp.maximum(msum, 1.0)


def lm_loss(cfg, params, batch, *, mode="reference", mesh=None,
            data_axes=("data",), remat: bool = True, aux_weight: float = 0.01):
    if cfg.ce_chunk:
        hidden, aux = lm_forward(cfg, params, batch["inputs"], mode=mode,
                                 mesh=mesh, data_axes=data_axes, remat=remat,
                                 return_hidden=True)
        ce = _chunked_ce(cfg, cast_params(params, cfg.compute_dtype), hidden,
                         batch["targets"], batch.get("loss_mask"),
                         cfg.ce_chunk)
    else:
        logits, aux = lm_forward(cfg, params, batch["inputs"], mode=mode,
                                 mesh=mesh, data_axes=data_axes, remat=remat)
        ce = cross_entropy_loss(logits, batch["targets"],
                                batch.get("loss_mask"))
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def _block_cache(cfg, kind, batch, max_len, dtype):
    if kind in ("attn", "local", "moe"):
        return init_attn_cache(cfg, batch, max_len, _block_window(cfg, kind),
                               dtype)
    if kind == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    if kind == "rg":
        return init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def lm_init_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, n_groups = layout

        def stacked(kind):
            one = _block_cache(cfg, kind, batch, max_len, dtype)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape),
                one)
        if len(pattern) == 1:
            return stacked(pattern[0])
        return {f"blocks_{i}": stacked(kind)
                for i, kind in enumerate(pattern)}
    return {f"layer_{i:03d}": _block_cache(cfg, cfg.layer_kind(i), batch,
                                           max_len, dtype)
            for i in range(cfg.num_layers)}


def _scan_cache(cfg, cache, layout):
    _, pattern, _ = layout
    if len(pattern) == 1:
        return (cache,)
    return tuple(cache[f"blocks_{i}"] for i in range(len(pattern)))


def _unscan_cache(cfg, cache_tuple, layout):
    _, pattern, _ = layout
    if len(pattern) == 1:
        return cache_tuple[0]
    return {f"blocks_{i}": c for i, c in enumerate(cache_tuple)}


def block_prefill(cfg, kind, p, x, cache, *, positions, mode="reference",
                  mesh=None, data_axes=("data",)):
    """Full-seq forward that also fills the decode cache. Returns (x, cache)."""
    s = x.shape[1]
    if kind in ("attn", "local", "moe"):
        window = _block_window(cfg, kind)
        # the same fused-QKV plan ladder as block_forward (DESIGN.md §12);
        # k comes back rotated, which is exactly the cache convention
        q, k, v = project_qkv_heads(cfg, p["attn"], x, positions, mode=mode,
                                    prenorm=norm_params(p, "ln1"))
        o = attention_op(q, k, v, causal=True, window=window, mode=mode,
                         softcap=getattr(cfg, "attn_logit_softcap", None))
        cache = prefill_attn_cache(cfg, cache, k, v, s, window)
        x = x + cfg.residual_scale * (_merge_heads(o) @ p["attn"]["wo"])
        if kind == "moe":
            m, _ = moe_forward(cfg, p["moe"], x, mesh=mesh,
                               data_axes=data_axes, mode=mode,
                               prenorm=norm_params(p, "ln2"))
            x = x + cfg.residual_scale * m
        else:
            x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                            residual_scale=cfg.residual_scale,
                            prenorm=norm_params(p, "ln2"))
    elif kind == "ssm":
        h = apply_norm(cfg, x, p, "ln1")
        o, cache = ssm_prefill(cfg, p["ssm"], h)
        x = x + cfg.residual_scale * o
    elif kind == "rg":
        h = apply_norm(cfg, x, p, "ln1")
        o, cache = rglru_prefill(cfg, p["rec"], h)
        x = x + cfg.residual_scale * o
        x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                        residual_scale=cfg.residual_scale,
                        prenorm=norm_params(p, "ln2"))
    return x, cache


def block_decode(cfg, kind, p, x, cache, pos, *, mode="reference", mesh=None,
                 data_axes=("data",)):
    rs = cfg.residual_scale
    if kind in ("attn", "local", "moe"):
        h = apply_norm(cfg, x, p, "ln1")
        a, cache = decode_attention_layer(cfg, p["attn"], h, cache, pos,
                                          window=_block_window(cfg, kind),
                                          mode=mode)
        x = x + rs * a
        if kind == "moe":
            m, _ = moe_forward(cfg, p["moe"], x, mesh=mesh,
                               data_axes=data_axes, mode=mode,
                               prenorm=norm_params(p, "ln2"))
            x = x + rs * m
        else:
            x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                            residual_scale=rs, prenorm=norm_params(p, "ln2"))
    elif kind == "ssm":
        h = apply_norm(cfg, x, p, "ln1")
        o, cache = ssm_decode_step(cfg, p["ssm"], h, cache)
        x = x + rs * o
    elif kind == "rg":
        h = apply_norm(cfg, x, p, "ln1")
        o, cache = rglru_decode_step(cfg, p["rec"], h, cache)
        x = x + rs * o
        x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                        residual_scale=rs, prenorm=norm_params(p, "ln2"))
    return x, cache


def lm_prefill(cfg, params, tokens, cache, *, mode="reference", mesh=None,
               data_axes=("data",)):
    """Returns (cache, last-position logits (B, V))."""
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(cfg.compute_dtype) * cfg.emb_scale
    positions = jnp.arange(tokens.shape[1])
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, _ = layout

        def body(h, xs):
            group_params, group_cache = xs
            new = []
            for kind, layer_params, layer_cache in zip(pattern, group_params,
                                                       group_cache):
                h, nc = block_prefill(cfg, kind, layer_params, h,
                                      layer_cache, positions=positions,
                                      mode=mode, mesh=mesh,
                                      data_axes=data_axes)
                new.append(nc)
            return h, tuple(new)

        from repro.util import scan_unroll
        x, cache_t = jax.lax.scan(body, x, (_scan_params(cfg, params, layout),
                                            _scan_cache(cfg, cache, layout)),
                                  unroll=scan_unroll())
        cache = _unscan_cache(cfg, cache_t, layout)
    else:
        new = {}
        for i in range(cfg.num_layers):
            key = f"layer_{i:03d}"
            x, new[key] = block_prefill(cfg, cfg.layer_kind(i), params[key], x,
                                        cache[key], positions=positions,
                                        mode=mode, mesh=mesh,
                                        data_axes=data_axes)
        cache = new
    logits = _logits(cfg, params, x[:, -1:, :])
    return cache, logits[:, 0]


def lm_decode_step(cfg, params, token, cache, pos, *, mode="reference",
                   mesh=None, data_axes=("data",)):
    """token: (B, 1) int32; pos: scalar. Returns (cache, logits (B, V))."""
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][token].astype(cfg.compute_dtype) * cfg.emb_scale
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, _ = layout

        def body(h, xs):
            group_params, group_cache = xs
            new = []
            for kind, layer_params, layer_cache in zip(pattern, group_params,
                                                       group_cache):
                h, nc = block_decode(cfg, kind, layer_params, h,
                                     layer_cache, pos, mode=mode, mesh=mesh,
                                     data_axes=data_axes)
                new.append(nc)
            return h, tuple(new)

        from repro.util import scan_unroll
        x, cache_t = jax.lax.scan(body, x, (_scan_params(cfg, params, layout),
                                            _scan_cache(cfg, cache, layout)),
                                  unroll=scan_unroll())
        cache = _unscan_cache(cfg, cache_t, layout)
    else:
        new = {}
        for i in range(cfg.num_layers):
            key = f"layer_{i:03d}"
            x, new[key] = block_decode(cfg, cfg.layer_kind(i), params[key], x,
                                       cache[key], pos, mode=mode, mesh=mesh,
                                       data_axes=data_axes)
        cache = new
    logits = _logits(cfg, params, x)
    return cache, logits[:, 0]


# ---------------------------------------------------------------------------
# Paged decode path (shared page pool; DESIGN.md §8)
# ---------------------------------------------------------------------------

def _block_paged_cache(cfg, kind, batch_slots, n_pages, page_size, dtype):
    """Attention layers share a physical page pool; recurrent layers keep
    their constant-size per-slot state (continuous batching resets a slot's
    state at admission, so no paging is needed there)."""
    if kind in ("attn", "local", "moe"):
        return init_paged_attn_cache(cfg, n_pages, page_size, dtype)
    if kind == "ssm":
        return init_ssm_cache(cfg, batch_slots, dtype)
    if kind == "rg":
        return init_rglru_cache(cfg, batch_slots, dtype)
    raise ValueError(kind)


def lm_init_paged_cache(cfg, batch_slots: int, n_pages: int, page_size: int):
    """Paged analogue of :func:`lm_init_cache`: same pytree layout, but
    attention leaves are (n_pages, Hkv, page_size, hd) pools instead of
    (B, Hkv, max_len, hd) dense caches."""
    dtype = jnp.dtype(cfg.compute_dtype)
    layout = _layout(cfg)

    def one(kind):
        return _block_paged_cache(cfg, kind, batch_slots, n_pages,
                                  page_size, dtype)

    if layout[0] == "scan":
        _, pattern, n_groups = layout

        def stacked(kind):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape),
                one(kind))
        if len(pattern) == 1:
            return stacked(pattern[0])
        return {f"blocks_{i}": stacked(kind)
                for i, kind in enumerate(pattern)}
    return {f"layer_{i:03d}": one(cfg.layer_kind(i))
            for i in range(cfg.num_layers)}


def block_prefill_paged(cfg, kind, p, x, cache, *, page_rows, slot,
                        positions, mode="reference", mesh=None,
                        data_axes=("data",)):
    """Single-sequence (B=1) prefill that fills the paged cache: attention
    k/v land in the sequence's pages; recurrent state lands in its batch
    slot. Returns (x, cache)."""
    if kind in ("attn", "local", "moe"):
        window = _block_window(cfg, kind)
        # same fused plan ladder as the dense block_prefill; rotated k
        # lands in the pages (the cache convention)
        q, k, v = project_qkv_heads(cfg, p["attn"], x, positions, mode=mode,
                                    prenorm=norm_params(p, "ln1"))
        o = attention_op(q, k, v, causal=True, window=window, mode=mode,
                         softcap=getattr(cfg, "attn_logit_softcap", None))
        cache = paged_prefill_attn_cache(cfg, cache, k, v, page_rows)
        x = x + cfg.residual_scale * (_merge_heads(o) @ p["attn"]["wo"])
        if kind == "moe":
            m, _ = moe_forward(cfg, p["moe"], x, mesh=mesh,
                               data_axes=data_axes, mode=mode,
                               prenorm=norm_params(p, "ln2"))
            x = x + cfg.residual_scale * m
        else:
            x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                            residual_scale=cfg.residual_scale,
                            prenorm=norm_params(p, "ln2"))
    elif kind == "ssm":
        h = apply_norm(cfg, x, p, "ln1")
        o, state = ssm_prefill(cfg, p["ssm"], h)
        cache = jax.tree.map(lambda c, s: c.at[slot].set(s[0]), cache, state)
        x = x + cfg.residual_scale * o
    elif kind == "rg":
        h = apply_norm(cfg, x, p, "ln1")
        o, state = rglru_prefill(cfg, p["rec"], h)
        cache = jax.tree.map(lambda c, s: c.at[slot].set(s[0]), cache, state)
        x = x + cfg.residual_scale * o
        x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                        residual_scale=cfg.residual_scale,
                        prenorm=norm_params(p, "ln2"))
    return x, cache


def lm_prefill_paged(cfg, params, tokens, cache, page_rows, slot, true_len,
                     *, mode="reference", mesh=None, data_axes=("data",)):
    """Prefill ONE sequence into the shared paged cache.

    tokens: (1, S); ``page_rows``: (max_pages,) page-table row; ``slot``:
    the sequence's batch slot (recurrent state lands there). Returns
    (cache, logits (1, V) at position ``true_len - 1``).

    S may exceed ``true_len`` (a padded bucket) ONLY for attention-only
    stacks: attention k/v past true_len stay masked by the length until
    overwritten, but ssm/rglru prefill state is the *final* scan state and
    would absorb the pad positions — callers serving recurrent/hybrid archs
    (PagedEngine does) must pass exact-length tokens (S == true_len).
    """
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(cfg.compute_dtype) * cfg.emb_scale
    positions = jnp.arange(tokens.shape[1])
    kw = dict(page_rows=page_rows, slot=slot, positions=positions, mode=mode,
              mesh=mesh, data_axes=data_axes)
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, _ = layout

        def body(h, xs):
            group_params, group_cache = xs
            new = []
            for kind, layer_params, layer_cache in zip(pattern, group_params,
                                                       group_cache):
                h, nc = block_prefill_paged(cfg, kind, layer_params, h,
                                            layer_cache, **kw)
                new.append(nc)
            return h, tuple(new)

        from repro.util import scan_unroll
        x, cache_t = jax.lax.scan(body, x, (_scan_params(cfg, params, layout),
                                            _scan_cache(cfg, cache, layout)),
                                  unroll=scan_unroll())
        cache = _unscan_cache(cfg, cache_t, layout)
    else:
        new = {}
        for i in range(cfg.num_layers):
            key = f"layer_{i:03d}"
            x, new[key] = block_prefill_paged(cfg, cfg.layer_kind(i),
                                              params[key], x, cache[key],
                                              **kw)
        cache = new
    x_last = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
    logits = _logits(cfg, params, x_last)
    return cache, logits[:, 0]


def _attention_only(cfg) -> bool:
    """True when every layer is attention-family (attn/local/moe blocks).

    The serving fast paths — chunked prefill, prefix reuse, multi-token
    verify — all rely on the KV cache being position-addressable pages.
    Recurrent state (ssm/rg) is a single constant-size scan state per slot:
    it cannot be re-entered mid-prompt, shared by prefix, or stepped T
    tokens at once, so those stacks keep the exact-length one-shot paths.
    """
    return all(cfg.layer_kind(i) in ("attn", "local", "moe")
               for i in range(cfg.num_layers))


def block_prefill_paged_chunk(cfg, kind, p, x, cache, *, page_rows, start,
                              positions, mode="reference", mesh=None,
                              data_axes=("data",)):
    """One layer of chunked prefill: the chunk's k/v land in the sequence's
    pages at page offset ``start // page_size`` and the chunk's queries
    attend to everything already in the pages (previous chunks + this one)
    through the multi-token paged-decode mask. Attention-family only."""
    window = _block_window(cfg, kind)
    c = x.shape[1]
    q, k, v = project_qkv_heads(cfg, p["attn"], x, positions, mode=mode,
                                prenorm=norm_params(p, "ln1"))
    page_size = cache["k_pages"].shape[2]
    cache = paged_prefill_attn_cache(cfg, cache, k, v, page_rows,
                                     start_page=start // page_size)
    o = attention_decode_paged(
        q, cache["k_pages"], cache["v_pages"],
        jnp.asarray(page_rows, jnp.int32)[None, :],
        jnp.asarray(start + c, jnp.int32).reshape(1),
        window=window, mode=mode,
        softcap=getattr(cfg, "attn_logit_softcap", None)).astype(x.dtype)
    x = x + cfg.residual_scale * (_merge_heads(o) @ p["attn"]["wo"])
    if kind == "moe":
        m, _ = moe_forward(cfg, p["moe"], x, mesh=mesh,
                           data_axes=data_axes, mode=mode,
                           prenorm=norm_params(p, "ln2"))
        x = x + cfg.residual_scale * m
    else:
        x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                        residual_scale=cfg.residual_scale,
                        prenorm=norm_params(p, "ln2"))
    return x, cache


def lm_prefill_paged_chunk(cfg, params, tokens, cache, page_rows, start,
                           last_index, *, mode="reference", mesh=None,
                           data_axes=("data",)):
    """Prefill ONE chunk of one sequence into the shared paged cache.

    tokens: (1, C) — chunk C must be a whole number of pages; ``start``
    (traced ok) is the chunk's first absolute position (a page multiple);
    ``last_index`` (traced ok) indexes the final true token within the
    chunk (its logits seed sampling — meaningful on the last chunk only).
    One compiled instance per chunk length C serves every chunk index and
    every suffix offset: prefix-cache admission reuses it with ``start`` =
    the matched prefix length. Returns (cache, logits (1, V)).

    Attention-family stacks only (see :func:`_attention_only`): recurrent
    state cannot be re-entered mid-prompt, so hybrid archs keep the
    exact-length :func:`lm_prefill_paged`.
    """
    if not _attention_only(cfg):
        raise ValueError(
            "chunked paged prefill requires an attention-only stack; "
            f"{cfg.name} has recurrent layers — use lm_prefill_paged")
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][tokens].astype(cfg.compute_dtype) * cfg.emb_scale
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(tokens.shape[1])
    kw = dict(page_rows=page_rows, start=start, positions=positions,
              mode=mode, mesh=mesh, data_axes=data_axes)
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, _ = layout

        def body(h, xs):
            group_params, group_cache = xs
            new = []
            for kind, layer_params, layer_cache in zip(pattern, group_params,
                                                       group_cache):
                h, nc = block_prefill_paged_chunk(cfg, kind, layer_params, h,
                                                  layer_cache, **kw)
                new.append(nc)
            return h, tuple(new)

        from repro.util import scan_unroll
        x, cache_t = jax.lax.scan(body, x, (_scan_params(cfg, params, layout),
                                            _scan_cache(cfg, cache, layout)),
                                  unroll=scan_unroll())
        cache = _unscan_cache(cfg, cache_t, layout)
    else:
        new = {}
        for i in range(cfg.num_layers):
            key = f"layer_{i:03d}"
            x, new[key] = block_prefill_paged_chunk(cfg, cfg.layer_kind(i),
                                                    params[key], x,
                                                    cache[key], **kw)
        cache = new
    x_last = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
    logits = _logits(cfg, params, x_last)
    return cache, logits[:, 0]


def block_decode_paged(cfg, kind, p, x, cache, page_table, lengths, *,
                       mode="reference", mesh=None, data_axes=("data",)):
    rs = cfg.residual_scale
    if kind in ("attn", "local", "moe"):
        h = apply_norm(cfg, x, p, "ln1")
        a, cache = paged_decode_attention_layer(
            cfg, p["attn"], h, cache, page_table, lengths,
            window=_block_window(cfg, kind), mode=mode)
        x = x + rs * a
        if kind == "moe":
            m, _ = moe_forward(cfg, p["moe"], x, mesh=mesh,
                               data_axes=data_axes, mode=mode,
                               prenorm=norm_params(p, "ln2"))
            x = x + rs * m
        else:
            x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                            residual_scale=rs, prenorm=norm_params(p, "ln2"))
    elif kind == "ssm":
        h = apply_norm(cfg, x, p, "ln1")
        o, cache = ssm_decode_step(cfg, p["ssm"], h, cache)
        x = x + rs * o
    elif kind == "rg":
        h = apply_norm(cfg, x, p, "ln1")
        o, cache = rglru_decode_step(cfg, p["rec"], h, cache)
        x = x + rs * o
        x = mlp_forward(cfg, p["mlp"], x, mode=mode, residual=x,
                        residual_scale=rs, prenorm=norm_params(p, "ln2"))
    return x, cache


def lm_decode_step_paged(cfg, params, token, cache, page_table, lengths, *,
                         mode="reference", mesh=None, data_axes=("data",)):
    """One decode step for every batch slot over the paged cache.

    token: (B, T) int32 — T == 1 is plain decode (each slot's token lands
    at position lengths[b], logits return as (B, V)); T > 1 is the
    speculative verify step (token t lands at lengths[b] + t, logits
    return as (B, T, V); attention-only stacks). Inactive slots decode
    against the null page and produce ignorable logits.
    """
    if token.shape[1] > 1 and not _attention_only(cfg):
        raise ValueError(
            "multi-token paged decode (speculative verify) requires an "
            f"attention-only stack; {cfg.name} has recurrent layers")
    params = cast_params(params, cfg.compute_dtype)
    x = params["embed"][token].astype(cfg.compute_dtype) * cfg.emb_scale
    layout = _layout(cfg)
    if layout[0] == "scan":
        _, pattern, _ = layout

        def body(h, xs):
            group_params, group_cache = xs
            new = []
            for kind, layer_params, layer_cache in zip(pattern, group_params,
                                                       group_cache):
                h, nc = block_decode_paged(cfg, kind, layer_params, h,
                                           layer_cache, page_table, lengths,
                                           mode=mode, mesh=mesh,
                                           data_axes=data_axes)
                new.append(nc)
            return h, tuple(new)

        from repro.util import scan_unroll
        x, cache_t = jax.lax.scan(body, x, (_scan_params(cfg, params, layout),
                                            _scan_cache(cfg, cache, layout)),
                                  unroll=scan_unroll())
        cache = _unscan_cache(cfg, cache_t, layout)
    else:
        new = {}
        for i in range(cfg.num_layers):
            key = f"layer_{i:03d}"
            x, new[key] = block_decode_paged(cfg, cfg.layer_kind(i),
                                            params[key], x, cache[key],
                                            page_table, lengths, mode=mode,
                                            mesh=mesh, data_axes=data_axes)
        cache = new
    logits = _logits(cfg, params, x)
    if token.shape[1] > 1:
        return cache, logits          # (B, T, V) — speculative verify
    return cache, logits[:, 0]
