"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Gated linear recurrence: h_t = a_t · h_{t-1} + √(1−a_t²) · (i_t ⊙ u_t) with
a_t = σ(Λ)^(c·r_t). Trained with an associative scan (log-depth, sub-quadratic
— this is what makes the long_500k cell runnable); decode carries a (B, W)
state. Attention kernels are inapplicable to these layers (attention-free);
the 1-in-3 local-attention layers use the flash kernel with a window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef


def rg_width(cfg) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_defs(cfg, prefix: str, *, stack: int | None = None) -> dict:
    d, w = cfg.d_model, rg_width(cfg)
    kw = cfg.rglru.conv_width
    lead = (stack,) if stack else ()
    lx = ("layers",) if stack else ()
    dt = cfg.param_dtype
    return {
        f"{prefix}/proj_x": ParamDef(lead + (d, w), lx + ("embed", "ffn"), dtype=dt),
        f"{prefix}/proj_gate": ParamDef(lead + (d, w), lx + ("embed", "ffn"), dtype=dt),
        f"{prefix}/conv_w": ParamDef(lead + (w, kw), lx + (None, None), dtype=dt),
        f"{prefix}/conv_b": ParamDef(lead + (w,), lx + (None,), init="zeros", dtype=dt),
        f"{prefix}/w_a": ParamDef(lead + (w, w), lx + ("ffn", None), dtype=dt),
        f"{prefix}/b_a": ParamDef(lead + (w,), lx + (None,), init="zeros", dtype=dt),
        f"{prefix}/w_i": ParamDef(lead + (w, w), lx + ("ffn", None), dtype=dt),
        f"{prefix}/b_i": ParamDef(lead + (w,), lx + (None,), init="zeros", dtype=dt),
        f"{prefix}/lambda": ParamDef(lead + (w,), lx + (None,), init="lru_a", dtype=dt),
        f"{prefix}/proj_out": ParamDef(lead + (w, d), lx + ("ffn", "embed"), dtype=dt),
    }


def _causal_conv(x, w, b):
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "OIH", "NHC"),
        feature_group_count=w.shape[0])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _gates(cfg, p, u):
    """u: (B, L, W) conv output. Returns (log_a, gated_input) both fp32.

    ``cfg.rglru_f32_gates=False`` runs the two (W, W) gate matmuls in bf16
    (§Perf lever — the fp32 gate GEMMs are 4x the MXU cost and 2x the bytes;
    the recurrence carries stay fp32 either way)."""
    gd = jnp.float32 if cfg.rglru_f32_gates else u.dtype
    ug = u.astype(gd)
    r = jax.nn.sigmoid((ug @ p["w_a"].astype(gd) +
                        p["b_a"].astype(gd)).astype(jnp.float32))
    i = jax.nn.sigmoid((ug @ p["w_i"].astype(gd) +
                        p["b_i"].astype(gd)).astype(jnp.float32))
    # log a_t = c · r_t · log σ(Λ) = −c · r_t · softplus(−Λ)
    log_a = -cfg.rglru.c_exponent * r * jax.nn.softplus(
        -p["lambda"].astype(jnp.float32))
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * u.astype(jnp.float32))
    return log_a, gated


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 + a2, jnp.exp(a2) * b1 + b2


def rglru_scan(log_a, x, chunk: int = 0):
    """Scan of h_t = a_t h_{t-1} + x_t over axis 1 (time).

    ``chunk=0``: single associative scan — log2(L) levels of (B, L, W)
    intermediates. ``chunk>0`` (§Perf lever): two-level SSD-style scan —
    associative within chunks (log2(C) levels) + a tiny sequential scan over
    the L/C chunk boundaries, cutting scan-intermediate traffic by
    ~log2(L)/log2(C) while computing the identical recurrence.
    """
    if not chunk or x.shape[1] % chunk or x.shape[1] <= chunk:
        la, h = jax.lax.associative_scan(_combine, (log_a, x), axis=1)
        return h
    b, l, w = x.shape
    nc = l // chunk
    la_c = log_a.reshape(b, nc, chunk, w)
    x_c = x.reshape(b, nc, chunk, w)
    cum_a, h_local = jax.lax.associative_scan(_combine, (la_c, x_c), axis=2)

    # carry chunk-boundary states: H_c = exp(a_end_c) * H_{c-1} + h_end_c
    a_end = cum_a[:, :, -1]            # (B, nc, W)
    h_end = h_local[:, :, -1]

    from repro.util import scan_unroll

    def step(carry, inp):
        ae, he = inp
        new = jnp.exp(ae) * carry + he
        return new, carry                # emit the PREVIOUS chunk's state

    h0 = jnp.zeros((b, w), x.dtype)
    _, h_prev = jax.lax.scan(step, h0, (a_end.transpose(1, 0, 2),
                                        h_end.transpose(1, 0, 2)),
                             unroll=scan_unroll())
    h_prev = h_prev.transpose(1, 0, 2)  # (B, nc, W) state entering each chunk
    h = h_local + jnp.exp(cum_a) * h_prev[:, :, None, :]
    return h.reshape(b, l, w)


def rglru_forward(cfg, p, x):
    """Full recurrent block. x: (B, L, D) -> (B, L, D)."""
    gate = jax.nn.gelu(x @ p["proj_gate"], approximate=True)
    u = _causal_conv(x @ p["proj_x"], p["conv_w"], p["conv_b"])
    log_a, gated = _gates(cfg, p, u)
    h = rglru_scan(log_a, gated,
                   chunk=getattr(cfg, "rglru_chunk", 0)).astype(x.dtype)
    return (h * gate) @ p["proj_out"]


def init_rglru_cache(cfg, batch: int, dtype) -> dict:
    w = rg_width(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode_step(cfg, p, x, cache):
    """x: (B, 1, D). Returns (out (B,1,D), new_cache)."""
    gate = jax.nn.gelu(x[:, 0] @ p["proj_gate"], approximate=True)
    ux = x[:, 0] @ p["proj_x"]
    window = jnp.concatenate([cache["conv"], ux[:, None, :]], axis=1)
    u = (jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) +
         p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    log_a, gated = _gates(cfg, p, u[:, None, :])
    h = jnp.exp(log_a[:, 0]) * cache["h"] + gated[:, 0]
    out = ((h.astype(x.dtype) * gate) @ p["proj_out"])[:, None, :]
    return out, {"conv": window[:, 1:], "h": h}


def rglru_prefill(cfg, p, x):
    """Full forward returning the decode cache at the end of x."""
    gate = jax.nn.gelu(x @ p["proj_gate"], approximate=True)
    ux = x @ p["proj_x"]
    conv_tail = ux[:, -(cfg.rglru.conv_width - 1):, :]
    u = _causal_conv(ux, p["conv_w"], p["conv_b"])
    log_a, gated = _gates(cfg, p, u)
    h_seq = rglru_scan(log_a, gated)
    out = (h_seq.astype(x.dtype) * gate) @ p["proj_out"]
    return out, {"conv": conv_tail, "h": h_seq[:, -1]}
