"""Model substrate: the 10 assigned architectures in pure JAX."""
from .api import Model, build_model, make_batch  # noqa: F401
