"""Parameter declaration machinery + shared numerics.

A model is described by a flat dict ``{path: ParamDef}`` — one source of
truth for (a) initialization, (b) logical sharding axes, (c) the dry-run's
ShapeDtypeStructs. The nested param pytree is derived from the flat paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro import obs


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # 'normal' | 'zeros' | 'ones' | 'lru_a'
    scale: float = 1.0                # stddev multiplier (normal init)
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def nest(flat: Mapping[str, object]) -> dict:
    """{'a/b/c': v} -> {'a': {'b': {'c': v}}}"""
    tree: dict = {}
    for path, value in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


def init_params(defs: Mapping[str, ParamDef], rng: jax.Array) -> dict:
    keys = jax.random.split(rng, max(1, len(defs)))
    flat = {}
    for key, (path, d) in zip(keys, sorted(defs.items())):
        dtype = jnp.dtype(d.dtype)
        if d.init == "zeros":
            flat[path] = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            flat[path] = jnp.ones(d.shape, dtype)
        elif d.init == "lru_a":
            # RG-LRU Λ init: a = sigmoid(Λ) uniform in [0.9, 0.999] (Griffin)
            u = jax.random.uniform(key, d.shape, jnp.float32, 0.9, 0.999)
            flat[path] = jnp.log(u / (1 - u)).astype(dtype)
        else:
            fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[-1]
            std = d.scale / math.sqrt(max(1, fan_in))
            flat[path] = (jax.random.normal(key, d.shape, jnp.float32) * std
                          ).astype(dtype)
    return nest(flat)


def abstract_params(defs: Mapping[str, ParamDef]) -> dict:
    return nest({p: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))
                 for p, d in defs.items()})


def logical_axes(defs: Mapping[str, ParamDef]) -> dict:
    return nest({p: d.axes for p, d in defs.items()})


def param_bytes(defs: Mapping[str, ParamDef]) -> int:
    return sum(math.prod(d.shape) * jnp.dtype(d.dtype).itemsize
               for d in defs.values())


def cast_params(params, dtype):
    """Cast float params to the compute dtype (fp32 masters live in the
    train state; norms/softmax upcast internally regardless)."""
    dtype = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dtype)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)


# ---------------------------------------------------------------------------
# Shared numerics (always fp32 internally).
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    c = xf - mean
    var = jnp.mean(c * c, axis=-1, keepdims=True)
    out = c * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(cfg, x, p, prefix: str):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p[f"{prefix}_scale"])
    return layernorm(x, p[f"{prefix}_scale"], p.get(f"{prefix}_bias"))


def norm_params(p, prefix: str) -> tuple:
    """The (scale, bias) pair of a norm's params, for the ``prenorm``
    argument of mlp_forward / attention_layer (DESIGN.md §10): blocks hand
    the *pre-norm* residual stream plus these params to the layer, and the
    fused paths fold the norm into the first GEMM's A-tile prologue."""
    return (p[f"{prefix}_scale"], p.get(f"{prefix}_bias"))


def apply_prenorm(cfg, x, prenorm: tuple):
    """Standalone fallback for a ``prenorm`` pair — identical math to
    apply_norm (the prologue's oracle)."""
    # eager jnp, invisible to the kernel-launch journal — the counter is how
    # "no standalone norm ran" is asserted through obs.capture()
    obs.incr("model.standalone_norm")
    scale, bias = prenorm
    if getattr(cfg, "norm", "rmsnorm") == "rmsnorm":
        return rmsnorm(x, scale)
    return layernorm(x, scale, bias)


def resolve_norm_prologue(cfg, prenorm, *, kind, plan_shape, gemm_shape,
                          dtype, epilogue, residual=True):
    """The shared first rung of the prenorm fusion ladder (DESIGN.md §10),
    used by both the fused MLP and the fused QKV paths: fold the block's
    pre-norm into the first GEMM's A-tile prologue iff (a) the chain model
    picks the norm-fused plan from modeled dma_bytes and (b) a VMEM-legal
    prologue-carrying policy exists for that GEMM (the recompute path's
    full-K tile can be illegal for huge feature dims — the memoized
    select_policy probe discovers that).

    Returns (prologue, operand kwargs for gemm_fused, policy), or None —
    the caller then applies the standalone norm and scores the plain
    (norm-free) plan instead.
    """
    if prenorm is None:
        return None
    from repro.core import autotune
    from repro.kernels.gemm import norm_prologue

    norm_kind = getattr(cfg, "norm", "rmsnorm")
    plan = autotune.select_fusion(kind, plan_shape, dtype, residual=residual,
                                  prenorm=norm_kind)
    if plan["plan"] != "fused":
        return None
    scale, bias = prenorm
    pro = norm_prologue(norm_kind, beta=bias is not None)
    try:
        policy = autotune.select_policy("gemm", gemm_shape, dtype,
                                        epilogue=epilogue, prologue=pro)
    except ValueError:
        return None
    kw = {"gamma": scale}
    if bias is not None:
        kw["beta"] = bias
    return pro, kw, policy


def act_fn(name: str):
    if name == "swiglu" or name == "silu":
        return jax.nn.silu
    if name == "geglu" or name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


# Config activation name -> epilogue activation name. Exhaustive on purpose:
# an activation act_fn doesn't know must not silently fuse as something else.
_EPILOGUE_ACT = {"swiglu": "silu", "silu": "silu",
                 "geglu": "gelu", "gelu": "gelu"}


def _act_name(mlp_act: str) -> str:
    if mlp_act not in _EPILOGUE_ACT:
        raise ValueError(mlp_act)
    return _EPILOGUE_ACT[mlp_act]


def _mlp_fused(cfg, p, x, *, residual, residual_scale, mode, gated,
               prenorm=None):
    """The fused-megakernel MLP (DESIGN.md §9-§10): the two gated
    up-projections run as ONE dual-output GEMM whose store applies
    act(x@w_gate)·(x@w_in), and the down-projection GEMM's store applies
    the scaled residual add — the (T, F) intermediate and the (T, D)
    output never round-trip HBM between ops. With ``prenorm`` (the block's
    (scale, bias) norm params) the pre-norm additionally folds into the up
    GEMM's A-tile prologue when the chain model picks that plan and the
    full-K tile is VMEM-legal; otherwise the standalone norm runs here and
    the rest of the chain still fuses. Returns None when no part of the
    chain fuses (stacked weights, or the chain model picks the eager plan)
    — the caller then owns the norm and the unfused chain.
    """
    from repro.core import autotune
    from repro.kernels.gemm import Epilogue, gemm_fused

    w_in = p["w_in"]
    if w_in.ndim != 2:
        return None  # stacked (scan-layout) weights: per-layer slices only
    *lead, d = x.shape
    f = w_in.shape[-1]
    tokens = math.prod(lead) if lead else 1
    has_res = residual is not None
    act = _act_name(cfg.mlp_act)
    up_ep = (Epilogue(activation=act, gate=True) if gated
             else Epilogue(activation=act))

    resolved = resolve_norm_prologue(
        cfg, prenorm, kind="mlp", plan_shape=(tokens, d, f, gated),
        gemm_shape=(tokens, f, d), dtype=str(x.dtype), epilogue=up_ep,
        residual=has_res)
    if resolved is None:
        plan = autotune.select_fusion("mlp", (tokens, d, f, gated),
                                      str(x.dtype), residual=has_res)
        if plan["plan"] != "fused":
            return None
        if prenorm is not None:
            x = apply_prenorm(cfg, x, prenorm)  # standalone-norm fallback
        kw = {}
    else:
        prologue, pro_kw, up_policy = resolved
        kw = dict(prologue=prologue, policy=up_policy, **pro_kw)

    x2 = x.reshape(tokens, d)
    if gated:
        h = gemm_fused(x2, p["w_gate"], b2=w_in, epilogue=up_ep,
                       out_dtype=x.dtype, mode=mode, **kw)
    else:
        h = gemm_fused(x2, w_in, epilogue=up_ep,
                       out_dtype=x.dtype, mode=mode, **kw)
    if residual is None:
        y = gemm_fused(h, p["w_out"], epilogue=Epilogue(),
                       out_dtype=x.dtype, mode=mode)
    else:
        y = gemm_fused(h, p["w_out"],
                       epilogue=Epilogue(residual=True, scale=True),
                       residual=residual.reshape(tokens, d),
                       scale=residual_scale, out_dtype=x.dtype, mode=mode)
    return y.reshape(x.shape)


def mlp_forward(cfg, p, x, *, mode: str = "reference", residual=None,
                residual_scale: float = 1.0, prenorm=None):
    """Gated (swiglu/geglu) or plain MLP. p: params subtree with
    w_in/w_gate/w_out.

    With ``residual`` the returned value is ``residual + residual_scale *
    mlp(x)`` — callers pass their residual stream in so the pallas modes can
    fuse the add into the down-projection's store. With ``prenorm`` (the
    enclosing block's (scale, bias) norm params, see ``norm_params``) ``x``
    is the *pre-norm* residual stream and the returned value is
    ``residual + residual_scale * mlp(norm(x))`` — the pallas modes fold
    the norm into the up-projection GEMM's A-tile prologue (DESIGN.md §10)
    whenever the chain model picks that plan from modeled dma_bytes.
    'reference' keeps the original unfused jnp chain (the parity oracle).
    """
    gated = cfg.mlp_act in ("swiglu", "geglu")
    if mode != "reference":
        out = _mlp_fused(cfg, p, x, residual=residual,
                         residual_scale=residual_scale, mode=mode,
                         gated=gated, prenorm=prenorm)
        if out is not None:
            return out
    if prenorm is not None:
        x = apply_prenorm(cfg, x, prenorm)
    act = act_fn(cfg.mlp_act)
    if gated:
        h = act(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = act(x @ p["w_in"])
    m = h @ p["w_out"]
    if residual is None:
        return m
    return residual + residual_scale * m


def mlp_defs(cfg, prefix: str, *, stack: int | None = None,
             d_in: int | None = None, d_ff: int | None = None) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    lead = (stack,) if stack else ()
    lax_ = ("layers",) if stack else ()
    dt = cfg.param_dtype
    defs = {f"{prefix}/w_in": ParamDef(lead + (d, f), lax_ + ("embed", "ffn"), dtype=dt),
            f"{prefix}/w_out": ParamDef(lead + (f, d), lax_ + ("ffn", "embed"), dtype=dt)}
    if cfg.mlp_act in ("swiglu", "geglu"):
        defs[f"{prefix}/w_gate"] = ParamDef(lead + (d, f), lax_ + ("embed", "ffn"), dtype=dt)
    return defs


def norm_defs(cfg, prefix: str, *, stack: int | None = None,
              width: int | None = None) -> dict:
    d = width or cfg.d_model
    lead = (stack,) if stack else ()
    lax_ = ("layers",) if stack else ()
    dt = cfg.param_dtype
    defs = {f"{prefix}_scale": ParamDef(lead + (d,), lax_ + (None,), init="ones", dtype=dt)}
    if cfg.norm == "layernorm":
        defs[f"{prefix}_bias"] = ParamDef(lead + (d,), lax_ + (None,), init="zeros", dtype=dt)
    return defs


def cross_entropy_loss(logits, labels, mask=None):
    """Mean CE over valid positions. logits (..., V) fp32-cast internally."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
