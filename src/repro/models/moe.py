"""Mixture-of-Experts FFN: top-k routing with two implementations.

* ``dense`` — every expert computed for every token (tiny smoke configs only).
* ``ep``    — production expert parallelism via shard_map: tokens are
  sequence-split across the 'model' axis, dispatched into capacity buckets,
  all_to_all'd to their expert's owner, FFN'd with the locally-resident
  expert weights, all_to_all'd back and combined. This is the standard
  MoE a2a pattern (Switch/COMET) mapped to jax.lax collectives per the
  hardware-adaptation rule in DESIGN.md.
"""
from __future__ import annotations

import functools
import math

import dataclasses
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import obs
from .common import ParamDef, _act_name, act_fn, apply_prenorm

# Execution modes safe under collective tracing (shard_map). The interpret
# Pallas path traces fine inside shard_map on the forced-host-device harness;
# the real-TPU lowering has not been validated under collectives, so it
# observably falls back to the reference einsum (DESIGN.md §16).
_COLLECTIVE_SAFE_MODES = ("reference", "pallas_interpret")


def moe_defs(cfg, prefix: str, *, stack: int | None = None) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    lead = (stack,) if stack else ()
    lx = ("layers",) if stack else ()
    dt = cfg.param_dtype
    if cfg.moe.shard == "expert":          # EP: expert dim over the model axis
        in_axes = lx + ("expert", "embed", None)
        out_axes = lx + ("expert", None, "embed")
    else:                                   # TP: FFN hidden dim over model axis
        in_axes = lx + (None, "embed", "ffn")
        out_axes = lx + (None, "ffn", "embed")
    defs = {
        f"{prefix}/router": ParamDef(lead + (d, e), lx + ("embed", None), dtype=dt),
        f"{prefix}/w_in": ParamDef(lead + (e, d, f), in_axes, dtype=dt),
        f"{prefix}/w_out": ParamDef(lead + (e, f, d), out_axes, dtype=dt),
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        defs[f"{prefix}/w_gate"] = ParamDef(lead + (e, d, f), in_axes, dtype=dt)
    return defs


def _full_k_policy(shape, dtype, epilogue):
    """A gemm policy with block_k pinned to the full contraction dim, or
    None when no such VMEM-legal policy exists. K-tile accumulation order is
    the only fp difference between the fused kernel and jnp.dot, so a full-K
    policy makes the fused path *bitwise* equal to the reference einsum —
    the property the shard_map paths need so fused-vs-reference parity holds
    through collectives (DESIGN.md §16)."""
    from repro.core import autotune

    _, _, k = shape
    try:
        pol = autotune.select_policy("gemm", shape, dtype, epilogue=epilogue)
    except ValueError:
        return None
    if pol.block_k == k:
        return pol
    pinned = dataclasses.replace(
        pol, schedule=dataclasses.replace(pol.schedule, block_k=k))
    return pinned if pinned.is_legal() else None


def _expert_ffn_fused(cfg, p, x, mode, shard=None):
    """Per-expert fused megakernel FFN (DESIGN.md §9): each expert's two
    up-projections run as one dual-output GEMM (store applies the SwiGLU
    gating) followed by the down GEMM — the (T, F) expert intermediate
    never round-trips HBM. E is static, so the python loop unrolls into E
    independent kernel launches. Returns None when the autotuner's chain
    model picks the unfused plan. With ``shard`` (the enclosing shard_map's
    ShardSpec) the plan is scored with the collective chain term and both
    GEMMs run full-K policies so the fused path stays bitwise-equal to the
    reference oracle on every rank."""
    from repro.core import autotune
    from repro.kernels.gemm import Epilogue, gemm_fused

    e, t, d = x.shape
    f = p["w_in"].shape[-1]
    gated = cfg.mlp_act in ("swiglu", "geglu")
    # residual=False: the expert FFN chain has no residual add to eliminate
    plan = autotune.select_fusion("mlp", (t, d, f, gated), str(x.dtype),
                                  residual=False, shard=shard)
    if plan["plan"] != "fused":
        return None
    act = _act_name(cfg.mlp_act)
    up_ep = (Epilogue(activation=act, gate=True) if gated
             else Epilogue(activation=act))
    down_ep = Epilogue()
    up_pol = down_pol = None
    if shard is not None:
        up_pol = _full_k_policy((t, f, d), str(x.dtype), up_ep)
        down_pol = _full_k_policy((t, d, f), str(x.dtype), down_ep)
        if up_pol is None or down_pol is None:
            return None  # no bitwise-safe policy: reference path owns it
    outs = []
    for i in range(e):
        if gated:
            h = gemm_fused(x[i], p["w_gate"][i], b2=p["w_in"][i],
                           epilogue=up_ep, policy=up_pol,
                           out_dtype=x.dtype, mode=mode)
        else:
            h = gemm_fused(x[i], p["w_in"][i],
                           epilogue=up_ep, policy=up_pol,
                           out_dtype=x.dtype, mode=mode)
        outs.append(gemm_fused(h, p["w_out"][i], epilogue=down_ep,
                               policy=down_pol,
                               out_dtype=x.dtype, mode=mode))
    return jnp.stack(outs)


def _expert_ffn(cfg, p, x, mode: str = "reference", shard=None):
    """x: (E, T, D) grouped tokens; expert weights (E, D, F)/(E, F, D)."""
    if mode != "reference":
        out = _expert_ffn_fused(cfg, p, x, mode, shard=shard)
        if out is not None:
            return out
    act = act_fn(cfg.mlp_act)
    if cfg.mlp_act in ("swiglu", "geglu"):
        h = act(jnp.einsum("etd,edf->etf", x, p["w_gate"])) * \
            jnp.einsum("etd,edf->etf", x, p["w_in"])
    else:
        h = act(jnp.einsum("etd,edf->etf", x, p["w_in"]))
    return jnp.einsum("etf,efd->etd", h, p["w_out"])


def _route(cfg, x_flat, router_w):
    """x_flat: (T, D). Returns (weights (T,K), ids (T,K), aux_loss)."""
    logits = (x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.moe.top_k
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    e = cfg.moe.num_experts
    me = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(me * ce)
    return weights.astype(x_flat.dtype), ids, aux


def moe_dense(cfg, p, x, *, mode: str = "reference"):
    """All-experts einsum. x: (B, S, D). For reduced smoke configs."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    weights, ids, aux = _route(cfg, xf, p["router"])
    e = cfg.moe.num_experts
    outs = _expert_ffn(cfg, p, jnp.broadcast_to(xf, (e,) + xf.shape),
                       mode)  # (E,T,D)
    gate = jnp.zeros((xf.shape[0], e), x.dtype)
    gate = gate.at[jnp.arange(xf.shape[0])[:, None], ids].add(weights)
    out = jnp.einsum("te,etd->td", gate, outs)
    return out.reshape(b, s, d), aux


def _capacity(tokens_per_shard: int, cfg) -> int:
    c = math.ceil(tokens_per_shard * cfg.moe.top_k * cfg.moe.capacity_factor
                  / cfg.moe.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to sublane multiple


def _bspec(x, mesh, data_axes):
    """Batch-dim spec for shard_map: data axes when divisible, else None —
    the shared divisibility rule (distributed.sharding.divisible_axes)."""
    from repro.distributed.sharding import divisible_axes
    return divisible_axes(x.shape[0], mesh, data_axes or ())


def _gate_collective_mode(mode: str, impl: str, shard) -> str:
    """Capability gate for execution modes under shard_map. Unsafe modes
    fall back to the reference einsum *observably*: a counter plus a plan-
    audit event (§13), never a silent downgrade — the S2 fix for
    moe_ep/moe_tp historically dropping ``mode`` on the floor."""
    if mode in _COLLECTIVE_SAFE_MODES:
        return mode
    obs.incr("moe.collective_mode_fallback")
    obs.plan_decision(
        "collective_mode", f"moe_{impl}", (), "",
        {"mode": "reference", "requested": mode, "shard": shard.describe(),
         "reason": "mode not collective-safe"},
        [{"mode": m} for m in _COLLECTIVE_SAFE_MODES])
    return "reference"


def _prenorm_args(prenorm):
    """Flatten a (scale, bias-or-None) prenorm pair into explicit shard_map
    operands (closures over traced params are unsafe under shard_map) plus
    their replicated in_specs."""
    if prenorm is None:
        return (), ()
    scale, bias = prenorm
    args = (scale,) if bias is None else (scale, bias)
    return args, tuple(P(None) for _ in args)


def _apply_prenorm_args(cfg, t, norm):
    """Re-pair the flattened prenorm operands and apply to local tokens.
    The norm is rowwise, so norming the per-rank slice is bitwise-identical
    to slicing the normed full sequence — safe to push inside shard_map."""
    if not norm:
        return t
    pair = (norm[0], norm[1] if len(norm) > 1 else None)
    return apply_prenorm(cfg, t, pair)


def moe_ep(cfg, p, x, *, mesh, data_axes=("data",), model_axis="model",
           mode: str = "reference", prenorm=None):
    """Expert-parallel MoE. x: (B, S, D) sharded (data, None, None).

    Expert weights are sharded over ``model_axis`` (axis 0 = experts).
    Tokens are sequence-split across ``model_axis`` inside the shard, so each
    device routes S/ep_size of the sequence and the a2a volume per device is
    O(T/ep · D) — the COMET/Switch dispatch pattern.

    ``mode`` routes the per-rank expert FFN through the fused dual-GEMM
    megakernel (full-K policies — bitwise vs the reference einsum); unsafe
    modes fall back observably (``_gate_collective_mode``). ``prenorm`` is
    the block's (scale, bias) norm pair, applied to the per-rank token slice
    inside the shard (sequence-parallel norm: rowwise, so bitwise-identical
    to norm-then-slice).
    """
    from repro.distributed.sharding import ShardSpec

    e = cfg.moe.num_experts
    shard = ShardSpec.for_axis(mesh, model_axis, dim="expert",
                               collective="all_to_all")
    mode = _gate_collective_mode(mode, "ep", shard)
    bspec = _bspec(x, mesh, data_axes)
    norm_args, norm_specs = _prenorm_args(prenorm)
    in_specs = (P(bspec, None, None),                     # x
                P(None, None),                            # router (replicated)
                P(model_axis, None, None),                # w_in
                P(model_axis, None, None),                # w_out
                P(model_axis, None, None)) + norm_specs   # w_gate, norm
    out_specs = (P(bspec, None, None), P())

    has_gate = "w_gate" in p
    w_gate = p["w_gate"] if has_gate else p["w_in"]

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def inner(x, router, w_in, w_out, w_gate, *norm):
        ep = mesh.shape[model_axis]
        rank = jax.lax.axis_index(model_axis)
        bl, s, d = x.shape
        e_loc = e // ep
        seq_split = s % ep == 0 and s >= ep

        if seq_split:
            s_loc = s // ep
            xs = jax.lax.dynamic_slice_in_dim(x, rank * s_loc, s_loc, axis=1)
        else:
            xs = x  # tiny token counts (decode): route replicated
        t = xs.reshape(-1, d)                              # (T, D) local tokens
        t = _apply_prenorm_args(cfg, t, norm)
        weights, ids, aux = _route(cfg, t, router)
        cap = _capacity(t.shape[0], cfg)

        # slot assignment: token-major cumulative position per expert
        k = cfg.moe.top_k
        flat_ids = ids.reshape(-1)                         # (T*K,)
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot          # 1-based
        slot = jnp.sum(pos, axis=1) - 1                    # (T*K,)
        keep = (slot >= 0) & (slot < cap)

        buf = jnp.zeros((e, cap, d), x.dtype)
        tok_idx = jnp.repeat(jnp.arange(t.shape[0]), k)
        buf = buf.at[flat_ids, jnp.clip(slot, 0, cap - 1)].add(
            t[tok_idx] * keep[:, None].astype(x.dtype))

        ew = {"w_in": w_in, "w_out": w_out, "w_gate": w_gate}
        if seq_split:
            # dispatch: (E, C, D) -> (E_loc, ep*C, D) on the expert's owner
            recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                      concat_axis=1, tiled=True)
            out = _expert_ffn(cfg, ew, recv, mode, shard=shard)
            # return: (E_loc, ep*C, D) -> (E, C, D) back on the source rank
            back = jax.lax.all_to_all(out, model_axis, split_axis=1,
                                      concat_axis=0, tiled=True)
        else:
            # replicated dispatch: slice own experts, compute, all_gather
            mine = jax.lax.dynamic_slice_in_dim(buf, rank * e_loc, e_loc, axis=0)
            out = _expert_ffn(cfg, ew, mine, mode, shard=shard)
            back = jax.lax.all_gather(out, model_axis, axis=0, tiled=True)

        # combine: gather each token's k slots, weight, sum
        gathered = back.reshape(e * cap, d)[
            flat_ids * cap + jnp.clip(slot, 0, cap - 1)]
        gathered = gathered * (keep[:, None] * weights.reshape(-1)[:, None]
                               ).astype(x.dtype)
        y = jnp.sum(gathered.reshape(-1, k, d), axis=1)    # (T, D)
        if seq_split:
            ys = y.reshape(bl, s // ep, d)
            full = jax.lax.all_gather(ys, model_axis, axis=1, tiled=True)
        else:
            full = y.reshape(bl, s, d)
        aux = jax.lax.pmean(aux, model_axis)
        aux = jax.lax.pmean(aux, data_axes)
        return full, aux

    return inner(x, p["router"], p["w_in"], p["w_out"], w_gate, *norm_args)


def moe_tp(cfg, p, x, *, mesh, data_axes=("data",), model_axis="model",
           mode: str = "reference", prenorm=None):
    """Megatron-TP MoE: every expert's FFN hidden dim is sharded over the
    model axis; tokens are replicated across it. The block ends with one
    activation psum — the same wire cost as a dense Megatron MLP layer.
    Used when E < |model| (Mixtral's 8 experts on a 16-way axis).

    ``mode``/``prenorm`` as in :func:`moe_ep`: fused per-rank expert FFN
    (full-K, partial over the sharded F — identical psum operands to the
    reference path, so the collective preserves bitwise parity), norm
    applied to the replicated tokens inside the shard.
    """
    from repro.distributed.sharding import ShardSpec

    e = cfg.moe.num_experts
    shard = ShardSpec.for_axis(mesh, model_axis, dim="ffn",
                               collective="all_reduce")
    mode = _gate_collective_mode(mode, "tp", shard)
    bspec = _bspec(x, mesh, data_axes)
    norm_args, norm_specs = _prenorm_args(prenorm)
    in_specs = (P(bspec, None, None),
                P(None, None),
                P(None, None, model_axis),                # w_in: F sharded
                P(None, model_axis, None),                # w_out
                P(None, None, model_axis)) + norm_specs   # w_gate, norm
    out_specs = (P(bspec, None, None), P())
    has_gate = "w_gate" in p
    w_gate = p["w_gate"] if has_gate else p["w_in"]

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def inner(x, router, w_in, w_out, w_gate, *norm):
        bl, s, d = x.shape
        t = x.reshape(-1, d)
        t = _apply_prenorm_args(cfg, t, norm)
        weights, ids, aux = _route(cfg, t, router)
        cap = _capacity(t.shape[0], cfg)
        k = cfg.moe.top_k
        flat_ids = ids.reshape(-1)
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) * onehot
        slot = jnp.sum(pos, axis=1) - 1
        keep = (slot >= 0) & (slot < cap)
        buf = jnp.zeros((e, cap, d), x.dtype)
        tok_idx = jnp.repeat(jnp.arange(t.shape[0]), k)
        buf = buf.at[flat_ids, jnp.clip(slot, 0, cap - 1)].add(
            t[tok_idx] * keep[:, None].astype(x.dtype))

        out = _expert_ffn(cfg, {"w_in": w_in, "w_out": w_out,
                                "w_gate": w_gate}, buf, mode,
                          shard=shard)                    # partial over F
        gathered = out.reshape(e * cap, d)[
            flat_ids * cap + jnp.clip(slot, 0, cap - 1)]
        gathered = gathered * (keep[:, None] * weights.reshape(-1)[:, None]
                               ).astype(x.dtype)
        y = jnp.sum(gathered.reshape(-1, k, d), axis=1)
        y = jax.lax.psum(y, model_axis)                   # Megatron-style AR
        aux = jax.lax.pmean(aux, data_axes)
        return y.reshape(bl, s, d), aux

    return inner(x, p["router"], p["w_in"], p["w_out"], w_gate, *norm_args)


def moe_forward(cfg, p, x, *, mesh=None, data_axes=("data",),
                model_axis="model", mode: str = "reference", prenorm=None):
    """Dispatch between implementations (cfg.moe.impl / mesh availability).

    ``mode`` routes the expert FFN through the fused dual-GEMM epilogue
    kernel on *every* implementation: the shard_map paths (ep/tp) run the
    interpret-safe pallas_call under collective tracing behind the
    ``_COLLECTIVE_SAFE_MODES`` capability gate, with full-K policies so
    fused stays bitwise-equal to the reference oracle (DESIGN.md §16).
    ``prenorm`` is the enclosing block's (scale, bias) norm pair — blocks
    hand the pre-norm residual stream here and the shard_map paths norm the
    per-rank token slice inside the shard.
    """
    impl = cfg.moe.impl
    if impl == "auto":
        if (mesh is None or model_axis not in mesh.axis_names
                or mesh.shape[model_axis] == 1):
            impl = "dense"
        elif (cfg.moe.shard == "expert"
              and cfg.moe.num_experts % mesh.shape[model_axis] == 0):
            impl = "ep"
        else:
            impl = "tp"
    if impl == "ep":
        return moe_ep(cfg, p, x, mesh=mesh, data_axes=data_axes,
                      model_axis=model_axis, mode=mode, prenorm=prenorm)
    if impl == "tp":
        return moe_tp(cfg, p, x, mesh=mesh, data_axes=data_axes,
                      model_axis=model_axis, mode=mode, prenorm=prenorm)
    if prenorm is not None:
        x = apply_prenorm(cfg, x, prenorm)
    return moe_dense(cfg, p, x, mode=mode)
