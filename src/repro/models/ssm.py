"""Mamba2 SSD (state-space duality) block — chunked, sub-quadratic, pure JAX.

Implements the minimal SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
within-chunk quadratic attention-like term + across-chunk linear recurrence.
The paper's attention kernels are inapplicable here (attention-free — see
DESIGN.md §Arch-applicability); the SSD chunk matmuls are GEMM-shaped and
inherit the tile/scheduling treatment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef, rmsnorm


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return d_inner, n_heads, conv_dim, d_in_proj


def ssm_defs(cfg, prefix: str, *, stack: int | None = None) -> dict:
    s = cfg.ssm
    d_inner, n_heads, conv_dim, d_in_proj = ssm_dims(cfg)
    lead = (stack,) if stack else ()
    lx = ("layers",) if stack else ()
    dt = cfg.param_dtype
    return {
        f"{prefix}/in_proj": ParamDef(lead + (cfg.d_model, d_in_proj),
                                      lx + ("embed", "ffn"), dtype=dt),
        f"{prefix}/conv_w": ParamDef(lead + (conv_dim, s.d_conv),
                                     lx + (None, None), scale=1.0, dtype=dt),
        f"{prefix}/conv_b": ParamDef(lead + (conv_dim,), lx + (None,),
                                     init="zeros", dtype=dt),
        f"{prefix}/a_log": ParamDef(lead + (n_heads,), lx + (None,),
                                    init="ones", dtype=dt),
        f"{prefix}/d_skip": ParamDef(lead + (n_heads,), lx + (None,),
                                     init="ones", dtype=dt),
        f"{prefix}/dt_bias": ParamDef(lead + (n_heads,), lx + (None,),
                                      init="zeros", dtype=dt),
        f"{prefix}/norm_scale": ParamDef(lead + (d_inner,), lx + (None,),
                                         init="ones", dtype=dt),
        f"{prefix}/out_proj": ParamDef(lead + (d_inner, cfg.d_model),
                                       lx + ("ffn", "embed"), dtype=dt),
    }


def _segsum(x):
    """x: (..., T) -> (..., T, T) lower-triangular segment sums."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, C); w: (C, K); b: (C,)."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "OIH", "NHC"),
        feature_group_count=w.shape[0])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, a, b_mat, c_mat, chunk: int, initial_state=None):
    """SSD scan. x: (B,L,H,P); a: (B,L,H) log-decay; b/c: (B,L,G,N).

    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l_orig, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    chunk = min(chunk, l_orig)
    pad = (-l_orig) % chunk
    if pad:
        # zero-pad the tail: a=0 (decay exp(0)=1) and x=0 leave the state
        # untouched, so the final state is exact; padded y rows are dropped.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l_orig + pad
    c_ = l // chunk

    def ch(t):  # (B, L, ...) -> (B, C, Q, ...)
        return t.reshape(bsz, c_, chunk, *t.shape[2:])

    xc = ch(x).astype(jnp.float32)
    ac = ch(a).transpose(0, 3, 1, 2).astype(jnp.float32)     # (B,H,C,Q)
    bc = ch(b_mat).astype(jnp.float32)                       # (B,C,Q,G,N)
    cc = ch(c_mat).astype(jnp.float32)

    a_cum = jnp.cumsum(ac, axis=-1)                          # (B,H,C,Q)
    # 1. within-chunk (attention-like) term
    l_mat = jnp.exp(_segsum(ac))                             # (B,H,C,Q,Q)
    bh = jnp.repeat(bc, rep, axis=3)                         # (B,C,Q,H,N)
    chh = jnp.repeat(cc, rep, axis=3)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", chh, bh, l_mat, xc)

    # 2. chunk states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)          # (B,H,C,Q)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", bh, decay_states, xc)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                    # (B,H,C)
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the *previous* state for this chunk

    from repro.util import scan_unroll
    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)), unroll=scan_unroll())
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B,C,H,P,N)

    # 4. state -> output within chunk
    state_decay = jnp.exp(a_cum)                             # (B,H,C,Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", chh, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, l, h, p)[:, :l_orig]
    return y.astype(x.dtype), final


def ssm_forward(cfg, p, x):
    """Full Mamba2 block. x: (B, L, D) -> (B, L, D)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim, _ = ssm_dims(cfg)
    bsz, l, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, b_mat, c_mat = jnp.split(
        xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(bsz, l, n_heads, s.head_dim)
    b_mat = b_mat.reshape(bsz, l, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(bsz, l, s.n_groups, s.d_state)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (H,)

    y, _ = ssd_chunked(xs * dt[..., None].astype(xs.dtype),
                       dt * a[None, None, :], b_mat, c_mat, s.chunk)
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(bsz, l, d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_scale"])
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode path: O(1) per-token state update.
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, n_heads, conv_dim, _ = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode_step(cfg, p, x, cache):
    """x: (B, 1, D). Returns (out (B,1,D), new_cache)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim, _ = ssm_dims(cfg)
    bsz = x.shape[0]

    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    xbc_t = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:]

    xs, b_mat, c_mat = jnp.split(
        xbc_t, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(bsz, n_heads, s.head_dim)
    b_mat = b_mat.reshape(bsz, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(bsz, s.n_groups, s.d_state)
    rep = n_heads // s.n_groups
    bh = jnp.repeat(b_mat, rep, axis=1)                       # (B,H,N)
    chh = jnp.repeat(c_mat, rep, axis=1)

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt_f * a[None, :])                           # (B,H)

    state = cache["state"] * da[:, :, None, None] + \
        jnp.einsum("bh,bhn,bhp->bhpn", dt_f, bh.astype(jnp.float32),
                   xs.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, chh.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, d_inner)
    y = rmsnorm(y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_scale"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "state": state}


def ssm_prefill(cfg, p, x):
    """Full forward that also returns the decode cache at the end of x."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim, _ = ssm_dims(cfg)
    bsz, l, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    conv_tail = xbc[:, -(s.d_conv - 1):, :]
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, b_mat, c_mat = jnp.split(
        xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(bsz, l, n_heads, s.head_dim)
    b_mat = b_mat.reshape(bsz, l, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(bsz, l, s.n_groups, s.d_state)

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, final_state = ssd_chunked(xs * dt_f[..., None].astype(xs.dtype),
                                 dt_f * a[None, None, :], b_mat, c_mat, s.chunk)
    y = y + p["d_skip"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(bsz, l, d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_scale"])
    out = y @ p["out_proj"]
    cache = {"conv": conv_tail, "state": final_state}
    return out, cache
