"""Public attention op with custom VJP through the Pallas kernels.

``attention(q, k, v, causal=..., window=..., mode=...)``:
  * mode="reference"        — jnp softmax attention, jax autodiff (dry-run path)
  * mode="pallas_interpret" — flash fwd/bwd kernels, interpret=True
  * mode="pallas_tpu"       — same kernels lowered for TPU

Policy resolution order (DESIGN.md §5): explicit ``policy``/``bwd_policy`` >
legacy ``block_q``/``block_kv`` keywords (deprecation shim) > the analytic
autotuner, which resolves fwd and bwd policies independently (the bwd pass
has a larger scratch working set and may legally need smaller tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.policy import (KernelPolicy, legacy_attention_blocks,
                               resolve_policy)
from .kernel_fwd import flash_attention_fwd
from .kernel_bwd import flash_attention_bwd
from .ref import attention_ref, attention_ref_chunked

# above this KV length, 'reference' mode switches to the chunked
# online-softmax scan so temps stay O(S·chunk) instead of O(S^2)
_CHUNKED_THRESHOLD = 2048


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, policy, bwd_policy, logit_scale,
           interpret):
    out, _ = flash_attention_fwd(
        q, k, v, policy=policy, causal=causal, window=window,
        logit_scale=logit_scale, interpret=interpret)
    return out


def _flash_fwd(q, k, v, causal, window, policy, bwd_policy, logit_scale,
               interpret):
    out, lse = flash_attention_fwd(
        q, k, v, policy=policy, causal=causal, window=window,
        logit_scale=logit_scale, interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, policy, bwd_policy, logit_scale, interpret,
               res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, policy=bwd_policy, causal=causal,
        window=window, logit_scale=logit_scale, interpret=interpret)
    h, hkv = q.shape[1], k.shape[1]
    if h != hkv:  # GQA: reduce per-query-head dk/dv over the group
        group = h // hkv
        b, _, skv, d = dk.shape
        dk = dk.reshape(b, hkv, group, skv, d).sum(axis=2)
        dv = dv.reshape(b, hkv, group, skv, d).sum(axis=2)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def resolve_attention_policies(q_shape, kv_shape, dtype, *,
                               causal: bool = False) -> tuple:
    """(fwd, bwd) tuned policies for a (B,H,Sq,D) x (B,Hkv,Skv,D) launch."""
    b, h, sq, d = q_shape
    skv = kv_shape[2]
    sig = (b, h, sq, skv, d)
    fwd = autotune.select_policy("attention_fwd", sig, str(dtype),
                                 causal=causal)
    bwd = autotune.select_policy("attention_bwd", sig, str(dtype),
                                 causal=causal)
    return fwd, bwd


def attention(q, k, v, *, causal: bool = False, window: int | None = None,
              policy: KernelPolicy | None = None,
              bwd_policy: KernelPolicy | None = None,
              block_q: int | None = None, block_kv: int | None = None,
              logit_scale: float | None = None,
              mode: str = "pallas_interpret"):
    """Multi-/grouped-query flash attention. q:(B,H,S,D), k/v:(B,Hkv,S,D)."""
    if mode == "reference":
        if k.shape[2] > _CHUNKED_THRESHOLD:
            return attention_ref_chunked(q, k, v, causal=causal,
                                         window=window,
                                         logit_scale=logit_scale)
        return attention_ref(q, k, v, causal=causal, window=window,
                             logit_scale=logit_scale)
    if policy is None:
        b, h, sq, d = q.shape
        skv = k.shape[2]
        legacy = legacy_attention_blocks(block_q, block_kv, sq, skv, d)
        if legacy is not None:
            # legacy keyword surface -> explicit policy (deprecation shim)
            sig = (b, h, sq, skv, d)
            policy = resolve_policy("attention_fwd", sig, q.dtype,
                                    causal=causal, legacy_blocks=legacy,
                                    warn_what="attention")
            bwd_policy = bwd_policy or resolve_policy(
                "attention_bwd", sig, q.dtype, causal=causal,
                legacy_blocks=legacy, warn_what="attention")
        else:
            policy, auto_bwd = resolve_attention_policies(
                q.shape, k.shape, q.dtype, causal=causal)
            bwd_policy = bwd_policy or auto_bwd
    elif bwd_policy is None:
        _, bwd_policy = resolve_attention_policies(
            q.shape, k.shape, q.dtype, causal=causal)
    return _flash(q, k, v, causal, window, policy, bwd_policy, logit_scale,
                  mode == "pallas_interpret")
