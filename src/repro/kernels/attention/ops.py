"""Public attention op with custom VJP through the Pallas kernels.

``attention(q, k, v, causal=..., window=..., mode=...)``:
  * mode="reference"        — jnp softmax attention, jax autodiff (dry-run path)
  * mode="pallas_interpret" — flash fwd/bwd kernels, interpret=True
  * mode="pallas_tpu"       — same kernels lowered for TPU

Policy resolution order (DESIGN.md §5): explicit ``policy``/``bwd_policy`` >
legacy ``block_q``/``block_kv`` keywords (deprecation shim) > the analytic
autotuner, which resolves fwd and bwd policies independently (the bwd pass
has a larger scratch working set and may legally need smaller tiles).

Attention epilogue chains (DESIGN.md §12): ``softcap``/``sinks`` build an
:class:`~repro.kernels.attention.epilogue.AttnEpilogue` that rides the
resolved policy (and its autotune bucket). The fused-vs-unfused decision is
a real plan: ``autotune.select_fusion("attention", ...)`` scores the flash
chain against the eager score-matrix chain from modeled ``dma_bytes``, the
same protocol every GEMM-side fusion uses. The sink operand is a
*differentiable* input — ``_flash``'s VJP returns dsink alongside dq/dk/dv
(a jnp reduction over the saved (out, lse) residuals; the kernels never
see a sink gradient because the fwd folded the sink mass into lse).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import autotune
from repro.core.policy import (KernelPolicy, legacy_attention_blocks,
                               make_policy, resolve_policy)
from .epilogue import AttnEpilogue
from .kernel_fwd import flash_attention_fwd
from .kernel_bwd import flash_attention_bwd
from .kernel_decode import flash_decode, flash_decode_paged
from .ref import attention_ref, attention_ref_chunked, decode_ref

# above this KV length, 'reference' mode switches to the chunked
# online-softmax scan so temps stay O(S·chunk) instead of O(S^2)
_CHUNKED_THRESHOLD = 2048


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10))
def _flash(q, k, v, sinks, causal, window, policy, bwd_policy, logit_scale,
           epilogue, interpret):
    out, _ = flash_attention_fwd(
        q, k, v, policy=policy, causal=causal, window=window,
        logit_scale=logit_scale, epilogue=epilogue, sinks=sinks,
        interpret=interpret)
    return out


def _flash_fwd(q, k, v, sinks, causal, window, policy, bwd_policy,
               logit_scale, epilogue, interpret):
    out, lse = flash_attention_fwd(
        q, k, v, policy=policy, causal=causal, window=window,
        logit_scale=logit_scale, epilogue=epilogue, sinks=sinks,
        interpret=interpret)
    # saved-preact convention: (out, lse) are the only residuals — lse
    # already contains the sink mass, softcap recomputes in-kernel
    return out, (q, k, v, sinks, out, lse)


def _flash_bwd(causal, window, policy, bwd_policy, logit_scale, epilogue,
               interpret, res, do):
    q, k, v, sinks, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, policy=bwd_policy, causal=causal,
        window=window, logit_scale=logit_scale, epilogue=epilogue,
        interpret=interpret)
    h, hkv = q.shape[1], k.shape[1]
    if h != hkv:  # GQA: reduce per-query-head dk/dv over the group
        group = h // hkv
        b, _, skv, d = dk.shape
        dk = dk.reshape(b, hkv, group, skv, d).sum(axis=2)
        dv = dv.reshape(b, hkv, group, skv, d).sum(axis=2)
    dsinks = None
    if sinks is not None:
        dsinks = epilogue.operand_grads(do, out, lse, sinks=sinks)["sinks"]
        dsinks = dsinks.astype(sinks.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype), dsinks


_flash.defvjp(_flash_fwd, _flash_bwd)


def resolve_attention_policies(q_shape, kv_shape, dtype, *,
                               causal: bool = False,
                               epilogue: AttnEpilogue | None = None) -> tuple:
    """(fwd, bwd) tuned policies for a (B,H,Sq,D) x (B,Hkv,Skv,D) launch.

    A non-identity ``epilogue`` joins the autotune signature (its streamed
    operands count in the VMEM legality rule and its extra reads in the
    traffic score) and rides the returned policies' epilogue field.
    """
    b, h, sq, d = q_shape
    skv = kv_shape[2]
    sig = (b, h, sq, skv, d)
    ep = epilogue if epilogue is not None and not epilogue.is_identity else None
    fwd = autotune.select_policy("attention_fwd", sig, str(dtype),
                                 causal=causal, epilogue=ep)
    bwd = autotune.select_policy("attention_bwd", sig, str(dtype),
                                 causal=causal, epilogue=ep)
    return fwd, bwd


def attention(q, k, v, *, causal: bool = False, window: int | None = None,
              policy: KernelPolicy | None = None,
              bwd_policy: KernelPolicy | None = None,
              block_q: int | None = None, block_kv: int | None = None,
              logit_scale: float | None = None,
              softcap: float | None = None, sinks=None,
              mode: str = "pallas_interpret"):
    """Multi-/grouped-query flash attention. q:(B,H,S,D), k/v:(B,Hkv,S,D).

    ``softcap``: gemma2-style tanh logit cap (configs/base.py
    ``attn_logit_softcap``), applied inside the kernels' softmax loop.
    ``sinks``: optional (H,) per-head attention-sink logits (differentiable
    — grads flow to them like any other operand). Both stages form the
    fused :class:`AttnEpilogue` store chain; reference mode applies the
    identical math in jnp.
    """
    epilogue = AttnEpilogue(softcap=float(softcap) if softcap else 0.0,
                            sink=sinks is not None)
    if mode == "reference":
        if k.shape[2] > _CHUNKED_THRESHOLD:
            return attention_ref_chunked(q, k, v, causal=causal,
                                         window=window,
                                         logit_scale=logit_scale,
                                         softcap=softcap, sinks=sinks)
        return attention_ref(q, k, v, causal=causal, window=window,
                             logit_scale=logit_scale, softcap=softcap,
                             sinks=sinks)
    if policy is None:
        b, h, sq, d = q.shape
        skv = k.shape[2]
        legacy = legacy_attention_blocks(block_q, block_kv, sq, skv, d)
        if legacy is not None:
            # legacy keyword surface -> explicit policy (deprecation shim)
            sig = (b, h, sq, skv, d)
            policy = resolve_policy("attention_fwd", sig, q.dtype,
                                    causal=causal, legacy_blocks=legacy,
                                    warn_what="attention")
            bwd_policy = bwd_policy or resolve_policy(
                "attention_bwd", sig, q.dtype, causal=causal,
                legacy_blocks=legacy, warn_what="attention")
        else:
            # plan decision: the flash chain vs the eager score-matrix
            # chain, from modeled dma_bytes — same protocol as the
            # mlp/qkv_rope plans (memoized per shape bucket)
            hkv = k.shape[1]
            plan = autotune.select_fusion(
                "attention", (b, h, hkv, sq, skv, d), str(q.dtype),
                causal=causal, softcap=bool(epilogue.softcap),
                sink=epilogue.sink)
            if plan["plan"] != "fused":
                # modeled traffic favors the eager chain (never at real
                # shapes — the flash chain strictly dominates — but the
                # plan, not the call site, owns that decision)
                return attention_ref(q, k, v, causal=causal, window=window,
                                     logit_scale=logit_scale,
                                     softcap=softcap, sinks=sinks)
            policy, auto_bwd = resolve_attention_policies(
                q.shape, k.shape, q.dtype, causal=causal, epilogue=epilogue)
            bwd_policy = bwd_policy or auto_bwd
    elif bwd_policy is None:
        _, bwd_policy = resolve_attention_policies(
            q.shape, k.shape, q.dtype, causal=causal, epilogue=epilogue)
    return _flash(q, k, v, sinks, causal, window, policy, bwd_policy,
                  logit_scale, epilogue, mode == "pallas_interpret")


# ---------------------------------------------------------------------------
# Decode path (q_len = 1): split-KV flash-decode + paged-attention variant.
# ---------------------------------------------------------------------------

def resolve_decode_policy(batch: int, kv_heads: int, group: int, kv_len: int,
                          head_dim: int, dtype, *,
                          page_size: int | None = None,
                          epilogue: AttnEpilogue | None = None,
                          q_tokens: int = 1) -> KernelPolicy:
    """The decode policy for a launch signature (DESIGN.md §5 / §8).

    Contiguous caches go through the autotuner (the split size is the one
    free axis of the bandwidth-dominated model). Paged caches have their
    split size fixed by the physical page (one page per grid step by
    construction), so the policy is built directly — deterministically, so
    an engine's pinned policy and the traced policy are the same object
    semantics as the autotuner's memoized path. A non-identity ``epilogue``
    rides the policy for reporting (decode's sink stage lives in the jnp
    LSE combine, so it never affects decode VMEM legality).
    """
    ep = epilogue if epilogue is not None and not epilogue.is_identity else None
    if page_size is None:
        return autotune.select_policy(
            "attention_decode", (batch, kv_heads, group, kv_len, head_dim),
            str(dtype), epilogue=ep)
    # q tile rows = GQA group × verify tokens (q_tokens > 1 is the
    # speculative verify step — same paged split, taller q tile)
    pol = make_policy("attention_decode", block_m=group * q_tokens,
                      block_n=page_size, block_k=head_dim,
                      in_dtype=str(jnp.dtype(dtype)),
                      name="paged" if q_tokens == 1 else f"paged_q{q_tokens}",
                      epilogue=ep)
    pol.check()
    return pol


def attention_decode(q, k, v, lengths, *, window: int | None = None,
                     policy: KernelPolicy | None = None,
                     logit_scale: float | None = None,
                     softcap: float | None = None, sinks=None,
                     mode: str = "pallas_interpret"):
    """Single-token decode attention over a contiguous (ring) KV cache.

    q: (B, H, 1, D) with H % Hkv == 0; k/v: (B, Hkv, S, D);
    ``lengths``: scalar or (B,) int32 — tokens written so far (ring
    semantics when lengths > S). ``softcap``/``sinks`` follow
    :func:`attention` (sinks is (H,), per query head). Returns
    (B, H, 1, D) in q.dtype.

    mode="reference" is the jnp einsum oracle (the pre-subsystem decode
    path, bitwise); the pallas modes run the split-KV kernel whose split
    size comes from the resolved ``attention_decode`` policy.
    """
    b, h, _, d = q.shape
    hkv, slots = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, hkv, group, d)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    if mode == "reference":
        out = decode_ref(qg, k, v, lengths, window=window,
                         logit_scale=logit_scale, softcap=softcap,
                         sinks=sinks)
    else:
        if policy is None:
            epilogue = AttnEpilogue(
                softcap=float(softcap) if softcap else 0.0,
                sink=sinks is not None)
            policy = resolve_decode_policy(b, hkv, group, slots, d, q.dtype,
                                           epilogue=epilogue)
        if obs.enabled():
            sig = autotune.OpSignature("attention_decode",
                                       (b, hkv, group, slots, d),
                                       str(q.dtype), epilogue=policy.epilogue)
            obs.launch("attention_decode",
                       grid=(b, hkv, max(1, slots // policy.block_kv)),
                       policy=policy,
                       dma_bytes=autotune.score_policy(sig, policy).dma_bytes,
                       flops=4 * b * h * slots * d)
        out = flash_decode(qg, k, v, lengths, policy=policy, window=window,
                           logit_scale=logit_scale,
                           softcap=float(softcap) if softcap else 0.0,
                           sinks=sinks,
                           interpret=mode == "pallas_interpret")
    return out.reshape(b, h, 1, d)


def attention_decode_paged(q, k_pages, v_pages, page_table, lengths, *,
                           window: int | None = None,
                           policy: KernelPolicy | None = None,
                           logit_scale: float | None = None,
                           softcap: float | None = None, sinks=None,
                           mode: str = "pallas_interpret"):
    """Decode attention (1 or T query tokens) over a paged KV pool.

    q: (B, H, T, D) — T == 1 is plain decode; T > 1 is the speculative
    verify step, where token t of sequence b sits at absolute position
    ``lengths[b] - T + t`` (i.e. ``lengths`` counts the KV *including* the
    T verify tokens already appended). k_pages/v_pages:
    (P, Hkv, page_size, D); page_table: (B, MP) physical page ids (0 =
    reserved null page); lengths: (B,). ``softcap``/``sinks`` follow
    :func:`attention`. Returns (B, H, T, D) in q.dtype. mode="reference"
    gathers the pages into a contiguous view and runs the einsum oracle.
    """
    b, h, t, d = q.shape
    hkv, page_size = k_pages.shape[1], k_pages.shape[2]
    mp = page_table.shape[1]
    group = h // hkv
    if t == 1:
        qg = q.reshape(b, hkv, group, d)
    else:
        # pack verify tokens group-major: row = g*T + t
        qg = q.reshape(b, hkv, group, t, d).reshape(b, hkv, group * t, d)
        if sinks is not None:
            sinks = jnp.repeat(jnp.asarray(sinks).reshape(hkv, group), t,
                               axis=1).reshape(-1)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (b,))
    if mode == "reference":
        # function-level import: serve sits above kernels in the layering
        from repro.serve.kv_cache import gather_pages

        out = decode_ref(qg, gather_pages(k_pages, page_table),
                         gather_pages(v_pages, page_table), lengths,
                         window=window, logit_scale=logit_scale,
                         softcap=softcap, sinks=sinks, q_tokens=t)
    else:
        if policy is None:
            epilogue = AttnEpilogue(
                softcap=float(softcap) if softcap else 0.0,
                sink=sinks is not None)
            policy = resolve_decode_policy(b, hkv, group, mp * page_size, d,
                                           q.dtype, page_size=page_size,
                                           epilogue=epilogue, q_tokens=t)
        if obs.enabled():
            sig = autotune.OpSignature("attention_decode",
                                       (b, hkv, group * t, mp * page_size, d),
                                       str(q.dtype), epilogue=policy.epilogue)
            obs.launch("attention_decode", variant="paged",
                       grid=(b, hkv, mp), policy=policy,
                       dma_bytes=autotune.score_policy(sig, policy).dma_bytes,
                       flops=4 * b * h * t * mp * page_size * d)
        out = flash_decode_paged(qg, k_pages, v_pages, page_table, lengths,
                                 policy=policy, window=window,
                                 logit_scale=logit_scale,
                                 softcap=float(softcap) if softcap else 0.0,
                                 sinks=sinks,
                                 interpret=mode == "pallas_interpret",
                                 q_tokens=t)
    return out.reshape(b, h, t, d)
