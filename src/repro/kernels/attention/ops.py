"""Public attention op with custom VJP through the Pallas kernels.

``attention(q, k, v, causal=..., window=..., mode=...)``:
  * mode="reference"        — jnp softmax attention, jax autodiff (dry-run path)
  * mode="pallas_interpret" — flash fwd/bwd kernels, interpret=True
  * mode="pallas_tpu"       — same kernels lowered for TPU
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel_fwd import flash_attention_fwd
from .kernel_bwd import flash_attention_bwd
from .ref import attention_ref, attention_ref_chunked

# above this KV length, 'reference' mode switches to the chunked
# online-softmax scan so temps stay O(S·chunk) instead of O(S^2)
_CHUNKED_THRESHOLD = 2048


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, block_q, block_kv, logit_scale, interpret):
    out, _ = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, logit_scale=logit_scale, interpret=interpret)
    return out


def _flash_fwd(q, k, v, causal, window, block_q, block_kv, logit_scale, interpret):
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, logit_scale=logit_scale, interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, block_kv, logit_scale, interpret,
               res, do):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, logit_scale=logit_scale, interpret=interpret)
    h, hkv = q.shape[1], k.shape[1]
    if h != hkv:  # GQA: reduce per-query-head dk/dv over the group
        group = h // hkv
        b, _, skv, d = dk.shape
        dk = dk.reshape(b, hkv, group, skv, d).sum(axis=2)
        dv = dv.reshape(b, hkv, group, skv, d).sum(axis=2)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q, k, v, *, causal: bool = False, window: int | None = None,
              block_q: int = 128, block_kv: int = 128,
              logit_scale: float | None = None,
              mode: str = "pallas_interpret"):
    """Multi-/grouped-query flash attention. q:(B,H,S,D), k/v:(B,Hkv,S,D)."""
    if mode == "reference":
        if k.shape[2] > _CHUNKED_THRESHOLD:
            return attention_ref_chunked(q, k, v, causal=causal,
                                         window=window,
                                         logit_scale=logit_scale)
        return attention_ref(q, k, v, causal=causal, window=window,
                             logit_scale=logit_scale)
    return _flash(q, k, v, causal, window, block_q, block_kv, logit_scale,
                  mode == "pallas_interpret")
