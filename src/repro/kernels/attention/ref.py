"""Pure-jnp oracles for MHA/GQA attention (fwd; bwd via jax autodiff).

Two implementations:
  * :func:`attention_ref` — direct (S_q, S_kv) einsum; the ground truth for
    kernel tests at small S.
  * :func:`attention_ref_chunked` — online-softmax lax.scan over KV chunks
    with per-chunk remat. O(S·chunk) memory, so 32k-prefill lowers with
    bounded temps; this is what 'reference' mode uses at long S (it is the
    flash algorithm expressed in XLA, which is also the honest non-Pallas
    baseline for the benchmarks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .epilogue import cap_logits, softmax_finalize


def attention_ref(q, k, v, *, causal: bool = False, window: int | None = None,
                  logit_scale: float | None = None,
                  softcap: float | None = None, sinks=None):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D) with H % Hkv == 0.

    ``window``: sliding-window size — position i attends to j iff
    i - j < window (combined with the causal mask when causal=True).
    ``softcap``: gemma2-style tanh logit cap on the scaled logits.
    ``sinks``: optional (H,) per-head attention-sink logits that join the
    softmax denominator only (DESIGN.md §12).
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    scale = logit_scale if logit_scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = cap_logits(s, softcap)
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    if sinks is not None:
        sb = jnp.asarray(sinks, jnp.float32)[None, :, None, None]
        acc = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
        out, _ = softmax_finalize(acc, m, l, sink=sb)
        return out.astype(q.dtype)
    p = p / jnp.maximum(l, 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ring_positions(lengths, slots: int):
    """Per-slot absolute positions and validity of a ring-buffer KV cache.

    ``lengths``: (B,) int32 — tokens written so far per sequence (the cache
    holds the last ``slots`` of them at slot = pos % slots; a dense cache is
    the special case lengths <= slots). Returns (actual, valid), both
    (B, slots): ``actual[b, j]`` is the absolute position stored in slot j
    and ``valid[b, j]`` is False for never-written slots (including the
    whole row when lengths[b] == 0).
    """
    lengths = jnp.asarray(lengths, jnp.int32)
    pos = lengths[:, None] - 1                      # last written position
    cur = jnp.mod(pos, slots)                       # its slot
    i = jnp.arange(slots)[None, :]
    actual = jnp.where(i <= cur, pos - cur + i, pos - cur - slots + i)
    valid = (actual >= 0) & (actual <= pos)
    return actual, valid


def decode_ref(q, k, v, lengths, *, window: int | None = None,
               logit_scale: float | None = None,
               softcap: float | None = None, sinks=None,
               q_tokens: int = 1):
    """Decode oracle (1 or T query tokens) over a (possibly ring) KV cache.

    q: (B, Hkv, G, D) — the GQA group packed into the q rows (G = H // Hkv;
    MHA is G == 1 with Hkv == H). k, v: (B, Hkv, S, D) ring cache;
    ``lengths``: (B,) tokens written so far. ``softcap``/``sinks`` follow
    :func:`attention_ref` (sinks is (H,), per query head). Returns
    (B, Hkv, G, D) in q.dtype. Matches the pre-subsystem einsum decode path
    bitwise for non-empty sequences; empty rows (lengths == 0) return zeros
    (with a sink, all mass lands on the sink, which attends to nothing).

    ``q_tokens`` > 1 (speculative verify): G packs group * T rows
    group-major (row = g*T + t); row t's causal horizon is position
    ``lengths - T + t``, matching the paged kernel's verify mask.
    """
    b, hkv, g, d = q.shape
    slots = k.shape[2]
    actual, valid = ring_positions(lengths, slots)
    if q_tokens == 1:
        if window is not None:
            pos = jnp.asarray(lengths, jnp.int32)[:, None] - 1
            valid &= (pos - actual) < window
        vmask = valid[:, None, None, :]
    else:
        row_t = jnp.arange(g) % q_tokens                        # (X,)
        pos_row = (jnp.asarray(lengths, jnp.int32)[:, None]
                   - q_tokens + row_t[None, :])                 # (B, X)
        vmask = valid[:, None, None, :] & (
            actual[:, None, None, :] <= pos_row[:, None, :, None])
        if window is not None:
            vmask &= (pos_row[:, None, :, None]
                      - actual[:, None, None, :]) < window
    scale = logit_scale if logit_scale is not None else d ** -0.5
    s = jnp.einsum("bgxd,bgkd->bgxk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = cap_logits(s, softcap)
    # -1e30 (not -inf) so fully-masked rows stay NaN-free; for rows with at
    # least one valid slot exp(-1e30 - max) underflows to exactly 0.0, so
    # the result is bitwise identical to -inf masking.
    s = jnp.where(vmask, s, -1e30)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    if sinks is not None:
        sb = jnp.asarray(sinks, jnp.float32).reshape(hkv, g)[None, :, :, None]
        pmax = jnp.maximum(pmax, sb)
    pexp = jnp.exp(s - pmax)
    pexp = jnp.where(vmask, pexp, 0.0)
    den = jnp.sum(pexp, axis=-1, keepdims=True)
    if sinks is not None:
        den = den + jnp.exp(sb - pmax)
    out = jnp.einsum("bgxk,bgkd->bgxd", pexp / jnp.maximum(den, 1e-30),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_ref_chunked(q, k, v, *, causal: bool = False,
                          window: int | None = None,
                          logit_scale: float | None = None,
                          softcap: float | None = None, sinks=None,
                          chunk: int = 1024):
    """Online-softmax over KV chunks (flash algorithm in pure XLA).

    ``softcap``/``sinks`` follow :func:`attention_ref`; the sink folds into
    the final rescale exactly like the flash kernel's store epilogue.
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    chunk = min(chunk, skv)
    while skv % chunk:      # e.g. the VLM's 32512-token prefill
        chunk //= 2
    nc = skv // chunk
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qf = q.astype(jnp.float32).reshape(b, hkv, group, sq, d)
    ks = k.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)[:, None]

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        m, l, acc = carry
        kc, vc, ci = inp
        s = jnp.einsum("bgxqd,bgcd->bgxqc", qf, kc.astype(jnp.float32)) * scale
        if softcap:
            s = cap_logits(s, softcap)
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bgxqc,bgcd->bgxqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    from repro.util import scan_unroll
    m0 = jnp.full((b, hkv, group, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nc)),
                                  unroll=scan_unroll())
    if sinks is not None:
        sb = jnp.asarray(sinks, jnp.float32).reshape(
            hkv, group)[None, :, :, None, None]
        out, _ = softmax_finalize(acc, m, l, sink=sb)
    else:
        out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, sq, d).astype(q.dtype)
