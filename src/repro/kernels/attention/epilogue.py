"""Declarative epilogue chains for the flash-attention family (DESIGN.md §12).

The GEMM megakernel grew a full chain-spec subsystem (Epilogue / Prologue /
transpose rules, DESIGN.md §9-§11); this module ports the same protocol onto
the attention kernels, where the paper's headline wins live (d=64 attention
and GQA backwards, Fig. 7). An :class:`AttnEpilogue` is a frozen, hashable
(jit-static) spec of the attention-adjacent stages that run *inside* the
flash kernels instead of round-tripping the (Sq, Skv) score matrix or the
output through HBM:

  * ``softcap`` — gemma2-style logit soft cap ``s = cap * tanh(s / cap)``,
    applied to the scaled logits inside the online-softmax loop (before
    masking), in the forward, backward and split-KV decode kernels alike.
    Its backward is recompute-style: the raw logits are re-derived from the
    streamed q/k tiles and the capped-grad factor ``1 - tanh²(s/cap)``
    modulates ds in-kernel — nothing extra is saved.
  * ``sink`` — a per-head attention-sink logit that joins the softmax
    *denominator only* (gpt-oss / StreamingLLM style): the sink absorbs
    probability mass but attends to no value row. It folds into the final
    LSE combine at the store (see :func:`softmax_finalize` for why the
    combine changes), streams one f32 scalar per head, and its gradient is
    a cheap jnp reduction over the saved ``(lse, delta)`` residuals.

Saved-preact convention for attention (the analogue of the GEMM chain's
saved accumulators, consumed by ``perf_model.attention_chain_bwd_model``):
the forward stores ``(out, lse)`` and nothing else. Both stages keep that
invariant — softcap recomputes, and the sink's mass is already *inside*
lse — so ``select_fusion(backward=True)`` can score a whole transformer
block from the same two residual streams.

Like the GEMM chain, the same stage code runs on VMEM tiles in the Pallas
kernels and on full jnp arrays in the oracles (every stage is elementwise
or a row-broadcast), so tile-wise application is exact.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def cap_logits(s, softcap: float):
    """gemma2-style logit soft cap: ``cap * tanh(s / cap)`` (identity when
    softcap is 0/None). Applied to the *scaled* logits, before masking, so
    the mask value never flows through tanh."""
    if not softcap:
        return s
    return softcap * jnp.tanh(s / softcap)


def cap_grad(s_raw, softcap: float):
    """d cap_logits / d s at the raw logits: ``1 - tanh²(s/cap)``."""
    t = jnp.tanh(s_raw / softcap)
    return 1.0 - t * t


def softmax_finalize(acc, m, l, sink=None):
    """(out, lse) from online-softmax state — the flash store epilogue.

    acc: unnormalized output (rows, d); m/l: running max/sum (rows, 1)
    (any broadcast-compatible shapes work — the oracles call this on full
    arrays). With a ``sink`` logit the combine changes: the sink enters the
    running max (``m_tot = max(m, sink)``) *before* the denominator is
    formed, because ``exp(sink - m)`` overflows when every KV block of a
    row was masked (m is still MASK_VALUE); re-anchoring at m_tot keeps
    the all-masked row exact (out = 0, lse = sink — all mass on the sink,
    which attends to nothing). Without a sink this is the classic
    ``acc / l`` store with the l == 0 guard.
    """
    if sink is not None:
        m_tot = jnp.maximum(m, sink)
        alpha = jnp.exp(m - m_tot)
        l_tot = l * alpha + jnp.exp(sink - m_tot)
        return acc * (alpha / l_tot), m_tot + jnp.log(l_tot)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    return acc / l_safe, m + jnp.log(l_safe)


@dataclasses.dataclass(frozen=True)
class AttnEpilogue:
    """A frozen, hashable attention epilogue spec (jit-static by construction).

    ``softcap``: tanh logit cap (0.0 = off). ``sink``: stream a per-head
    sink logit into the softmax denominator.
    """

    softcap: float = 0.0
    sink: bool = False

    def __post_init__(self):
        if self.softcap < 0.0 or self.softcap != self.softcap:  # NaN guard
            raise ValueError(f"softcap must be >= 0, got {self.softcap}")

    # -- identity / shape of the chain -------------------------------------
    @property
    def is_identity(self) -> bool:
        return not (self.softcap or self.sink)

    def operand_names(self) -> tuple:
        """Runtime extra operands, in the canonical kernel order."""
        return ("sinks",) if self.sink else ()

    # -- VMEM legality accounting (consumed by KernelPolicy) ----------------
    def extra_operand_blocks(self, block_q: int, block_kv: int,
                             head_dim: int, in_dtype: str) -> list:
        """(shape, dtype) of each extra pipelined block. The sink streams a
        single f32 scalar per (head, q-block) grid cell; softcap streams
        nothing (pure vector work on resident tiles)."""
        del block_q, block_kv, head_dim, in_dtype
        return [((1, 1), "float32")] if self.sink else []

    def check_blocks(self, block_q: int, block_kv: int) -> None:
        """Raise on block shapes the chain cannot legally tile. Neither
        stage constrains the tiling (both are row-local), so this exists
        for protocol symmetry with the GEMM Epilogue."""
        del block_q, block_kv

    # -- modeled HBM traffic of the extra streamed operands -----------------
    def extra_read_bytes(self, n_heads: int) -> int:
        """Bytes the fused kernel reads beyond q/k/v and the out/lse store."""
        return 4 * n_heads if self.sink else 0

    # -- the chain itself ---------------------------------------------------
    def apply_logits(self, s):
        """The in-loop stage: soft-cap the scaled logits (pre-mask). Exact
        on a VMEM tile and on the full (Sq, Skv) score matrix alike."""
        return cap_logits(s, self.softcap)

    def finalize(self, acc, m, l, sink=None):
        """The store stage: online-softmax state -> (out, lse), with the
        sink folded into the LSE combine (see :func:`softmax_finalize`)."""
        return softmax_finalize(acc, m, l, sink=sink if self.sink else None)

    # -- the chain transpose (saved-preact convention, DESIGN.md §12) -------
    @property
    def needs_saved_preact(self) -> bool:
        """Always False: attention's saved residuals are (out, lse) and the
        chain keeps it that way — softcap recomputes the raw logits from
        the streamed q/k tiles, and the sink mass is already inside lse."""
        return False

    @property
    def saved_accumulators(self) -> int:
        return 0

    def saved_residual_bytes(self, batch: int, heads: int, seq_q: int,
                             head_dim: int, dtype_bytes: int) -> int:
        """Bytes of the (out, lse) residuals the fwd saves for the bwd —
        the attention saved-preact convention the chain models charge."""
        return batch * heads * seq_q * (head_dim * dtype_bytes + 4)

    def grad_factor(self, s_raw):
        """ds modulation of the softcap stage at the raw logits (identity
        when softcap is off) — applied in-kernel by the bwd passes."""
        if not self.softcap:
            return None
        return cap_grad(s_raw, self.softcap)

    def operand_grads(self, do, out, lse, *, sinks=None) -> dict:
        """Cotangents of the chain's extra operands (jnp, full arrays).

        dsink[h] = -Σ_{b,q} exp(sink[h] - lse[b,h,q]) * delta[b,h,q] with
        delta = rowsum(dO·O): the sink only scales the denominator, so its
        gradient reuses the same delta reduction the kernel bwd streams.
        """
        grads = {}
        if self.sink and sinks is not None:
            delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                            axis=-1)                       # (B, H, Sq)
            w = jnp.exp(sinks.astype(jnp.float32)[None, :, None]
                        - lse.astype(jnp.float32))         # (B, H, Sq)
            grads["sinks"] = -jnp.sum(w * delta, axis=(0, 2))
        return grads

    def describe(self) -> str:
        """Short tag for reports/benchmark rows, e.g. 'softcap30+sink'."""
        if self.is_identity:
            return "none"
        parts = []
        if self.softcap:
            parts.append(f"softcap{self.softcap:g}")
        if self.sink:
            parts.append("sink")
        return "+".join(parts)


ATTN_EPILOGUE_NONE = AttnEpilogue()
