from .ops import attention  # noqa: F401
from .ref import attention_ref  # noqa: F401
from .kernel_fwd import flash_attention_fwd  # noqa: F401
from .kernel_bwd import flash_attention_bwd  # noqa: F401
