from .epilogue import (ATTN_EPILOGUE_NONE, AttnEpilogue,  # noqa: F401
                       cap_logits, softmax_finalize)
from .ops import (attention, attention_decode, attention_decode_paged,  # noqa: F401
                  resolve_attention_policies, resolve_decode_policy)
from .ref import attention_ref, decode_ref, ring_positions  # noqa: F401
from .kernel_fwd import flash_attention_fwd  # noqa: F401
from .kernel_bwd import flash_attention_bwd  # noqa: F401
from .kernel_decode import (combine_splits, flash_decode,  # noqa: F401
                            flash_decode_paged)
