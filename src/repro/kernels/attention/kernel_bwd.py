"""Flash-attention backward Pallas kernels (paper Fig. 8 / §4.3), TPU-adapted.

The paper's attention-backward is its most register-pressured kernel, using
mixed MFMA shapes, row- *and* column-layout shared-memory reads and pinned
AGPR tiles (Tab. 1). The TPU instantiation splits the work the standard
flash-bwd way — a dq pass and a dk/dv pass — with pinned fp32 VMEM scratch
accumulators playing the role of the pinned register tiles, and the Pallas
pipeline providing the compute/memory alternation.

Block sizes come from a :class:`~repro.core.policy.KernelPolicy`
(``attention_bwd`` kind — its scratch accounting covers the dk+dv
accumulator pair, so a legal bwd policy may be smaller than the fwd one).
Traversal stays row-major: both passes accumulate over a full inner sweep
per output block, so the consecutive-revisit DMA model shows no gain from
reordering the outer dimension (DESIGN.md §5).

GQA: dk/dv are computed per *query* head and the (Hkv, group) reduction is
done by the caller (ops.py) — same strategy as the paper's 1.8-2.3x GQA-bwd
kernel, which parallelizes over query heads.

Epilogue chains (DESIGN.md §12) transpose under the attention saved-preact
convention: the only residuals are (out, lse). The softcap stage recomputes
the raw logits from the streamed q/k tiles, forms p from the *capped*
logits, and modulates ds by ``1 - tanh²(s/cap)`` in-kernel. A sink stage
needs nothing here — the fwd folded its mass into lse, so ``p = exp(s-lse)``
rows already sum to < 1 and ``ds = p·(dp - delta)`` is unchanged; dsink is
a jnp reduction in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core import tiles
from repro.core.policy import (KernelPolicy, legacy_attention_blocks,
                               resolve_policy)

from .epilogue import ATTN_EPILOGUE_NONE, AttnEpilogue

MASK_VALUE = -1e30


def _p_and_dsfactor(s_raw, lse, epilogue, q_start, kv_start, causal, window):
    """(p, ds_factor) from the raw scaled logits under the epilogue chain.

    p is formed from the *capped* logits (matching the fwd); ds_factor is
    the softcap grad ``1 - tanh²(s/cap)`` (None for the identity chain).
    """
    s = epilogue.apply_logits(s_raw)
    p = _mask_and_p(s, lse, q_start, kv_start, causal, window)
    return p, epilogue.grad_factor(s_raw)


def _mask_and_p(s, lse, q_start, kv_start, causal, window):
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    p = jnp.exp(s - lse)
    return jnp.where(mask, p, 0.0)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, nkv: int, block_q: int, block_kv: int,
               scale: float, causal: bool, window: int | None,
               epilogue: AttnEpilogue):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start, kv_start = iq * block_q, ik * block_kv
    run = True
    if causal:
        run = kv_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, q_start - (kv_start + block_kv - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        p, ds_factor = _p_and_dsfactor(s, lse, epilogue, q_start, kv_start,
                                       causal, window)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        if ds_factor is not None:
            ds = ds * ds_factor
        ds = ds * scale
        acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32)

    @pl.when(ik == nkv - 1)
    def _store():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, nq: int, block_q: int,
                block_kv: int, scale: float, causal: bool,
                window: int | None, epilogue: AttnEpilogue):
    ik, iq = pl.program_id(2), pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, kv_start = iq * block_q, ik * block_kv
    run = True
    if causal:
        run = kv_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, q_start - (kv_start + block_kv - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        p, ds_factor = _p_and_dsfactor(s, lse, epilogue, q_start, kv_start,
                                       causal, window)
        # dv += p^T @ do   (column-layout read in the paper; transposed dot here)
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        if ds_factor is not None:
            ds = ds * ds_factor
        ds = ds * scale
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _store():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "causal", "window", "logit_scale", "epilogue",
                     "interpret"),
)
def _flash_bwd(q, k, v, out, lse, do, *, policy: KernelPolicy,
               causal: bool, window: int | None,
               logit_scale: float | None, epilogue: AttnEpilogue,
               interpret: bool):
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    block_q = min(policy.block_q, sq)
    block_kv = min(policy.block_kv, skv)
    nq, nkv = sq // block_q, skv // block_kv
    scale = logit_scale if logit_scale is not None else d ** -0.5
    # ragged when the problem dims themselves are unaligned (see kernel_fwd)
    ragged_q = tiles.shape_ragged(sq, d, q.dtype)
    ragged_kv = tiles.shape_ragged(skv, d, k.dtype)

    policy.check()  # budget covers the larger of the two passes' scratch

    # delta = rowsum(dO * O): cheap, memory-bound; jnp preprocess (as in FA2/3)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    def tile(shape, index_map, dtype, *, ragged):
        return tiles.block_spec(shape, index_map, dtype,
                                allow_ragged_minor=ragged)

    q_spec = tile((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0),
                  q.dtype, ragged=ragged_q)
    kv_spec = tile((1, 1, block_kv, d),
                   lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0),
                   k.dtype, ragged=ragged_kv)
    vec_spec = pl.BlockSpec((1, 1, block_q), lambda b_, h_, iq, ik: (b_, h_, iq))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, nkv=nkv, block_q=block_q,
                          block_kv=block_kv, scale=scale, causal=causal,
                          window=window, epilogue=epilogue),
        grid=(b, h, nq, nkv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, vec_spec, vec_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=tiles.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv pass: grid transposed (kv outer, q inner), per query head.
    q_spec2 = tile((1, 1, block_q, d), lambda b_, h_, ik, iq: (b_, h_, iq, 0),
                   q.dtype, ragged=ragged_q)
    kv_spec2 = tile((1, 1, block_kv, d),
                    lambda b_, h_, ik, iq, g=group: (b_, h_ // g, ik, 0),
                    k.dtype, ragged=ragged_kv)
    kv_out_spec = tile((1, 1, block_kv, d),
                       lambda b_, h_, ik, iq: (b_, h_, ik, 0), k.dtype,
                       ragged=ragged_kv)
    vec_spec2 = pl.BlockSpec((1, 1, block_q), lambda b_, h_, ik, iq: (b_, h_, iq))

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, nq=nq, block_q=block_q,
                          block_kv=block_kv, scale=scale, causal=causal,
                          window=window, epilogue=epilogue),
        grid=(b, h, nkv, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, vec_spec2, vec_spec2],
        out_specs=[kv_out_spec, kv_out_spec],
        out_shape=[jax.ShapeDtypeStruct((b, h, skv, d), k.dtype),
                   jax.ShapeDtypeStruct((b, h, skv, d), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                        pltpu.VMEM((block_kv, d), jnp.float32)],
        compiler_params=tiles.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


def flash_attention_bwd(q, k, v, out, lse, do, *,
                        policy: KernelPolicy | None = None,
                        causal: bool = False, window: int | None = None,
                        block_q: int | None = None,
                        block_kv: int | None = None,
                        logit_scale: float | None = None,
                        epilogue: AttnEpilogue | None = None,
                        interpret: bool = True):
    """Returns (dq, dk, dv) with dk/dv per *query* head: (B, H, Skv, D).

    ``epilogue``: the attention chain to transpose (saved-preact convention,
    see the module docstring); defaults to the policy's own epilogue field.
    """
    if policy is None:
        b, h, sq, d = q.shape
        skv = k.shape[2]
        policy = resolve_policy(
            "attention_bwd", (b, h, sq, skv, d), q.dtype, causal=causal,
            legacy_blocks=legacy_attention_blocks(block_q, block_kv, sq,
                                                  skv, d),
            warn_what="flash_attention_bwd")
    if epilogue is None:
        epilogue = (policy.epilogue if policy.epilogue is not None
                    else ATTN_EPILOGUE_NONE)
    if obs.enabled():
        from repro.core import autotune
        b, h, sq, d = q.shape
        skv = k.shape[2]
        sig = autotune.OpSignature("attention_bwd", (b, h, sq, skv, d),
                                   str(q.dtype), causal=causal,
                                   epilogue=policy.epilogue)
        obs.launch("attention_bwd",
                   variant="causal" if causal else "",
                   grid=(b, h, max(1, sq // policy.block_q)),
                   policy=policy, chain=str(epilogue.describe()),
                   dma_bytes=autotune.score_policy(sig, policy).dma_bytes,
                   flops=int(10 * b * h * sq * skv * d
                             * (0.5 if causal else 1.0)))
    return _flash_bwd(q, k, v, out, lse, do, policy=policy, causal=causal,
                      window=window, logit_scale=logit_scale,
                      epilogue=epilogue, interpret=interpret)
