"""Flash-attention forward Pallas kernel (paper §4.2, listing E.3), TPU-adapted.

The paper's 8-wave ping-pong attention kernel alternates compute clusters
(MFMA + online-softmax vector ops) with load clusters (K/V tile prefetch).
On TPU the same alternation is the Pallas grid pipeline: iteration ik's
QK^T/PV MXU work overlaps iteration ik+1's K/V DMA. Online softmax state
(m, l, acc) lives in pinned fp32 VMEM scratch (the paper pins AGPRs).

Block sizes AND traversal order come from a
:class:`~repro.core.policy.KernelPolicy`: the (head, q-block) pair is fused
into one grid dimension and remapped by the policy's SwizzleConfig (the same
Algorithm-1 permutation the GEMM uses), so e.g. short-KV shapes can run
same-head q-blocks back-to-back and hit the Pallas K/V revisit fast path.
ROW_MAJOR reproduces the classic (b, h, iq, ik) traversal exactly.

Supports MHA and GQA (kv-head indexing in the BlockSpec index_map), causal
masking, and sliding-window masking (Mixtral/RecurrentGemma local attention).

The kernel also hosts the attention epilogue chain (DESIGN.md §12): an
:class:`~repro.kernels.attention.epilogue.AttnEpilogue` places the gemma2
logit soft cap inside the online-softmax loop (on the scaled logits, before
masking) and the attention-sink LSE combine at the output store, so neither
stage round-trips the score matrix or the output through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core import tiles
from repro.core.policy import (KernelPolicy, legacy_attention_blocks,
                               resolve_policy)

from .epilogue import ATTN_EPILOGUE_NONE, AttnEpilogue

MASK_VALUE = -1e30
LANES = 128


def _fwd_kernel(q_ref, k_ref, v_ref, *refs, nq: int, nkv: int, n_heads: int,
                block_q: int, block_kv: int, scale: float, causal: bool,
                window: int | None, swizzle, epilogue: AttnEpilogue):
    if epilogue.sink:
        sink_ref, o_ref, l_ref, acc_ref, m_ref, s_ref = refs
    else:
        o_ref, l_ref, acc_ref, m_ref, s_ref = refs
        sink_ref = None
    hq = pl.program_id(1)
    ik = pl.program_id(2)
    _, iq = swizzle.remap(hq, n_heads, nq)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        s_ref[...] = jnp.zeros_like(s_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv

    # Skip kv blocks that are fully masked for every query row of this block.
    run = True
    if causal:
        run = kv_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, q_start - (kv_start + block_kv - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # in-loop epilogue stage: tanh soft cap on the scaled logits,
        # pre-mask (identity when the chain has no cap)
        s = epilogue.apply_logits(s)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_ref[:, :1]
        l_prev = s_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0]
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        s_ref[...] = jnp.broadcast_to(l_new, s_ref.shape)

    @pl.when(ik == nkv - 1)
    def _store():
        # store epilogue: the sink (if any) joins the LSE combine here —
        # epilogue.finalize re-anchors the running max at max(m, sink)
        # before forming the denominator (DESIGN.md §12)
        l = s_ref[:, :1]
        m = m_ref[:, :1]
        sink = sink_ref[...] if sink_ref is not None else None  # (1, 1)
        out, lse = epilogue.finalize(acc_ref[...], m, l, sink=sink)
        o_ref[0, 0] = out.astype(o_ref.dtype)
        # logsumexp residual for the backward pass (includes the sink mass,
        # which is what makes the saved-preact convention hold: the bwd
        # kernels need no sink operand at all)
        l_ref[0, 0] = lse[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("policy", "causal", "window", "logit_scale", "epilogue",
                     "interpret"),
)
def _flash_fwd(q, k, v, sinks, *, policy: KernelPolicy, causal: bool,
               window: int | None, logit_scale: float | None,
               epilogue: AttnEpilogue, interpret: bool):
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    block_q = min(policy.block_q, sq)
    block_kv = min(policy.block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    nq, nkv = sq // block_q, skv // block_kv
    scale = logit_scale if logit_scale is not None else d ** -0.5
    swizzle = policy.swizzle
    # ragged when the problem dims themselves are unaligned (head_dim 64
    # tiles — paper Fig. 7 — or short/odd sequences): Pallas pads those.
    ragged_q = tiles.shape_ragged(sq, d, q.dtype)
    ragged_kv = tiles.shape_ragged(skv, d, k.dtype)

    policy.check()  # Tab. 2 feasibility at the policy's pipeline depth

    def hq_coords(i):
        """Fused (head, q-block) grid index -> (head, q-block) via Algorithm 1."""
        return swizzle.remap(i, h, nq)

    def q_map(b_, i, ik):
        hh, iq = hq_coords(i)
        return (b_, hh, iq, 0)

    def kv_map(b_, i, ik):
        hh, _ = hq_coords(i)
        return (b_, hh // group, ik, 0)

    def lse_map(b_, i, ik):
        hh, iq = hq_coords(i)
        return (b_, hh, iq)

    kernel = functools.partial(
        _fwd_kernel, nq=nq, nkv=nkv, n_heads=h, block_q=block_q,
        block_kv=block_kv, scale=scale, causal=causal, window=window,
        swizzle=swizzle, epilogue=epilogue)

    in_specs = [
        tiles.block_spec((1, 1, block_q, d), q_map, q.dtype,
                         allow_ragged_minor=ragged_q),
        tiles.block_spec((1, 1, block_kv, d), kv_map, k.dtype,
                         allow_ragged_minor=ragged_kv),
        tiles.block_spec((1, 1, block_kv, d), kv_map, v.dtype,
                         allow_ragged_minor=ragged_kv),
    ]
    operands = [q, k, v]
    if epilogue.sink:
        assert sinks is not None, "sink epilogue needs a sinks operand"
        # one f32 scalar per head, streamed per (head, q-block) grid cell
        in_specs.append(pl.BlockSpec(
            (1, 1), lambda b_, i, ik: (hq_coords(i)[0], 0)))
        operands.append(
            jnp.asarray(sinks, jnp.float32).reshape(h, 1))

    grid = (b, h * nq, nkv)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            tiles.block_spec((1, 1, block_q, d), q_map, q.dtype,
                             allow_ragged_minor=ragged_q),
            pl.BlockSpec((1, 1, block_q), lse_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),      # acc (pinned, DESIGN §2)
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running max m
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running sum l
        ],
        compiler_params=tiles.compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out, lse


def flash_attention_fwd(q, k, v, *, policy: KernelPolicy | None = None,
                        causal: bool = False, window: int | None = None,
                        block_q: int | None = None,
                        block_kv: int | None = None,
                        logit_scale: float | None = None,
                        epilogue: AttnEpilogue | None = None,
                        sinks=None,
                        interpret: bool = True):
    """Returns (out, lse). q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D).

    ``epilogue`` is the fused attention store chain (softcap/sink stages,
    DESIGN.md §12); ``sinks`` is the (H,) f32 operand the sink stage
    streams. When the chain is omitted, the policy's own epilogue field
    applies (the autotuner attaches it there).

    Explicit ``block_q``/``block_kv`` is the deprecated pre-policy surface
    (builds an equivalent explicit row-major policy); with neither a policy
    nor blocks, the autotuner resolves one per shape-bucket.
    """
    if policy is None:
        b, h, sq, d = q.shape
        skv = k.shape[2]
        policy = resolve_policy(
            "attention_fwd", (b, h, sq, skv, d), q.dtype, causal=causal,
            legacy_blocks=legacy_attention_blocks(block_q, block_kv, sq,
                                                  skv, d),
            warn_what="flash_attention_fwd")
    if epilogue is None:
        epilogue = (policy.epilogue if policy.epilogue is not None
                    else ATTN_EPILOGUE_NONE)
    if obs.enabled():
        from repro.core import autotune
        b, h, sq, d = q.shape
        skv = k.shape[2]
        sig = autotune.OpSignature("attention_fwd", (b, h, sq, skv, d),
                                   str(q.dtype), causal=causal,
                                   epilogue=policy.epilogue)
        obs.launch("attention_fwd",
                   variant="windowed" if window else
                   ("causal" if causal else ""),
                   grid=(b, h, max(1, sq // policy.block_q)),
                   policy=policy, chain=str(epilogue.describe()),
                   dma_bytes=autotune.score_policy(sig, policy).dma_bytes,
                   flops=int(4 * b * h * sq * skv * d
                             * (0.5 if causal else 1.0)))
    return _flash_fwd(q, k, v, sinks, policy=policy, causal=causal,
                      window=window, logit_scale=logit_scale,
                      epilogue=epilogue, interpret=interpret)
