"""Flash-attention forward Pallas kernel (paper §4.2, listing E.3), TPU-adapted.

The paper's 8-wave ping-pong attention kernel alternates compute clusters
(MFMA + online-softmax vector ops) with load clusters (K/V tile prefetch).
On TPU the same alternation is the Pallas grid pipeline: iteration ik's
QK^T/PV MXU work overlaps iteration ik+1's K/V DMA. Online softmax state
(m, l, acc) lives in pinned fp32 VMEM scratch (the paper pins AGPRs).

Supports MHA and GQA (kv-head indexing in the BlockSpec index_map), causal
masking, and sliding-window masking (Mixtral/RecurrentGemma local attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASK_VALUE = -1e30
LANES = 128


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, l_ref, acc_ref, m_ref, s_ref,
                *, nkv: int, block_q: int, block_kv: int, scale: float,
                causal: bool, window: int | None):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, MASK_VALUE)
        s_ref[...] = jnp.zeros_like(s_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv

    # Skip kv blocks that are fully masked for every query row of this block.
    run = True
    if causal:
        run = kv_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(run, q_start - (kv_start + block_kv - 1) < window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_ref[:, :1]
        l_prev = s_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0]
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        s_ref[...] = jnp.broadcast_to(l_new, s_ref.shape)

    @pl.when(ik == nkv - 1)
    def _store():
        l = s_ref[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        # logsumexp residual for the backward pass
        l_ref[0, 0] = (m_ref[:, 0] + jnp.log(jnp.where(l[:, 0] == 0, 1.0, l[:, 0])))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "logit_scale",
                     "interpret"),
)
def flash_attention_fwd(q, k, v, *, causal: bool = False,
                        window: int | None = None, block_q: int = 128,
                        block_kv: int = 128, logit_scale: float | None = None,
                        interpret: bool = True):
    """Returns (out, lse). q: (B,H,Sq,D), k/v: (B,Hkv,Skv,D)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    nq, nkv = sq // block_q, skv // block_kv
    scale = logit_scale if logit_scale is not None else d ** -0.5

    kernel = functools.partial(
        _fwd_kernel, nkv=nkv, block_q=block_q, block_kv=block_kv, scale=scale,
        causal=causal, window=window)

    grid = (b, h, nq, nkv)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, iq, ik: (b_, h_, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),      # acc (pinned, DESIGN §2)
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running max m
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running sum l
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out, lse
