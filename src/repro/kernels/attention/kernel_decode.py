"""Split-KV flash-decode Pallas kernel (q_len = 1), contiguous and paged.

Autoregressive decode is the paper's memory-bound regime (Fig. 9, Tab. 1's
GQA rows): per generated token every KV byte is read exactly once, so the
kernel's only job is to stream the cache at full HBM bandwidth. The split-KV
shape does that with a grid over (batch, kv_head, kv_split): each grid cell
streams one KV split, computes a partial softmax-attention over it with the
whole GQA group packed into the q tile rows (q is (group, head_dim) — MHA is
group == 1), and writes an unnormalized partial output plus its online-
softmax (m, l) statistics. A cheap jnp log-sum-exp combine merges the splits
exactly. Splitting the KV axis manufactures grid parallelism when
batch * kv_heads alone is too small to keep the DMA pipeline saturated —
the same reason GPU implementations split KV across SMs.

Two cache layouts share the kernel body:

* :func:`flash_decode` — contiguous (B, Hkv, S, D) caches, ring-buffer
  aware: per-sequence ``lengths`` (scalar-prefetched) give each slot its
  absolute position (slot = pos % S), which drives the validity and
  sliding-window masks.
* :func:`flash_decode_paged` — a (P, Hkv, page, D) page pool indexed
  through a scalar-prefetched per-sequence page table: grid dim 2 walks the
  table and the K/V BlockSpec index_map dereferences it, so each step DMAs
  one physical page (block_kv == page_size by construction). Never-written
  table entries point at the reserved null page 0; the length mask zeroes
  their contribution in the combine.

Policies come from ``repro.core.policy`` (op kind ``attention_decode``,
bandwidth-dominated perf model); block_n is the split size.

Epilogue chains (DESIGN.md §12) split across the two halves: the gemma2
``softcap`` is per-logit, so it runs inside the split kernels (on the
scaled logits, before masking); the attention ``sink`` is per-*row*, so it
lives in :func:`combine_splits` — the one place decode sees the global
softmax state — where it re-anchors the cross-split max exactly like the
flash store epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tiles
from repro.core.policy import KernelPolicy

from .epilogue import cap_logits

MASK_VALUE = -1e30


def _split_partials(q, k, v, valid, scale, softcap: float = 0.0):
    """Partial attention of one KV split.

    q: (G, D) f32, k/v: (bkv, D), valid: (bkv,) bool — or (G, bkv) bool
    when rows carry different positions (multi-token verify queries).
    ``softcap``: tanh logit cap applied in-split (0 = off). Returns
    unnormalized (o (G, D) f32, m (G,), l (G,)); a fully-masked split
    yields (0, MASK_VALUE, 0) which the combine weights to zero.
    """
    s = jax.lax.dot_general(q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = cap_logits(s, softcap)
    vmask = valid if valid.ndim == 2 else valid[None, :]
    s = jnp.where(vmask, s, MASK_VALUE)
    m = jnp.max(s, axis=1)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(vmask, p, 0.0)
    l = jnp.sum(p, axis=1)
    o = jax.lax.dot_general(p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return o, m, l


def combine_splits(o, m, l, sinks=None):
    """Log-sum-exp merge of per-split partials (the split-KV epilogue).

    o: (..., NS, G, D) f32 unnormalized partials; m, l: (..., NS, G).
    Exact: rescales every split to the global running max before summing,
    so the result is independent of the split count. Rows whose every split
    was fully masked (empty sequences) return zeros.

    ``sinks``: optional per-head sink logits, broadcastable against the
    (..., 1, G) cross-split max (flash_decode passes (Hkv, 1, G)). This is
    where decode's sink stage must live — the per-split kernels never see
    the global max, and the sink joins the denominator exactly once: the
    cross-split max is re-anchored at max(m_max, sink) *before* the
    rescale so exp never overflows, then exp(sink - m_tot) joins den. With
    a sink, an empty row's mass all lands on the sink (den == 1, out == 0)
    with no epsilon guard needed.
    """
    m_max = jnp.max(m, axis=-2, keepdims=True)
    if sinks is not None:
        m_tot = jnp.maximum(m_max, sinks)            # (..., 1, G)
        alpha = jnp.exp(m - m_tot)
        den = jnp.sum(l * alpha, axis=-2) + jnp.exp(sinks - m_tot)[..., 0, :]
        num = jnp.sum(o * alpha[..., None], axis=-3)
        return num / den[..., None]
    alpha = jnp.exp(m - m_max)                       # (..., NS, G)
    den = jnp.sum(l * alpha, axis=-2)                # (..., G)
    num = jnp.sum(o * alpha[..., None], axis=-3)     # (..., G, D)
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return jnp.where((den > 0.0)[..., None], out, 0.0)


def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                   block_kv: int, slots: int, scale: float,
                   window: int | None, softcap: float = 0.0):
    """Contiguous/ring variant: grid (B, Hkv, n_splits)."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = lengths_ref[b]
    pos = length - 1                                 # last written position
    cur = jax.lax.rem(jax.lax.rem(pos, slots) + slots, slots)
    idx = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_kv,), 0)
    # ring-aware absolute position of each slot (dense caches degenerate to
    # actual == idx); empty rows (length == 0) mask everything.
    actual = jnp.where(idx <= cur, pos - cur + idx, pos - cur - slots + idx)
    valid = (actual >= 0) & (actual <= pos)
    if window is not None:
        valid &= (pos - actual) < window
    o, m, l = _split_partials(q_ref[0, 0].astype(jnp.float32),
                              k_ref[0, 0], v_ref[0, 0], valid, scale, softcap)
    o_ref[0, 0, 0] = o
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


def _decode_kernel_paged(page_table_ref, lengths_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, *, page_size: int, scale: float,
                         window: int | None, softcap: float = 0.0,
                         q_tokens: int = 1):
    """Paged variant: grid (B, Hkv, max_pages); one physical page per step.

    ``q_tokens`` > 1 is the speculative-verify shape: the q tile packs
    T = q_tokens query positions per GQA group row-major (row = g*T + t),
    token t sitting at absolute position ``length - T + t``, so each row
    gets its own causal (and window) mask.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = lengths_ref[b]
    if q_tokens == 1:
        idx = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (page_size,), 0)
        valid = idx < length
        if window is not None:
            valid &= (length - 1 - idx) < window
    else:
        rows = q_ref.shape[2]
        pos_row = length - q_tokens + (
            jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0)
            % q_tokens)
        idx = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        valid = idx <= pos_row
        if window is not None:
            valid &= (pos_row - idx) < window
    o, m, l = _split_partials(q_ref[0, 0].astype(jnp.float32),
                              k_ref[0, 0], v_ref[0, 0], valid, scale, softcap)
    o_ref[0, 0, 0] = o
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l


def _partial_specs(b, hkv, n_splits, g, d):
    """(out_specs, out_shapes) of the per-split partials + stats."""
    part_map = lambda b_, h_, j_, *_: (b_, h_, j_, 0, 0)
    stat_map = lambda b_, h_, j_, *_: (b_, h_, j_, 0)
    out_specs = [
        tiles.block_spec((1, 1, 1, g, d), part_map, jnp.float32,
                         allow_ragged_minor=True),   # q rows = GQA group
        pl.BlockSpec((1, 1, 1, g), stat_map),
        pl.BlockSpec((1, 1, 1, g), stat_map),
    ]
    out_shapes = [
        jax.ShapeDtypeStruct((b, hkv, n_splits, g, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hkv, n_splits, g), jnp.float32),
        jax.ShapeDtypeStruct((b, hkv, n_splits, g), jnp.float32),
    ]
    return out_specs, out_shapes


@functools.partial(
    jax.jit,
    static_argnames=("policy", "window", "logit_scale", "softcap",
                     "interpret"),
)
def flash_decode(q, k, v, lengths, *, policy: KernelPolicy,
                 window: int | None = None,
                 logit_scale: float | None = None,
                 softcap: float = 0.0, sinks=None,
                 interpret: bool = True):
    """Split-KV decode over a contiguous (possibly ring) KV cache.

    q: (B, Hkv, G, D) group-packed queries; k/v: (B, Hkv, S, D);
    lengths: (B,) int32 tokens written so far (ring semantics when
    lengths > S). ``softcap``: in-kernel tanh logit cap; ``sinks``: (H,)
    per-query-head sink logits, folded in by the LSE combine. Returns
    (B, Hkv, G, D) in q.dtype.
    """
    b, hkv, g, d = q.shape
    slots = k.shape[2]
    block_kv = min(policy.block_kv, slots)
    assert slots % block_kv == 0, (slots, block_kv)
    n_splits = slots // block_kv
    scale = logit_scale if logit_scale is not None else d ** -0.5
    policy.check()
    lengths = jnp.asarray(lengths, jnp.int32).reshape(b)

    ragged_kv = tiles.shape_ragged(slots, d, k.dtype)
    q_map = lambda b_, h_, j_, *_: (b_, h_, 0, 0)
    kv_map = lambda b_, h_, j_, *_: (b_, h_, j_, 0)
    out_specs, out_shapes = _partial_specs(b, hkv, n_splits, g, d)

    kernel = functools.partial(_decode_kernel, block_kv=block_kv, slots=slots,
                               scale=scale, window=window, softcap=softcap)
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hkv, n_splits),
            in_specs=[
                tiles.block_spec((1, 1, g, d), q_map, q.dtype,
                                 allow_ragged_minor=True),  # tiny q tile
                tiles.block_spec((1, 1, block_kv, d), kv_map, k.dtype,
                                 allow_ragged_minor=ragged_kv),
                tiles.block_spec((1, 1, block_kv, d), kv_map, v.dtype,
                                 allow_ragged_minor=ragged_kv),
            ],
            out_specs=out_specs,
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(lengths, q, k, v)
    if sinks is not None:
        sinks = jnp.asarray(sinks, jnp.float32).reshape(hkv, 1, g)
    return combine_splits(o, m, l, sinks=sinks).astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "window", "logit_scale", "softcap",
                     "interpret", "q_tokens"),
)
def flash_decode_paged(q, k_pages, v_pages, page_table, lengths, *,
                       policy: KernelPolicy, window: int | None = None,
                       logit_scale: float | None = None,
                       softcap: float = 0.0, sinks=None,
                       interpret: bool = True, q_tokens: int = 1):
    """Split-KV decode over a paged KV pool (one split == one page).

    q: (B, Hkv, G, D); k_pages/v_pages: (P, Hkv, page_size, D) physical
    pools; page_table: (B, MP) int32 physical page ids (0 = reserved null
    page for never-written entries); lengths: (B,) tokens written so far.
    ``softcap``/``sinks`` as in :func:`flash_decode`. Returns
    (B, Hkv, G, D) in q.dtype.

    ``q_tokens`` > 1: G packs group * q_tokens rows (row = g*T + t) and
    row t attends through position ``lengths - q_tokens + t`` — the
    speculative-decoding verify step, which streams the KV pool exactly
    once for all T tokens.
    """
    b, hkv, g, d = q.shape
    n_pages, _, page_size, _ = k_pages.shape
    mp = page_table.shape[1]
    assert policy.block_kv == page_size, (policy.block_kv, page_size)
    scale = logit_scale if logit_scale is not None else d ** -0.5
    policy.check()
    page_table = jnp.asarray(page_table, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(b)

    ragged_kv = tiles.shape_ragged(page_size, d, k_pages.dtype)
    q_map = lambda b_, h_, j_, *_: (b_, h_, 0, 0)
    # the paged-attention indirection: the K/V block for grid step (b, h, j)
    # is whatever physical page the (scalar-prefetched) table names
    kv_map = lambda b_, h_, j_, pt_ref, len_ref: (pt_ref[b_, j_], h_, 0, 0)
    out_specs, out_shapes = _partial_specs(b, hkv, mp, g, d)

    kernel = functools.partial(_decode_kernel_paged, page_size=page_size,
                               scale=scale, window=window, softcap=softcap,
                               q_tokens=q_tokens)
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, mp),
            in_specs=[
                tiles.block_spec((1, 1, g, d), q_map, q.dtype,
                                 allow_ragged_minor=True),
                tiles.block_spec((1, 1, page_size, d), kv_map, k_pages.dtype,
                                 allow_ragged_minor=ragged_kv),
                tiles.block_spec((1, 1, page_size, d), kv_map, v_pages.dtype,
                                 allow_ragged_minor=ragged_kv),
            ],
            out_specs=out_specs,
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)
    if sinks is not None:
        sinks = jnp.asarray(sinks, jnp.float32).reshape(hkv, 1, g)
    return combine_splits(o, m, l, sinks=sinks).astype(q.dtype)
