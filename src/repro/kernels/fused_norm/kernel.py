"""Fused dropout + residual + layernorm Pallas kernel (paper Fig. 9/22).

One pass over the activations: generate the dropout mask *in-kernel* from a
counter-based hash (no HBM mask traffic — the TPU-portable equivalent of the
paper's in-register dropout_mask), scale, add the residual, emit the residual
stream, then layernorm in fp32. Memory-bound by construction: exactly
2 reads + 2 writes of (rows, d) plus the (d,) affine params.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core.policy import KernelPolicy, resolve_policy


def _lowbias32(x: jax.Array) -> jax.Array:
    """Counter-based 32-bit mix (lowbias32); identical fn lives in ref.py."""
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def dropout_keep_mask(seed: jax.Array, row0, shape, p: float) -> jax.Array:
    """Deterministic keep-mask for rows [row0, row0+shape[0]) — uniform >= p."""
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    idx = rows.astype(jnp.uint32) * jnp.uint32(shape[1]) + cols.astype(jnp.uint32)
    bits = _lowbias32(idx ^ _lowbias32(jnp.uint32(seed)))
    uniform = (bits >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    return uniform >= p


def _fused_kernel(seed_ref, x_ref, res_ref, w_ref, b_ref, o_ref, oresid_ref,
                  *, block_rows: int, dropout_p: float, eps: float):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)
    resid = res_ref[...].astype(jnp.float32)

    if dropout_p > 0.0:
        keep = dropout_keep_mask(seed_ref[0], i * block_rows, x.shape, dropout_p)
        x = jnp.where(keep, x * (1.0 / (1.0 - dropout_p)), 0.0)

    resid = resid + x
    oresid_ref[...] = resid.astype(oresid_ref.dtype)

    mean = jnp.mean(resid, axis=1, keepdims=True)
    centered = resid - mean
    var = jnp.mean(centered * centered, axis=1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = (centered * inv * w + b).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("dropout_p", "eps", "policy", "interpret"),
)
def _fused(x, residual, weight, bias, seed, *, policy: KernelPolicy,
           dropout_p: float, eps: float, interpret: bool):
    rows, d = x.shape
    block_rows = min(policy.block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    seed_arr = jnp.asarray([seed], jnp.int32) if jnp.ndim(seed) == 0 else seed

    row_spec = pl.BlockSpec((block_rows, d), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    out, new_resid = pl.pallas_call(
        functools.partial(_fused_kernel, block_rows=block_rows,
                          dropout_p=dropout_p, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  row_spec, row_spec, vec_spec, vec_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, d), x.dtype),
                   jax.ShapeDtypeStruct((rows, d), x.dtype)],
        interpret=interpret,
    )(seed_arr, x, residual, weight.reshape(1, d), bias.reshape(1, d))
    return out, new_resid


def fused_dropout_residual_layernorm(x, residual, weight, bias, seed,
                                     *, policy: KernelPolicy | None = None,
                                     dropout_p: float = 0.0,
                                     eps: float = 1e-5,
                                     block_rows: int | None = None,
                                     interpret: bool = True):
    """x, residual: (rows, d); weight/bias: (d,). Returns (normed, new_residual).

    Explicit ``block_rows`` is the deprecated pre-policy surface; with
    neither a policy nor a block, the autotuner selects the row block.
    """
    rows, d = x.shape
    if policy is None:
        legacy = (None if block_rows is None
                  else dict(block_rows=min(block_rows, rows), d=d))
        policy = resolve_policy("fused_norm", (rows, d), x.dtype,
                                legacy_blocks=legacy, warn_what="fused_norm")
    if obs.enabled():
        from repro.core import perf_model as pm
        obs.launch("fused_norm",
                   grid=(max(1, rows // min(policy.block_rows, rows)),),
                   policy=policy,
                   dma_bytes=pm.dropout_residual_ln_traffic(
                       rows, d, dtype_bytes=jnp.dtype(x.dtype).itemsize),
                   flops=10 * rows * d)
    return _fused(x, residual, weight, bias, seed, policy=policy,
                  dropout_p=dropout_p, eps=eps, interpret=interpret)
