"""Pure-jnp oracle for the fused dropout+residual+layernorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _lowbias32(x):
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def dropout_keep_mask_ref(seed, shape, p):
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    idx = rows.astype(jnp.uint32) * jnp.uint32(shape[1]) + cols.astype(jnp.uint32)
    bits = _lowbias32(idx ^ _lowbias32(jnp.uint32(seed)))
    uniform = (bits >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    return uniform >= p


def fused_dropout_residual_layernorm_ref(x, residual, weight, bias, seed,
                                         *, dropout_p: float = 0.0,
                                         eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if dropout_p > 0.0:
        keep = dropout_keep_mask_ref(seed, x.shape, dropout_p)
        xf = jnp.where(keep, xf * (1.0 / (1.0 - dropout_p)), 0.0)
    resid = residual.astype(jnp.float32) + xf
    mean = jnp.mean(resid, axis=1, keepdims=True)
    centered = resid - mean
    var = jnp.mean(centered * centered, axis=1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    out = centered * inv * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype), resid.astype(x.dtype)
