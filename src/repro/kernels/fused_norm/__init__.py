from .ops import dropout_residual_layernorm  # noqa: F401
from .ref import fused_dropout_residual_layernorm_ref  # noqa: F401
from .kernel import fused_dropout_residual_layernorm  # noqa: F401
