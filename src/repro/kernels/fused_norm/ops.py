"""Public fused dropout+residual+layernorm op with mode dispatch."""
from __future__ import annotations

from repro.core.policy import KernelPolicy
from .kernel import fused_dropout_residual_layernorm
from .ref import fused_dropout_residual_layernorm_ref


def dropout_residual_layernorm(x, residual, weight, bias, seed=0, *,
                               policy: KernelPolicy | None = None,
                               dropout_p: float = 0.0, eps: float = 1e-5,
                               mode: str = "pallas_interpret"):
    """Fuses prenorm-transformer glue: (dropout(x) + residual) -> LN.

    Returns (normed, new_residual). Shapes: x/residual (rows, d). The row
    block comes from ``policy`` (or the autotuner when None — the memoized
    1-D row-block selection, DESIGN.md §5).
    """
    if mode == "reference":
        return fused_dropout_residual_layernorm_ref(
            x, residual, weight, bias, seed, dropout_p=dropout_p, eps=eps)
    return fused_dropout_residual_layernorm(
        x, residual, weight, bias, seed, policy=policy, dropout_p=dropout_p,
        eps=eps, interpret=(mode == "pallas_interpret"))
