"""Public fused dropout+residual+layernorm op with mode dispatch."""
from __future__ import annotations

from .kernel import fused_dropout_residual_layernorm
from .ref import fused_dropout_residual_layernorm_ref


def dropout_residual_layernorm(x, residual, weight, bias, seed=0, *,
                               dropout_p: float = 0.0, eps: float = 1e-5,
                               mode: str = "pallas_interpret"):
    """Fuses prenorm-transformer glue: (dropout(x) + residual) -> LN.

    Returns (normed, new_residual). Shapes: x/residual (rows, d).
    """
    if mode == "reference":
        return fused_dropout_residual_layernorm_ref(
            x, residual, weight, bias, seed, dropout_p=dropout_p, eps=eps)
    rows = x.shape[0]
    block_rows = 256
    while rows % block_rows:
        block_rows //= 2
    return fused_dropout_residual_layernorm(
        x, residual, weight, bias, seed, dropout_p=dropout_p, eps=eps,
        block_rows=block_rows, interpret=(mode == "pallas_interpret"))
