"""The paper's kernel suite, TPU-native (Pallas; validated via interpret=True).

Each kernel ships three layers: ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper with mode dispatch), ``ref.py`` (pure-jnp
oracle used by the tests and the 512-device dry-run).
"""
from .gemm import gemm, gemm_ref  # noqa: F401
from .attention import attention, attention_ref  # noqa: F401
from .fused_norm import dropout_residual_layernorm  # noqa: F401
from .rope import rope, rope_ref, rope_tables  # noqa: F401
