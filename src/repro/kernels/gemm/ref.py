"""Pure-jnp oracles for the GEMM kernel and its fused epilogue chains."""
import jax.numpy as jnp

from .epilogue import EPILOGUE_NONE, Epilogue


def gemm_ref(a, b, out_dtype=jnp.bfloat16):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def gemm_fused_ref(a, b, *, epilogue: Epilogue = EPILOGUE_NONE, b2=None,
                   bias=None, residual=None, scale=None, sin=None, cos=None,
                   out_dtype=jnp.bfloat16):
    """Unfused oracle for :func:`repro.kernels.gemm.ops.gemm_fused`.

    Materializes the full fp32 GEMM result(s), then runs the identical
    epilogue chain on the whole array — the HBM-round-trip version the fused
    kernel eliminates. Operand shapes: bias (N,) or (1, N); residual (M, N);
    scale scalar; sin/cos (M, head_dim) duplicated-halves tables.
    """
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc2 = None
    if epilogue.gate:
        acc2 = jnp.dot(a.astype(jnp.float32), b2.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    kw = {}
    if epilogue.bias:
        kw["bias"] = jnp.asarray(bias, jnp.float32).reshape(1, -1)
    if epilogue.residual:
        kw["residual"] = residual.astype(jnp.float32)
    if epilogue.scale:
        kw["scale"] = jnp.asarray(scale, jnp.float32).reshape(())
    if epilogue.rope:
        kw["sin"] = jnp.asarray(sin, jnp.float32)
        kw["cos"] = jnp.asarray(cos, jnp.float32)
    return epilogue.apply(acc, acc2, **kw).astype(out_dtype)
