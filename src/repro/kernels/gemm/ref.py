"""Pure-jnp oracles for the GEMM kernel and its fused prologue/epilogue chains."""
import jax.numpy as jnp

from .epilogue import EPILOGUE_NONE, Epilogue
from .prologue import PROLOGUE_NONE, Prologue


def gemm_ref(a, b, out_dtype=jnp.bfloat16):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def gemm_fused_ref(a, b, *, epilogue: Epilogue = EPILOGUE_NONE,
                   prologue: Prologue = PROLOGUE_NONE, b2=None,
                   bias=None, residual=None, scale=None, sin=None, cos=None,
                   gamma=None, beta=None, mean=None, rstd=None,
                   out_dtype=jnp.bfloat16):
    """Unfused oracle for :func:`repro.kernels.gemm.ops.gemm_fused`.

    Runs the identical prologue on the full A array (materializing the
    normed activation the fused kernel never writes), then the full fp32
    GEMM result(s), then the identical epilogue chain on the whole array —
    the HBM-round-trip version the fused kernel eliminates. Operand shapes:
    gamma/beta (K,) or (1, K); mean/rstd (M,) or (M, 1); bias (N,) or
    (1, N); residual (M, N); scale scalar; sin/cos (M, head_dim)
    duplicated-halves tables.
    """
    if not prologue.is_identity:
        pkw = {"gamma": jnp.asarray(gamma, jnp.float32).reshape(1, -1)}
        if prologue.beta:
            pkw["beta"] = jnp.asarray(beta, jnp.float32).reshape(1, -1)
        if prologue.precomputed_stats:
            if prologue.norm == "layernorm":
                pkw["mean"] = jnp.asarray(mean, jnp.float32).reshape(-1, 1)
            pkw["rstd"] = jnp.asarray(rstd, jnp.float32).reshape(-1, 1)
        # norm in fp32, then round through the MXU input dtype — the same
        # rounding point as the kernel (fp8 operands feed the MXU as bf16)
        mxu_dtype = jnp.bfloat16 if a.dtype.itemsize == 1 else a.dtype
        a = prologue.apply(a.astype(jnp.float32), **pkw).astype(mxu_dtype)
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc2 = None
    if epilogue.gate:
        acc2 = jnp.dot(a.astype(jnp.float32), b2.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    kw = {}
    if epilogue.bias:
        kw["bias"] = jnp.asarray(bias, jnp.float32).reshape(1, -1)
    if epilogue.residual:
        kw["residual"] = residual.astype(jnp.float32)
    if epilogue.scale:
        kw["scale"] = jnp.asarray(scale, jnp.float32).reshape(())
    if epilogue.rope:
        kw["sin"] = jnp.asarray(sin, jnp.float32)
        kw["cos"] = jnp.asarray(cos, jnp.float32)
    return epilogue.apply(acc, acc2, **kw).astype(out_dtype)
