"""Pure-jnp oracle for the GEMM kernel."""
import jax.numpy as jnp


def gemm_ref(a, b, out_dtype=jnp.bfloat16):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)
