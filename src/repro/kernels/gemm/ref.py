"""Pure-jnp oracles for the GEMM kernel and its fused prologue/epilogue
chains, plus the hand-written chain-transpose backward oracle."""
import jax.numpy as jnp

from .epilogue import EPILOGUE_NONE, Epilogue
from .prologue import PROLOGUE_NONE, Prologue
# one source of truth for the fp8→bf16 MXU rounding point: the oracle and
# the kernel's saved preactivations must never diverge on it
from .kernel import mxu_input_dtype as _mxu_dtype


def gemm_ref(a, b, out_dtype=jnp.bfloat16):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def gemm_fused_ref(a, b, *, epilogue: Epilogue = EPILOGUE_NONE,
                   prologue: Prologue = PROLOGUE_NONE, b2=None,
                   bias=None, residual=None, scale=None, sin=None, cos=None,
                   gamma=None, beta=None, mean=None, rstd=None,
                   out_dtype=jnp.bfloat16):
    """Unfused oracle for :func:`repro.kernels.gemm.ops.gemm_fused`.

    Runs the identical prologue on the full A array (materializing the
    normed activation the fused kernel never writes), then the full fp32
    GEMM result(s), then the identical epilogue chain on the whole array —
    the HBM-round-trip version the fused kernel eliminates. Operand shapes:
    gamma/beta (K,) or (1, K); mean/rstd (M,) or (M, 1); bias (N,) or
    (1, N); residual (M, N); scale scalar; sin/cos (M, head_dim)
    duplicated-halves tables.
    """
    if not prologue.is_identity:
        pkw = _prologue_kwargs(prologue, gamma, beta, mean, rstd)
        # norm in fp32, then round through the MXU input dtype — the same
        # rounding point as the kernel (fp8 operands feed the MXU as bf16)
        a = prologue.apply(a.astype(jnp.float32),
                           **pkw).astype(_mxu_dtype(a.dtype))
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc2 = None
    if epilogue.gate:
        acc2 = jnp.dot(a.astype(jnp.float32), b2.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    kw = {}
    if epilogue.bias:
        kw["bias"] = jnp.asarray(bias, jnp.float32).reshape(1, -1)
    if epilogue.residual:
        kw["residual"] = residual.astype(jnp.float32)
    if epilogue.scale:
        kw["scale"] = _scale_f32(epilogue, scale)
    if epilogue.rope:
        kw["sin"] = jnp.asarray(sin, jnp.float32)
        kw["cos"] = jnp.asarray(cos, jnp.float32)
    return epilogue.apply(acc, acc2, **kw).astype(out_dtype)


def _prologue_kwargs(prologue, gamma, beta, mean, rstd) -> dict:
    pkw = {"gamma": jnp.asarray(gamma, jnp.float32).reshape(1, -1)}
    if prologue.beta:
        pkw["beta"] = jnp.asarray(beta, jnp.float32).reshape(1, -1)
    if prologue.precomputed_stats:
        if prologue.norm == "layernorm":
            pkw["mean"] = jnp.asarray(mean, jnp.float32).reshape(-1, 1)
        pkw["rstd"] = jnp.asarray(rstd, jnp.float32).reshape(-1, 1)
    return pkw


def _scale_f32(epilogue, scale):
    """The scale operand in fp32, shaped per scale_kind (broadcastable)."""
    s = jnp.asarray(scale, jnp.float32)
    if epilogue.scale_kind == "row":
        return s.reshape(-1, 1)
    if epilogue.scale_kind == "col":
        return s.reshape(1, -1)
    return s.reshape(())


def gemm_fused_bwd_ref(a, b, g, *, epilogue: Epilogue = EPILOGUE_NONE,
                       prologue: Prologue = PROLOGUE_NONE, b2=None,
                       bias=None, residual=None, scale=None, sin=None,
                       cos=None, gamma=None, beta=None, mean=None, rstd=None,
                       preact=None, preact2=None, out=None):
    """Hand-written chain-transpose oracle for the fused backward
    (DESIGN.md §11) — the same declarative transpose rules the bwd Pallas
    launches run, on full arrays:

        gbar[, gbar2] = epilogue.transpose_tile(g)   # fwd epilogue, as a
                                                     # prologue on g
        dAn = gbar @ Bᵀ [+ gbar2 @ B2ᵀ]              # the dA GEMM
        dA, dgamma, ... = prologue.transpose(dAn, A) # norm transpose
        dB[, dB2] = Anᵀ @ gbar[, gbar2]              # the dB GEMM(s)
        dbias/dresidual/dscale/dsin/dcos via epilogue.operand_grads

    ``preact``/``preact2`` are the fwd launch's saved raw accumulators (in
    the MXU input dtype); when omitted the oracle recomputes them (the
    remat-style path). ``out`` is the fwd output, consulted only by the
    rope-table cotangents when no preact exists (the rotation is inverted).

    Returns ``(da, db, grads)`` with ``grads`` keyed by operand name
    (``b2``/``bias``/``residual``/``scale``/``sin``/``cos``/``gamma``/
    ``beta``/``mean``/``rstd``). Tested against the autodiff of
    :func:`gemm_fused_ref` — the declarative rules may never drift from the
    oracle — and serving as the grad oracle for the bwd kernels.
    """
    f32 = jnp.float32
    a_f32 = a.astype(f32)
    an = a_f32
    pkw = {}
    if not prologue.is_identity:
        pkw = _prologue_kwargs(prologue, gamma, beta, mean, rstd)
        an = prologue.apply(a_f32, **pkw).astype(_mxu_dtype(a.dtype))
    an_f32 = an.astype(f32)
    b_f32 = b.astype(f32)
    if preact is None and (epilogue.needs_saved_preact or
                           (epilogue.rope and out is None)):
        preact = jnp.dot(an_f32, b_f32, preferred_element_type=f32)
        if epilogue.gate:
            preact2 = jnp.dot(an_f32, b2.astype(f32),
                              preferred_element_type=f32)
    ekw = {}
    if epilogue.bias:
        ekw["bias"] = jnp.asarray(bias, f32).reshape(1, -1)
    if epilogue.scale:
        ekw["scale"] = _scale_f32(epilogue, scale)
    if epilogue.rope:
        ekw["sin"] = jnp.asarray(sin, f32)
        ekw["cos"] = jnp.asarray(cos, f32)
    g_f32 = g.astype(f32)
    p32 = None if preact is None else preact.astype(f32)
    p32_2 = None if preact2 is None else preact2.astype(f32)
    streams = epilogue.transpose_tile(g_f32, p32, p32_2, **ekw)
    dan = jnp.dot(streams["g_acc"], b_f32.T, preferred_element_type=f32)
    if epilogue.gate:
        dan = dan + jnp.dot(streams["g_acc2"], b2.astype(f32).T,
                            preferred_element_type=f32)
    tr = prologue.transpose(dan, a_f32, **pkw)
    da = tr["da"].astype(a.dtype)
    db = jnp.dot(an_f32.T, streams["g_acc"],
                 preferred_element_type=f32).astype(b.dtype)
    grads = {}
    if epilogue.gate:
        grads["b2"] = jnp.dot(an_f32.T, streams["g_acc2"],
                              preferred_element_type=f32).astype(b2.dtype)
    og = epilogue.operand_grads(
        g_f32, p32, p32_2, None if out is None else out.astype(f32), **ekw,
        residual=None)
    for name in ("bias", "residual", "scale", "sin", "cos"):
        if name in og:
            grads[name] = og[name]
    if epilogue.residual:
        grads["residual"] = g.astype(residual.dtype)
    for name in prologue.operand_names():
        grads[name] = tr["d" + name]
    return da, db, grads
