"""Declarative load-side prologues for the blocked GEMM megakernel.

The :class:`~repro.kernels.gemm.epilogue.Epilogue` (DESIGN.md §9) is the
*store* half of the fusion story: a short elementwise chain run on the
output tile while it is still VMEM-resident. A :class:`Prologue` is the
symmetric *load* half: a per-row normalization (rmsnorm / layernorm)
applied to each A tile as it streams into VMEM, before it feeds the MXU.
This eliminates the normed-activation HBM round trip in front of every
pre-norm transformer GEMM — the QKV projection and the MLP up-projection
both read ``norm(x)``, which today is written by a standalone norm pass and
immediately read back (DESIGN.md §10).

Two stats paths, selected by ``precomputed_stats``:

  * **recompute (default)** — the kernel computes the row statistics
    (mean / rstd) from the A tile itself. Exact only when the tile spans
    the full feature dim, so :meth:`check_blocks` pins
    ``block_k == K``. The norm is recomputed once per A-tile *visit*
    (i.e. once per output-column block under the traversal order) — cheap
    vector work the plan model charges per visit, bought against the
    eliminated ``2·M·K`` activation round trip.
  * **precomputed-rstd fast path** — the caller precomputes the (M, 1)
    row statistics (``rstd``, plus ``mean`` for layernorm) with one jnp
    pass over x and streams them as tiny row blocks. Given the row stats
    the norm is affine per element, so any ``block_k`` is exact and
    K-blocking is preserved.

gamma (and beta for layernorm) stream as (1, block_k) row vectors indexed
by the k grid dim — the same row-broadcast convention as the epilogue's
bias, on the operand side.

:class:`Prologue` implements the same chain-spec protocol as
:class:`Epilogue` (``operand_names`` / ``extra_operand_blocks`` /
``check_blocks`` / ``apply`` / ``describe`` / ``extra_read_bytes``), and
one :meth:`apply` serves both the Pallas kernel (on VMEM tiles) and the
jnp oracle (on full arrays). Extra-operand convention (prologue operands
precede epilogue operands in the kernel ref list):
``gamma?, beta?, mean?, rstd?`` — see :meth:`operand_names`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NORMS = ("none", "rmsnorm", "layernorm")

# eps defaults matching models/common.{rmsnorm,layernorm} — the prologue
# must be bit-compatible with the standalone norms it replaces.
_DEFAULT_EPS = {"rmsnorm": 1e-6, "layernorm": 1e-5}


@dataclasses.dataclass(frozen=True)
class Prologue:
    """A frozen, hashable A-operand prologue spec (jit-static by construction).

    ``beta`` marks the layernorm bias row; ``precomputed_stats`` selects the
    fast path (caller-supplied ``rstd`` and, for layernorm, ``mean`` row
    vectors); ``eps`` defaults per norm kind to match the standalone
    reference norms.
    """

    norm: str = "none"              # 'none' | 'rmsnorm' | 'layernorm'
    beta: bool = False              # layernorm bias row present
    precomputed_stats: bool = False # stream (M, 1) stats instead of recompute
    eps: Optional[float] = None     # resolved per norm kind when None

    def __post_init__(self):
        if self.norm not in NORMS:
            raise ValueError(f"unknown norm {self.norm!r}; have {NORMS}")
        if self.norm == "none":
            if self.beta or self.precomputed_stats or self.eps is not None:
                raise ValueError("beta/precomputed_stats/eps are only "
                                 "meaningful with a norm")
        else:
            if self.beta and self.norm != "layernorm":
                raise ValueError("beta (bias row) only applies to layernorm")
            if self.eps is None:
                object.__setattr__(self, "eps", _DEFAULT_EPS[self.norm])

    # -- identity / shape of the chain -------------------------------------
    @property
    def is_identity(self) -> bool:
        return self.norm == "none"

    @property
    def needs_full_k(self) -> bool:
        """True when the A tile must span the whole feature dim (the
        recompute path derives row stats from the tile itself)."""
        return self.norm != "none" and not self.precomputed_stats

    def operand_names(self) -> tuple:
        """Runtime extra operands, in the canonical kernel order (prologue
        operands precede epilogue operands)."""
        names = []
        if self.norm != "none":
            names.append("gamma")
            if self.beta:
                names.append("beta")
            if self.precomputed_stats:
                if self.norm == "layernorm":
                    names.append("mean")
                names.append("rstd")
        return tuple(names)

    # -- VMEM legality accounting (consumed by KernelPolicy) ----------------
    def extra_operand_blocks(self, block_m: int, block_k: int,
                             in_dtype: str) -> list:
        """(shape, dtype) of each extra pipelined block, for vmem budgeting.

        gamma/beta are (1, block_k) row blocks indexed by the k grid dim;
        the fast-path stats are (block_m, 1) f32 column blocks indexed by
        the output-row dim.
        """
        blocks = []
        if self.norm != "none":
            blocks.append(((1, block_k), in_dtype))
            if self.beta:
                blocks.append(((1, block_k), in_dtype))
            if self.precomputed_stats:
                n_stats = 2 if self.norm == "layernorm" else 1
                blocks += [((block_m, 1), "float32")] * n_stats
        return blocks

    def check_blocks(self, block_k: int, k_total: int) -> None:
        """Raise on block shapes the prologue cannot legally tile."""
        if self.needs_full_k and block_k != k_total:
            raise ValueError(
                f"{self.norm} prologue (recompute path) needs the A tile to "
                f"span the full feature dim: block_k == K "
                f"(got block_k={block_k}, K={k_total}); use "
                f"precomputed_stats=True to keep K-blocking")

    # -- modeled HBM traffic of the extra streamed operands -----------------
    def extra_read_bytes(self, m: int, k: int, dtype_bytes: int) -> int:
        """Bytes the fused kernel reads beyond the A/B panels: the gamma
        (and beta) row vectors, plus the fast-path stats columns. The
        *eliminated* normed-activation round trip is accounted at the
        chain-model level (perf_model), not here."""
        extra = 0
        if self.norm != "none":
            extra += k * dtype_bytes * (2 if self.beta else 1)
            if self.precomputed_stats:
                extra += m * 4 * (2 if self.norm == "layernorm" else 1)
        return extra

    # -- the chain itself ---------------------------------------------------
    def compute_stats(self, x) -> dict:
        """The fast path's (rows, 1) f32 row statistics for full array ``x``
        — one cheap jnp pass; callers feed the result to ``gemm_fused``."""
        if self.norm == "none":
            return {}
        xf = x.astype(jnp.float32)
        if self.norm == "rmsnorm":
            var = jnp.mean(xf * xf, axis=-1, keepdims=True)
            return {"rstd": jax.lax.rsqrt(var + self.eps)}
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        c = xf - mean
        var = jnp.mean(c * c, axis=-1, keepdims=True)
        return {"mean": mean, "rstd": jax.lax.rsqrt(var + self.eps)}

    def apply(self, x, *, gamma=None, beta=None, mean=None, rstd=None):
        """Normalize an fp32 A tile (or full array) row-wise.

        Without precomputed stats the reduction runs over the tile's last
        axis — exact because ``check_blocks`` pinned the tile to the full
        feature dim. All operands must already be fp32; broadcasting makes
        the same code exact for a (block_m, block_k) tile and the full
        (M, K) array. Identical math to models/common.{rmsnorm,layernorm}.
        """
        if self.norm == "none":
            return x
        if self.norm == "rmsnorm":
            if rstd is None:
                var = jnp.mean(x * x, axis=-1, keepdims=True)
                rstd = jax.lax.rsqrt(var + self.eps)
            return x * rstd * gamma
        if mean is None:
            mean = jnp.mean(x, axis=-1, keepdims=True)
        c = x - mean
        if rstd is None:
            var = jnp.mean(c * c, axis=-1, keepdims=True)
            rstd = jax.lax.rsqrt(var + self.eps)
        out = c * rstd * gamma
        if self.beta:
            out = out + beta
        return out

    # -- the chain transpose (DESIGN.md §11) --------------------------------
    def transpose(self, d_an, a, *, gamma=None, beta=None, mean=None,
                  rstd=None) -> dict:
        """Declarative transpose rule: the cotangent of the normed A wrt the
        raw A and the norm parameters, computed row-locally.

        ``d_an`` is the (rows, K) cotangent the dA GEMM accumulated (grad wrt
        the normed activation); ``a`` is the matching raw A tile (fp32). On
        the recompute path the row statistics are re-derived from ``a`` and
        the full chain rule applies (the stats' own dependence on A is
        transposed too), so the tile must span the full feature dim — the
        same `check_blocks` rule the fwd obeys. On the fast path the
        streamed ``mean``/``rstd`` are independent operands (matching the
        oracle's autodiff) and get their own cotangents.

        Returns {'da': (rows, K)} plus, per spec: 'dgamma'/'dbeta' (1, K)
        row partials (summed over the tile's rows — the dA launch stores one
        partial per row block and a tiny jnp sum finishes the cross-block
        reduction) and fast-path 'dmean'/'drstd' (rows, 1) columns. The same
        code serves the kernel store and the jnp oracle.
        """
        if self.norm == "none":
            return {"da": d_an}
        out = {}
        if self.precomputed_stats:
            if self.norm == "rmsnorm":
                dahat = d_an * gamma
                out["da"] = dahat * rstd
                out["dgamma"] = jnp.sum(d_an * a * rstd, axis=0,
                                        keepdims=True)
                out["drstd"] = jnp.sum(dahat * a, axis=-1, keepdims=True)
                return out
            c = a - mean
            dahat = d_an * gamma
            out["da"] = dahat * rstd
            out["dgamma"] = jnp.sum(d_an * c * rstd, axis=0, keepdims=True)
            if self.beta:
                out["dbeta"] = jnp.sum(d_an, axis=0, keepdims=True)
            out["dmean"] = -jnp.sum(dahat * rstd, axis=-1, keepdims=True)
            out["drstd"] = jnp.sum(dahat * c, axis=-1, keepdims=True)
            return out
        if self.norm == "rmsnorm":
            var = jnp.mean(a * a, axis=-1, keepdims=True)
            rstd = jax.lax.rsqrt(var + self.eps)
            ahat = a * rstd
            dahat = d_an * gamma
            cterm = jnp.mean(dahat * ahat, axis=-1, keepdims=True)
            out["da"] = rstd * (dahat - ahat * cterm)
            out["dgamma"] = jnp.sum(d_an * ahat, axis=0, keepdims=True)
            return out
        mean = jnp.mean(a, axis=-1, keepdims=True)
        c = a - mean
        var = jnp.mean(c * c, axis=-1, keepdims=True)
        rstd = jax.lax.rsqrt(var + self.eps)
        chat = c * rstd
        dchat = d_an * gamma
        out["da"] = rstd * (dchat - jnp.mean(dchat, axis=-1, keepdims=True)
                            - chat * jnp.mean(dchat * chat, axis=-1,
                                              keepdims=True))
        out["dgamma"] = jnp.sum(d_an * chat, axis=0, keepdims=True)
        if self.beta:
            out["dbeta"] = jnp.sum(d_an, axis=0, keepdims=True)
        return out

    def grad_names(self) -> tuple:
        """The transpose rule's extra outputs, matching operand_names():
        'dgamma'[, 'dbeta'][, 'dmean', 'drstd'] in kernel output order."""
        return tuple("d" + n for n in self.operand_names())

    def describe(self) -> str:
        """Short tag for reports/benchmark rows, e.g. 'rmsnorm@rstd'."""
        if self.is_identity:
            return "none"
        tag = self.norm
        if self.beta:
            tag += "+beta"
        if self.precomputed_stats:
            tag += "@rstd"
        return tag


PROLOGUE_NONE = Prologue()


def norm_prologue(kind: str, *, beta: bool = False,
                  precomputed_stats: bool = False) -> Prologue:
    """The prologue matching a model config's ``norm`` field ('rmsnorm' |
    'layernorm'), with the reference eps for that kind."""
    return Prologue(norm=kind, beta=beta, precomputed_stats=precomputed_stats)
