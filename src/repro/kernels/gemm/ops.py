"""Public GEMM op: schedule/swizzle-aware dispatch with a reference path.

``mode``:
  * "reference"        — jnp.dot (used by the 512-device dry-run; XLA fuses)
  * "pallas_interpret" — the Pallas kernel, interpret=True (CPU validation)
  * "pallas_tpu"       — the Pallas kernel lowered for real TPUs
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, best_window
from repro.core.schedule import Schedule, PINGPONG
from .kernel import gemm_pallas
from .ref import gemm_ref


def _fit_block(dim: int, want: int, align: int) -> int:
    """Largest block ≤ want that divides dim and is ``align``-aligned."""
    want = min(want, dim)
    for cand in range(want - want % align, 0, -align):
        if dim % cand == 0:
            return cand
    if dim % align == 0:
        return align
    raise ValueError(f"dim {dim} not divisible by any {align}-aligned block")


def gemm(a, b, *, schedule: Schedule = PINGPONG,
         swizzle: SwizzleConfig | str | None = "auto",
         out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    if mode == "reference":
        return gemm_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm = _fit_block(m, schedule.block_m, 128)
    bn = _fit_block(n, schedule.block_n, 128)
    bk = _fit_block(k, schedule.block_k, 128)
    if swizzle == "auto":
        num_rows, num_cols = max(1, m // bm), max(1, n // bn)
        swizzle = best_window(num_rows, num_cols,
                              bm * k * a.dtype.itemsize,
                              k * bn * b.dtype.itemsize,
                              candidates=(1, 2, 4, 8, num_rows))
    elif swizzle is None:
        swizzle = ROW_MAJOR
    return gemm_pallas(a, b, block_m=bm, block_n=bn, block_k=bk,
                       swizzle=swizzle, out_dtype=out_dtype,
                       interpret=(mode == "pallas_interpret"))
