"""Public GEMM ops: policy-aware dispatch with a reference path.

``mode``:
  * "reference"        — jnp (used by the 512-device dry-run; XLA fuses)
  * "pallas_interpret" — the Pallas kernel, interpret=True (CPU validation)
  * "pallas_tpu"       — the Pallas kernel lowered for real TPUs

Policy resolution order (DESIGN.md §5): explicit ``policy`` > legacy
``schedule``/``swizzle`` keywords (deprecation shim) > the analytic autotuner
(``autotune.select_policy``, memoized per shape-bucket).

:func:`gemm_fused` is the megakernel entry point (DESIGN.md §9-§10): one
GEMM launch whose A tiles run a declarative :class:`Prologue`
(rmsnorm/layernorm as the operand streams in — producers never write the
normed activation) and whose store runs a declarative :class:`Epilogue`
chain — bias, activation, dual-output SwiGLU gating, residual add, fp8
dequant scale, and the QKV→RoPE rotation — so consumers never re-read the
activation from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, best_window
from repro.core.policy import KernelPolicy, make_policy
from repro.core.schedule import Schedule
from .epilogue import EPILOGUE_NONE, Epilogue
from .prologue import PROLOGUE_NONE, Prologue
from .kernel import _fit_block, _gemm_pallas, gemm_pallas
from .ref import gemm_fused_ref, gemm_ref


def _policy_from_schedule(schedule: Schedule, swizzle, m, n, k,
                          dtype) -> KernelPolicy:
    """Deprecation shim: fit a legacy Schedule's blocks to the problem and
    wrap them (plus the requested/auto swizzle) in an explicit policy."""
    import warnings
    warnings.warn(
        "gemm: the schedule=/swizzle= keywords are deprecated; pass "
        "policy=KernelPolicy(...) (or neither, to use the autotuner)",
        DeprecationWarning, stacklevel=3)
    bm = _fit_block(m, schedule.block_m, prefer=128)
    bn = _fit_block(n, schedule.block_n, prefer=128)
    bk = _fit_block(k, schedule.block_k, prefer=128)
    if swizzle == "auto":
        num_rows, num_cols = max(1, m // bm), max(1, n // bn)
        itemsize = jnp.dtype(dtype).itemsize
        swizzle = best_window(num_rows, num_cols, bm * k * itemsize,
                              k * bn * itemsize,
                              candidates=(1, 2, 4, 8, num_rows))
    elif swizzle is None:
        swizzle = ROW_MAJOR
    return make_policy("gemm", block_m=bm, block_n=bn, block_k=bk,
                       n_buffers=schedule.n_buffers, swizzle=swizzle,
                       name=f"shim_{schedule.name}")


def gemm(a, b, *, policy: KernelPolicy | None = None,
         schedule: Schedule | None = None,
         swizzle: SwizzleConfig | str | None = "auto",
         out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    if mode == "reference":
        return gemm_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        if schedule is not None or isinstance(swizzle, SwizzleConfig) or \
                swizzle is None:
            # legacy keyword surface -> explicit policy (deprecation shim)
            policy = _policy_from_schedule(
                schedule if schedule is not None else
                Schedule("pingpong", 2, 512, 512, 512),
                swizzle, m, n, k, a.dtype)
        else:
            policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype))
    return gemm_pallas(a, b, policy=policy, out_dtype=out_dtype,
                       interpret=(mode == "pallas_interpret"))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _gemm_fused(policy, out_dtype, interpret, epilogue, prologue, a, b,
                extras):
    return _gemm_pallas(a, b, *extras, policy=policy, out_dtype=out_dtype,
                        interpret=interpret, epilogue=epilogue,
                        prologue=prologue)


def _gemm_fused_fwd(policy, out_dtype, interpret, epilogue, prologue, a, b,
                    extras):
    out = _gemm_pallas(a, b, *extras, policy=policy, out_dtype=out_dtype,
                       interpret=interpret, epilogue=epilogue,
                       prologue=prologue)
    return out, (a, b, extras)


def _gemm_fused_bwd(policy, out_dtype, interpret, epilogue, prologue, res, g):
    """Backward = autodiff of the unfused jnp oracle (the fused prologue and
    store chain are short elementwise graphs whose VJPs XLA fuses well; the
    forward GEMMs are recomputed here, which the train path pays anyway
    under remat). Keeps the fused MLP/QKV paths — including the norm
    prologue's gamma/beta gradients — trainable without a hand-written
    chain transpose."""
    a, b, extras = res
    names = prologue.operand_names() + epilogue.operand_names()

    def ref_fn(a, b, extras):
        kw = dict(zip(names, extras))
        return gemm_fused_ref(a, b, epilogue=epilogue, prologue=prologue,
                              out_dtype=out_dtype, **kw)

    _, vjp = jax.vjp(ref_fn, a, b, extras)
    return vjp(g)


_gemm_fused.defvjp(_gemm_fused_fwd, _gemm_fused_bwd)


def gemm_fused(a, b, *, epilogue: Epilogue = EPILOGUE_NONE,
               prologue: Prologue = PROLOGUE_NONE, b2=None, bias=None,
               residual=None, scale=None, sin=None, cos=None,
               gamma=None, beta=None, mean=None, rstd=None,
               policy: KernelPolicy | None = None,
               out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    """C = epilogue(prologue(A) @ B) in one kernel launch (DESIGN.md §9-§10).

    Extra operands per epilogue flag: ``gate`` → ``b2`` (K, N) second weight
    (dual-output SwiGLU GEMM, C = act(A@B) * (A@B2)); ``bias`` → (N,);
    ``residual`` → (M, N); ``scale`` → scalar (fp8 dequant / residual
    scale); ``rope`` → ``sin``/``cos`` (M, head_dim) duplicated-halves
    tables (the fused QKV→RoPE rotation).

    Per prologue flag: any norm → ``gamma`` (K,) row scale; ``beta`` →
    (K,) layernorm bias row; ``precomputed_stats`` → ``rstd`` (M,) (and
    ``mean`` (M,) for layernorm) f32 row statistics (the fast path that
    keeps K-blocking; see Prologue.compute_stats).

    'reference' mode runs the unfused jnp oracle (full HBM round trips);
    the pallas modes run the prologue on each A tile as it streams in and
    the epilogue inside the kernel's final store. With ``policy=None`` the
    autotuner resolves a chain-aware policy (extra operands and the second
    accumulator count against the VMEM budget; a recompute-path norm
    prologue pins block_k to the full feature dim).
    """
    provided = dict(b2=b2, bias=bias, residual=residual, scale=scale,
                    sin=sin, cos=cos)
    pro_provided = dict(gamma=gamma, beta=beta, mean=mean, rstd=rstd)
    wanted = epilogue.operand_names()
    pro_wanted = prologue.operand_names()
    for name, val in provided.items():
        if (val is not None) != (name in wanted):
            raise ValueError(
                f"gemm_fused: operand {name!r} "
                f"{'missing for' if name in wanted else 'not accepted by'} "
                f"epilogue {epilogue.describe()!r}")
    for name, val in pro_provided.items():
        if (val is not None) != (name in pro_wanted):
            raise ValueError(
                f"gemm_fused: operand {name!r} "
                f"{'missing for' if name in pro_wanted else 'not accepted by'} "
                f"prologue {prologue.describe()!r}")
    if mode == "reference":
        return gemm_fused_ref(a, b, epilogue=epilogue, prologue=prologue,
                              b2=b2, bias=bias, residual=residual,
                              scale=scale, sin=sin, cos=cos, gamma=gamma,
                              beta=beta, mean=mean, rstd=rstd,
                              out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype),
                                        epilogue=epilogue, prologue=prologue)
    else:
        # two sources of truth: the explicit chain arguments must match the
        # chains the policy's legality/traffic accounting was done for
        if policy.epilogue is not None and policy.epilogue != epilogue:
            raise ValueError(
                f"gemm_fused: policy carries epilogue "
                f"{policy.epilogue.describe()!r} but the call passes "
                f"{epilogue.describe()!r}")
        if policy.prologue is not None and policy.prologue != prologue:
            raise ValueError(
                f"gemm_fused: policy carries prologue "
                f"{policy.prologue.describe()!r} but the call passes "
                f"{prologue.describe()!r}")
    extras = []
    for name in pro_wanted:
        val = pro_provided[name]
        if name in ("gamma", "beta"):
            val = jnp.asarray(val).reshape(1, -1)
        else:  # mean / rstd: (M, 1) f32 columns
            val = jnp.asarray(val, jnp.float32).reshape(-1, 1)
        extras.append(val)
    for name in wanted:
        val = provided[name]
        if name == "bias":
            val = jnp.asarray(val).reshape(1, -1)
        elif name == "scale":
            val = jnp.asarray(val, jnp.float32).reshape(1, 1)
        extras.append(val)
    return _gemm_fused(policy, out_dtype, mode == "pallas_interpret",
                       epilogue, prologue, a, b, tuple(extras))
