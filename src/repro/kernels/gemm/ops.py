"""Public GEMM ops: policy-aware dispatch with a reference path.

``mode``:
  * "reference"        — jnp (used by the 512-device dry-run; XLA fuses)
  * "pallas_interpret" — the Pallas kernel, interpret=True (CPU validation)
  * "pallas_tpu"       — the Pallas kernel lowered for real TPUs

Policy resolution order (DESIGN.md §5): explicit ``policy`` > legacy
``schedule``/``swizzle`` keywords (deprecation shim) > the analytic autotuner
(``autotune.select_policy``, memoized per shape-bucket).

:func:`gemm_fused` is the megakernel entry point (DESIGN.md §9): one GEMM
launch whose store runs a declarative :class:`Epilogue` chain — bias,
activation, dual-output SwiGLU gating, residual add, fp8 dequant scale, and
the QKV→RoPE prologue rotation — so consumers never re-read the activation
from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, best_window
from repro.core.policy import KernelPolicy, make_policy
from repro.core.schedule import Schedule
from .epilogue import EPILOGUE_NONE, Epilogue
from .kernel import _fit_block, _gemm_pallas, gemm_pallas
from .ref import gemm_fused_ref, gemm_ref


def _policy_from_schedule(schedule: Schedule, swizzle, m, n, k,
                          dtype) -> KernelPolicy:
    """Deprecation shim: fit a legacy Schedule's blocks to the problem and
    wrap them (plus the requested/auto swizzle) in an explicit policy."""
    import warnings
    warnings.warn(
        "gemm: the schedule=/swizzle= keywords are deprecated; pass "
        "policy=KernelPolicy(...) (or neither, to use the autotuner)",
        DeprecationWarning, stacklevel=3)
    bm = _fit_block(m, schedule.block_m, prefer=128)
    bn = _fit_block(n, schedule.block_n, prefer=128)
    bk = _fit_block(k, schedule.block_k, prefer=128)
    if swizzle == "auto":
        num_rows, num_cols = max(1, m // bm), max(1, n // bn)
        itemsize = jnp.dtype(dtype).itemsize
        swizzle = best_window(num_rows, num_cols, bm * k * itemsize,
                              k * bn * itemsize,
                              candidates=(1, 2, 4, 8, num_rows))
    elif swizzle is None:
        swizzle = ROW_MAJOR
    return make_policy("gemm", block_m=bm, block_n=bn, block_k=bk,
                       n_buffers=schedule.n_buffers, swizzle=swizzle,
                       name=f"shim_{schedule.name}")


def gemm(a, b, *, policy: KernelPolicy | None = None,
         schedule: Schedule | None = None,
         swizzle: SwizzleConfig | str | None = "auto",
         out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    if mode == "reference":
        return gemm_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        if schedule is not None or isinstance(swizzle, SwizzleConfig) or \
                swizzle is None:
            # legacy keyword surface -> explicit policy (deprecation shim)
            policy = _policy_from_schedule(
                schedule if schedule is not None else
                Schedule("pingpong", 2, 512, 512, 512),
                swizzle, m, n, k, a.dtype)
        else:
            policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype))
    return gemm_pallas(a, b, policy=policy, out_dtype=out_dtype,
                       interpret=(mode == "pallas_interpret"))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _gemm_fused(policy, out_dtype, interpret, epilogue, a, b, extras):
    return _gemm_pallas(a, b, *extras, policy=policy, out_dtype=out_dtype,
                        interpret=interpret, epilogue=epilogue)


def _gemm_fused_fwd(policy, out_dtype, interpret, epilogue, a, b, extras):
    out = _gemm_pallas(a, b, *extras, policy=policy, out_dtype=out_dtype,
                       interpret=interpret, epilogue=epilogue)
    return out, (a, b, extras)


def _gemm_fused_bwd(policy, out_dtype, interpret, epilogue, res, g):
    """Backward = autodiff of the unfused jnp oracle (the fused store chain
    is a short elementwise graph whose VJP XLA fuses well; the forward
    GEMMs are recomputed here, which the train path pays anyway under
    remat). Keeps the fused MLP/QKV paths trainable without a hand-written
    chain transpose."""
    a, b, extras = res

    def ref_fn(a, b, extras):
        kw = dict(zip(epilogue.operand_names(), extras))
        return gemm_fused_ref(a, b, epilogue=epilogue, out_dtype=out_dtype,
                              **kw)

    _, vjp = jax.vjp(ref_fn, a, b, extras)
    return vjp(g)


_gemm_fused.defvjp(_gemm_fused_fwd, _gemm_fused_bwd)


def gemm_fused(a, b, *, epilogue: Epilogue, b2=None, bias=None, residual=None,
               scale=None, sin=None, cos=None,
               policy: KernelPolicy | None = None,
               out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    """C = epilogue(A @ B) in one kernel launch (DESIGN.md §9).

    Extra operands per epilogue flag: ``gate`` → ``b2`` (K, N) second weight
    (dual-output SwiGLU GEMM, C = act(A@B) * (A@B2)); ``bias`` → (N,);
    ``residual`` → (M, N); ``scale`` → scalar (fp8 dequant / residual
    scale); ``rope`` → ``sin``/``cos`` (M, head_dim) duplicated-halves
    tables (the fused QKV→RoPE prologue).

    'reference' mode runs the unfused jnp oracle (full HBM round trips);
    the pallas modes run the chain inside the kernel's final store. With
    ``policy=None`` the autotuner resolves an epilogue-aware policy (extra
    operands and the second accumulator count against the VMEM budget).
    """
    provided = dict(b2=b2, bias=bias, residual=residual, scale=scale,
                    sin=sin, cos=cos)
    wanted = epilogue.operand_names()
    for name, val in provided.items():
        if (val is not None) != (name in wanted):
            raise ValueError(
                f"gemm_fused: operand {name!r} "
                f"{'missing for' if name in wanted else 'not accepted by'} "
                f"epilogue {epilogue.describe()!r}")
    if mode == "reference":
        return gemm_fused_ref(a, b, epilogue=epilogue, b2=b2, bias=bias,
                              residual=residual, scale=scale, sin=sin,
                              cos=cos, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype),
                                        epilogue=epilogue)
    elif policy.epilogue is not None and policy.epilogue != epilogue:
        # two sources of truth: the explicit chain argument must match the
        # chain the policy's legality/traffic accounting was done for
        raise ValueError(
            f"gemm_fused: policy carries epilogue "
            f"{policy.epilogue.describe()!r} but the call passes "
            f"{epilogue.describe()!r}")
    extras = []
    for name in wanted:
        val = provided[name]
        if name == "bias":
            val = jnp.asarray(val).reshape(1, -1)
        elif name == "scale":
            val = jnp.asarray(val, jnp.float32).reshape(1, 1)
        extras.append(val)
    return _gemm_fused(policy, out_dtype, mode == "pallas_interpret",
                       epilogue, a, b, tuple(extras))
