"""Public GEMM op: policy-aware dispatch with a reference path.

``mode``:
  * "reference"        — jnp.dot (used by the 512-device dry-run; XLA fuses)
  * "pallas_interpret" — the Pallas kernel, interpret=True (CPU validation)
  * "pallas_tpu"       — the Pallas kernel lowered for real TPUs

Policy resolution order (DESIGN.md §5): explicit ``policy`` > legacy
``schedule``/``swizzle`` keywords (deprecation shim) > the analytic autotuner
(``autotune.select_policy``, memoized per shape-bucket).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import autotune
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, best_window
from repro.core.policy import KernelPolicy, make_policy
from repro.core.schedule import Schedule
from .kernel import gemm_pallas
from .ref import gemm_ref


def _fit_block(dim: int, want: int, align: int) -> int:
    """Largest block ≤ want that divides dim and is ``align``-aligned."""
    want = min(want, dim)
    for cand in range(want - want % align, 0, -align):
        if dim % cand == 0:
            return cand
    if dim % align == 0:
        return align
    raise ValueError(f"dim {dim} not divisible by any {align}-aligned block")


def _policy_from_schedule(schedule: Schedule, swizzle, m, n, k,
                          dtype) -> KernelPolicy:
    """Deprecation shim: fit a legacy Schedule's blocks to the problem and
    wrap them (plus the requested/auto swizzle) in an explicit policy."""
    import warnings
    warnings.warn(
        "gemm: the schedule=/swizzle= keywords are deprecated; pass "
        "policy=KernelPolicy(...) (or neither, to use the autotuner)",
        DeprecationWarning, stacklevel=3)
    bm = _fit_block(m, schedule.block_m, 128)
    bn = _fit_block(n, schedule.block_n, 128)
    bk = _fit_block(k, schedule.block_k, 128)
    if swizzle == "auto":
        num_rows, num_cols = max(1, m // bm), max(1, n // bn)
        itemsize = jnp.dtype(dtype).itemsize
        swizzle = best_window(num_rows, num_cols, bm * k * itemsize,
                              k * bn * itemsize,
                              candidates=(1, 2, 4, 8, num_rows))
    elif swizzle is None:
        swizzle = ROW_MAJOR
    return make_policy("gemm", block_m=bm, block_n=bn, block_k=bk,
                       n_buffers=schedule.n_buffers, swizzle=swizzle,
                       name=f"shim_{schedule.name}")


def gemm(a, b, *, policy: KernelPolicy | None = None,
         schedule: Schedule | None = None,
         swizzle: SwizzleConfig | str | None = "auto",
         out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    if mode == "reference":
        return gemm_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        if schedule is not None or isinstance(swizzle, SwizzleConfig) or \
                swizzle is None:
            # legacy keyword surface -> explicit policy (deprecation shim)
            policy = _policy_from_schedule(
                schedule if schedule is not None else
                Schedule("pingpong", 2, 512, 512, 512),
                swizzle, m, n, k, a.dtype)
        else:
            policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype))
    return gemm_pallas(a, b, policy=policy, out_dtype=out_dtype,
                       interpret=(mode == "pallas_interpret"))
