"""Public GEMM ops: policy-aware dispatch with a reference path.

``mode``:
  * "reference"        — jnp (used by the 512-device dry-run; XLA fuses)
  * "pallas_interpret" — the Pallas kernel, interpret=True (CPU validation)
  * "pallas_tpu"       — the Pallas kernel lowered for real TPUs

Policy resolution order (DESIGN.md §5): explicit ``policy`` > legacy
``schedule``/``swizzle`` keywords (deprecation shim) > the analytic autotuner
(``autotune.select_policy``, memoized per shape-bucket).

:func:`gemm_fused` is the megakernel entry point (DESIGN.md §9-§10): one
GEMM launch whose A tiles run a declarative :class:`Prologue`
(rmsnorm/layernorm as the operand streams in — producers never write the
normed activation) and whose store runs a declarative :class:`Epilogue`
chain — bias, activation, dual-output SwiGLU gating, residual add, fp8
dequant scale, and the QKV→RoPE rotation — so consumers never re-read the
activation from HBM.
"""
from __future__ import annotations

import contextlib
import functools
import time
import warnings

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import autotune
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, best_window
from repro.core.policy import KernelPolicy, make_policy
from repro.core.schedule import Schedule
from .epilogue import EPILOGUE_NONE, Epilogue
from .prologue import PROLOGUE_NONE, Prologue
from .kernel import _fit_block, _gemm_pallas, gemm_pallas
from .ref import gemm_fused_ref, gemm_ref

_DEPRECATION_MSG = (
    "gemm: the schedule=/swizzle= keywords are deprecated; pass "
    "policy=KernelPolicy(...) (or neither, to use the autotuner)")


def _policy_from_schedule(schedule: Schedule, swizzle, m, n, k,
                          dtype) -> KernelPolicy:
    """Deprecation shim: fit a legacy Schedule's blocks to the problem and
    wrap them (plus the requested/auto swizzle) in an explicit policy."""
    warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=3)
    bm = _fit_block(m, schedule.block_m, prefer=128)
    bn = _fit_block(n, schedule.block_n, prefer=128)
    bk = _fit_block(k, schedule.block_k, prefer=128)
    if swizzle == "auto":
        num_rows, num_cols = max(1, m // bm), max(1, n // bn)
        itemsize = jnp.dtype(dtype).itemsize
        swizzle = best_window(num_rows, num_cols, bm * k * itemsize,
                              k * bn * itemsize,
                              candidates=(1, 2, 4, 8, num_rows))
    elif swizzle is None:
        swizzle = ROW_MAJOR
    return make_policy("gemm", block_m=bm, block_n=bn, block_k=bk,
                       n_buffers=schedule.n_buffers, swizzle=swizzle,
                       name=f"shim_{schedule.name}")


def _policy_from_swizzle(swizzle, m, n, k, dtype) -> KernelPolicy:
    """Deprecation shim for swizzle-only legacy calls: rank the autotuner's
    candidate set restricted to the requested traversal order, instead of
    pinning the old hard-coded pingpong-512 schedule (which silently leaned
    on the _fit_policy clamp for every small-M/N/K problem)."""
    warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=3)
    return autotune.select_policy(
        "gemm", (m, n, k), str(dtype),
        swizzle=swizzle if swizzle is not None else ROW_MAJOR)


def gemm(a, b, *, policy: KernelPolicy | None = None,
         schedule: Schedule | None = None,
         swizzle: SwizzleConfig | str | None = "auto",
         out_dtype=jnp.bfloat16, mode: str = "pallas_interpret"):
    if mode == "reference":
        return gemm_ref(a, b, out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        if schedule is not None:
            # legacy keyword surface -> explicit policy (deprecation shim)
            policy = _policy_from_schedule(schedule, swizzle, m, n, k,
                                           a.dtype)
        elif isinstance(swizzle, SwizzleConfig) or swizzle is None:
            # swizzle-only legacy surface -> autotuned blocks under the
            # requested traversal order
            policy = _policy_from_swizzle(swizzle, m, n, k, a.dtype)
        else:
            policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype))
    if obs.enabled():
        obs.launch("gemm",
                   grid=(max(1, m // policy.block_m),
                         max(1, n // policy.block_n)),
                   policy=policy, flops=2 * m * n * k,
                   dma_bytes=autotune.gemm_traffic_bytes(
                       policy, m, n, k, jnp.dtype(a.dtype).itemsize))
    return gemm_pallas(a, b, policy=policy, out_dtype=out_dtype,
                       interpret=(mode == "pallas_interpret"))


# Default backward path for gemm_fused (DESIGN.md §11): 'kernel' runs the
# hand-written chain transpose as fused Pallas launches; 'reference' keeps
# the jnp-oracle recompute VJP as the grad oracle.
BWD_MODES = ("kernel", "reference", "auto")
_DEFAULT_BWD_MODE = ["kernel"]


@contextlib.contextmanager
def default_bwd_mode(mode: str):
    """Temporarily override the backward path used by gemm_fused calls that
    don't pass ``bwd_mode`` (i.e. every model layer) — the lever the parity
    tests and benchmarks use to pit the kernel-side fused backward against
    the oracle-recompute VJP on identical graphs."""
    if mode not in BWD_MODES:
        raise ValueError(f"unknown bwd_mode {mode!r}; have {BWD_MODES}")
    prev = _DEFAULT_BWD_MODE[0]
    _DEFAULT_BWD_MODE[0] = mode
    try:
        yield
    finally:
        _DEFAULT_BWD_MODE[0] = prev


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _gemm_fused(policy, out_dtype, interpret, epilogue, prologue, bwd_mode,
                a, b, extras):
    return _gemm_pallas(a, b, *extras, policy=policy, out_dtype=out_dtype,
                        interpret=interpret, epilogue=epilogue,
                        prologue=prologue)


def _gemm_fused_fwd(policy, out_dtype, interpret, epilogue, prologue,
                    bwd_mode, a, b, extras):
    """Differentiated fwd: under the kernel bwd path the launch additionally
    stores the raw accumulator(s) the chain transpose needs (rounded through
    the MXU input dtype — see Epilogue.needs_saved_preact), and the output
    rides the residuals when the rope-table cotangents must invert the
    rotation from it. When no legal gemm_bwd policy exists for this shape
    (the bwd will fall back to the oracle VJP), nothing extra is stored."""
    save = bwd_mode == "kernel" and epilogue.saved_accumulators > 0
    if save:
        from . import backward

        m, k = a.shape
        n = b.shape[1]
        save = backward.bwd_policies_available(policy, m, n, k, a.dtype,
                                               epilogue, prologue)
    if save:
        out, *preacts = _gemm_pallas(a, b, *extras, policy=policy,
                                     out_dtype=out_dtype,
                                     interpret=interpret, epilogue=epilogue,
                                     prologue=prologue, save_preact=True)
    else:
        out = _gemm_pallas(a, b, *extras, policy=policy, out_dtype=out_dtype,
                           interpret=interpret, epilogue=epilogue,
                           prologue=prologue)
        preacts = []
    keep_out = out if (bwd_mode == "kernel" and epilogue.rope) else None
    return out, (a, b, extras, tuple(preacts), keep_out)


def _gemm_fused_bwd(policy, out_dtype, interpret, epilogue, prologue,
                    bwd_mode, res, g):
    """Backward dispatch (DESIGN.md §11).

    'kernel' (default): the hand-written chain transpose — dA = gbar@Bᵀ and
    dB = Anᵀ@gbar run as fused Pallas launches with the transposed epilogue
    applied to the g tiles as they stream in and the norm prologue
    recomputed tile-wise (kernels/gemm/backward.py).

    'reference': autodiff of the unfused jnp oracle (forward recompute,
    remat-style) — kept as the grad oracle the kernel path is tested
    against, and as the remat-friendly fallback.
    """
    a, b, extras, preacts, out = res

    def oracle_vjp():
        names = prologue.operand_names() + epilogue.operand_names()

        def ref_fn(a, b, extras):
            kw = dict(zip(names, extras))
            return gemm_fused_ref(a, b, epilogue=epilogue, prologue=prologue,
                                  out_dtype=out_dtype, **kw)

        _, vjp = jax.vjp(ref_fn, a, b, extras)
        return vjp(g)

    if bwd_mode == "reference":
        return oracle_vjp()
    from . import backward

    m, k = a.shape
    n = b.shape[1]
    try:
        policies = backward.resolve_bwd_policies(policy, m, n, k, a.dtype,
                                                 epilogue, prologue)
    except ValueError:
        # no VMEM-legal gemm_bwd policy for this shape (e.g. the norm
        # transpose's full-K tiles at huge feature dims) — the same
        # legality signal the fwd fusion ladder falls back on. The bwd
        # must handle every shape the fwd legally engaged, so fall back
        # to the oracle-recompute VJP (raised at trace time only). The
        # catch is deliberately narrow: errors from the launches
        # themselves are bugs and must surface, not reroute silently.
        return oracle_vjp()
    return backward.gemm_fused_bwd(a, b, extras, preacts, out, g,
                                   policy=policy, epilogue=epilogue,
                                   prologue=prologue, interpret=interpret,
                                   policies=policies)


_gemm_fused.defvjp(_gemm_fused_fwd, _gemm_fused_bwd)


def gemm_fused(a, b, *, epilogue: Epilogue = EPILOGUE_NONE,
               prologue: Prologue = PROLOGUE_NONE, b2=None, bias=None,
               residual=None, scale=None, sin=None, cos=None,
               gamma=None, beta=None, mean=None, rstd=None,
               policy: KernelPolicy | None = None,
               out_dtype=jnp.bfloat16, mode: str = "pallas_interpret",
               bwd_mode: str | None = None):
    """C = epilogue(prologue(A) @ B) in one kernel launch (DESIGN.md §9-§10).

    Extra operands per epilogue flag: ``gate`` → ``b2`` (K, N) second weight
    (dual-output SwiGLU GEMM, C = act(A@B) * (A@B2)); ``bias`` → (N,);
    ``residual`` → (M, N); ``scale`` → scalar, (M, 1) row or (1, N) column
    per ``scale_kind`` (fp8 dequant — per-tensor or per-channel — and the
    residual scale); ``rope`` → ``sin``/``cos`` (M, head_dim)
    duplicated-halves tables (the fused QKV→RoPE rotation).

    ``bwd_mode`` picks the ``jax.grad`` path (DESIGN.md §11): ``"kernel"``
    (the default, overridable via :func:`default_bwd_mode`) runs the
    hand-written chain transpose as fused Pallas launches — both bwd GEMMs
    with the transposed epilogue as a prologue on g and the norm recomputed
    tile-wise; ``"reference"`` keeps the jnp-oracle recompute VJP (the grad
    oracle); ``"auto"`` routes per shape bucket via
    ``autotune.select_bwd_mode`` (docs/autotuning.md) — kernel on
    train-shaped cells, oracle on degenerate ones. 'reference' *mode*
    always differentiates the oracle directly.

    Per prologue flag: any norm → ``gamma`` (K,) row scale; ``beta`` →
    (K,) layernorm bias row; ``precomputed_stats`` → ``rstd`` (M,) (and
    ``mean`` (M,) for layernorm) f32 row statistics (the fast path that
    keeps K-blocking; see Prologue.compute_stats).

    'reference' mode runs the unfused jnp oracle (full HBM round trips);
    the pallas modes run the prologue on each A tile as it streams in and
    the epilogue inside the kernel's final store. With ``policy=None`` the
    autotuner resolves a chain-aware policy (extra operands and the second
    accumulator count against the VMEM budget; a recompute-path norm
    prologue pins block_k to the full feature dim).
    """
    provided = dict(b2=b2, bias=bias, residual=residual, scale=scale,
                    sin=sin, cos=cos)
    pro_provided = dict(gamma=gamma, beta=beta, mean=mean, rstd=rstd)
    wanted = epilogue.operand_names()
    pro_wanted = prologue.operand_names()
    for name, val in provided.items():
        if (val is not None) != (name in wanted):
            raise ValueError(
                f"gemm_fused: operand {name!r} "
                f"{'missing for' if name in wanted else 'not accepted by'} "
                f"epilogue {epilogue.describe()!r}")
    for name, val in pro_provided.items():
        if (val is not None) != (name in pro_wanted):
            raise ValueError(
                f"gemm_fused: operand {name!r} "
                f"{'missing for' if name in pro_wanted else 'not accepted by'} "
                f"prologue {prologue.describe()!r}")
    if mode == "reference":
        return gemm_fused_ref(a, b, epilogue=epilogue, prologue=prologue,
                              b2=b2, bias=bias, residual=residual,
                              scale=scale, sin=sin, cos=cos, gamma=gamma,
                              beta=beta, mean=mean, rstd=rstd,
                              out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    if policy is None:
        policy = autotune.select_policy("gemm", (m, n, k), str(a.dtype),
                                        epilogue=epilogue, prologue=prologue)
    else:
        # two sources of truth: the explicit chain arguments must match the
        # chains the policy's legality/traffic accounting was done for
        if policy.epilogue is not None and policy.epilogue != epilogue:
            raise ValueError(
                f"gemm_fused: policy carries epilogue "
                f"{policy.epilogue.describe()!r} but the call passes "
                f"{epilogue.describe()!r}")
        if policy.prologue is not None and policy.prologue != prologue:
            raise ValueError(
                f"gemm_fused: policy carries prologue "
                f"{policy.prologue.describe()!r} but the call passes "
                f"{prologue.describe()!r}")
    extras = []
    for name in pro_wanted:
        val = pro_provided[name]
        if name in ("gamma", "beta"):
            val = jnp.asarray(val).reshape(1, -1)
        else:  # mean / rstd: (M, 1) f32 columns
            val = jnp.asarray(val, jnp.float32).reshape(-1, 1)
        extras.append(val)
    for name in wanted:
        val = provided[name]
        if name == "bias":
            val = jnp.asarray(val).reshape(1, -1)
        elif name == "scale":
            val = jnp.asarray(val, jnp.float32)
            if epilogue.scale_kind == "row":
                val = val.reshape(-1, 1)    # (M, 1) per-row dequant
            elif epilogue.scale_kind == "col":
                val = val.reshape(1, -1)    # (1, N) per-channel dequant
            else:
                val = val.reshape(1, 1)
        extras.append(val)
    if bwd_mode is None:
        bwd_mode = _DEFAULT_BWD_MODE[0]
    if bwd_mode not in BWD_MODES:
        raise ValueError(f"unknown bwd_mode {bwd_mode!r}; have {BWD_MODES}")
    if bwd_mode == "auto":
        # plan-aware routing (DESIGN.md §15): the roofline + peak-memory
        # model sends degenerate cells (tiny-K: saved preacts dominate) to
        # the oracle VJP and train-shaped cells to the fused kernel bwd.
        # Journaled as a 'bwd_route' plan decision, memoized per bucket.
        bwd_mode = autotune.select_bwd_mode(m, n, k, dtype=str(a.dtype),
                                            epilogue=epilogue,
                                            prologue=prologue)
    timing = obs.timing_enabled()
    t0 = time.perf_counter() if timing else 0.0
    out = _gemm_fused(policy, out_dtype, mode == "pallas_interpret",
                      epilogue, prologue, bwd_mode, a, b, tuple(extras))
    if obs.enabled():
        wall = None
        if timing:
            jax.block_until_ready(out)
            wall = time.perf_counter() - t0
        obs.launch("gemm_fused", variant=bwd_mode,
                   grid=(max(1, m // policy.block_m),
                         max(1, n // policy.block_n)),
                   policy=policy,
                   chain=f"{prologue.describe()}|{epilogue.describe()}",
                   dma_bytes=autotune.gemm_traffic_bytes(
                       policy, m, n, k, jnp.dtype(a.dtype).itemsize),
                   flops=(2 if epilogue.gate else 1) * 2 * m * n * k,
                   wall_s=wall)
    return out
