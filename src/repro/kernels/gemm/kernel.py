"""Blocked GEMM Pallas kernel with Algorithm-1 grid swizzling (paper §3.4, E.1)
and a fused epilogue/prologue store (DESIGN.md §9).

Structure mirrors the paper's BF16 GEMM listing (Fig. 21), TPU-adapted:
  * the thread-block output tile        → the per-grid-step output block
  * the 8-wave ping-pong double buffer  → the Pallas grid pipeline (the
    policy's ``n_buffers`` deep — PINGPONG=2, INTERLEAVE=3)
  * chiplet_transform_chunked + window  → the same Algorithm 1 permutation,
    applied in the BlockSpec index_maps so traversal order (and with it the
    DMA revisit pattern) matches the policy's SwizzleConfig
  * pinned AGPR accumulators            → pinned fp32 VMEM scratch accumulator
    (two of them for the dual-output SwiGLU GEMM)

The final ``@pl.when(k == nk-1)`` store runs the policy's
:class:`~repro.kernels.gemm.epilogue.Epilogue` chain (bias, activation,
gated multiply, residual, dequant scale, RoPE rotation) on the fp32
accumulator while it is still VMEM-resident — the whole point of the fused
megakernel paths: consumers never re-read the activation from HBM. The
symmetric load side is the :class:`~repro.kernels.gemm.prologue.Prologue`:
each A tile is row-normalized (rmsnorm/layernorm) in fp32 as it streams in,
so producers never *write* the normed activation either (DESIGN.md §10).

Every grid/BlockSpec dimension here is derived from a
:class:`~repro.core.policy.KernelPolicy`; the old ``block_m/n/k`` + ``swizzle``
keywords survive as a deprecation shim that builds an explicit policy.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tiles
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR
from repro.core.policy import KernelPolicy, resolve_policy
from .epilogue import EPILOGUE_NONE, Epilogue
from .prologue import PROLOGUE_NONE, Prologue


def _upcast(x):
    """fp8 operands feed the MXU as bf16 (exactly representable)."""
    return x.astype(jnp.bfloat16) if x.dtype.itemsize == 1 else x


def epilogue_f32_kwargs(epilogue: Epilogue, extras: dict, *,
                        residual: bool = False) -> dict:
    """Read an epilogue's extra-operand refs as the fp32 kwargs its
    ``apply``/``transpose_tile`` expect (scalar scale unwraps to a rank-0
    value, vector kinds stay blocks). One helper serves the fwd store and
    both bwd launches so the operand conventions cannot drift."""
    kw = {}
    if epilogue.bias:
        kw["bias"] = extras["bias"][...].astype(jnp.float32)
    if residual and epilogue.residual:
        kw["residual"] = extras["residual"][...].astype(jnp.float32)
    if epilogue.scale:
        kw["scale"] = (extras["scale"][0, 0]
                       if epilogue.scale_kind == "scalar"
                       else extras["scale"][...].astype(jnp.float32))
    if epilogue.rope:
        kw["sin"] = extras["sin"][...].astype(jnp.float32)
        kw["cos"] = extras["cos"][...].astype(jnp.float32)
    return kw


def prologue_f32_kwargs(prologue: Prologue, extras: dict) -> dict:
    """Read a prologue's gamma/beta rows and fast-path stats columns as the
    fp32 kwargs ``apply``/``transpose`` expect — shared with the bwd
    launches like :func:`epilogue_f32_kwargs`."""
    kw = {"gamma": extras["gamma"][...].astype(jnp.float32)}
    if prologue.beta:
        kw["beta"] = extras["beta"][...].astype(jnp.float32)
    if prologue.precomputed_stats:
        if prologue.norm == "layernorm":
            kw["mean"] = extras["mean"][...]
        kw["rstd"] = extras["rstd"][...]
    return kw


def _gemm_kernel(*refs, nk: int, out_dtype, epilogue: Epilogue,
                 prologue: Prologue, save_preact: bool = False):
    """refs: a, b, *extra inputs (prologue then epilogue operand_names()
    order), o[, preact[, preact2]], acc[, acc2]. The optional preact
    outputs store the raw fp32 accumulator(s) rounded through the MXU
    input dtype — the residuals the kernel-side fused backward streams
    (DESIGN.md §11); they exist only on differentiated fwd launches."""
    refs = list(refs)
    a_ref, b_ref = refs[0], refs[1]
    names = prologue.operand_names() + epilogue.operand_names()
    extras = dict(zip(names, refs[2:2 + len(names)]))
    gate = epilogue.gate
    rest = refs[2 + len(names):]
    n_out = 1 + (epilogue.saved_accumulators if save_preact else 0)
    o_ref, preact_refs = rest[0], rest[1:n_out]
    scratch = rest[n_out:]
    acc_ref = scratch[0]
    acc2_ref = scratch[1] if gate else None

    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if gate:
            acc2_ref[...] = jnp.zeros_like(acc2_ref)

    a = _upcast(a_ref[...])
    if not prologue.is_identity:
        # load-side norm: the A tile is normalized in fp32 while VMEM-resident
        # (row stats recomputed from the full-K tile, or streamed on the fast
        # path), then fed to the MXU in the input dtype — the normed
        # activation never round-trips HBM (DESIGN.md §10).
        a = prologue.apply(a.astype(jnp.float32),
                           **prologue_f32_kwargs(prologue, extras)
                           ).astype(a.dtype)
    acc_ref[...] += jnp.dot(a, _upcast(b_ref[...]),
                            preferred_element_type=jnp.float32)
    if gate:
        acc2_ref[...] += jnp.dot(a, _upcast(extras["b2"][...]),
                                 preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        kw = epilogue_f32_kwargs(epilogue, extras, residual=True)
        out = epilogue.apply(acc_ref[...],
                             acc2_ref[...] if gate else None, **kw)
        o_ref[...] = out.astype(out_dtype)
        if preact_refs:
            preact_refs[0][...] = acc_ref[...].astype(preact_refs[0].dtype)
            if gate:
                preact_refs[1][...] = acc2_ref[...].astype(
                    preact_refs[1].dtype)


def _fit_block(dim: int, want: int, multiple: int = 1,
               prefer: int = 1) -> int:
    """Largest block ≤ ``want`` that divides ``dim``, is a ``multiple``
    multiple (hard constraint, e.g. rope's whole-head rule), and — when one
    exists — a ``prefer`` multiple (soft native-alignment preference; a
    problem dim with no aligned divisor is itself unaligned, which waives
    tiles.block_spec's strict gate). Always succeeds: 1 divides everything,
    and every rope-constrained n is itself a head_dim multiple."""
    want = max(1, min(want, dim))
    soft = multiple * prefer // math.gcd(multiple, prefer)  # lcm
    for req in (soft, multiple):
        for b in range(want, 0, -1):
            if dim % b == 0 and b % req == 0:
                return b
    return dim


def _fit_policy(policy: KernelPolicy, m: int, n: int, k: int,
                epilogue: Epilogue = EPILOGUE_NONE,
                prologue: Prologue = PROLOGUE_NONE) -> tuple:
    """Clamp the policy's blocks to the largest divisor blocks of the problem.

    A policy tuned for one shape-bucket stays usable on any shape: blocks
    shrink to the largest divisor ≤ the tuned block instead of raising on
    non-divisible problems (the autotuner emits exact-divisor candidates, so
    tuned launches never pay the shrink). Lane/sublane-aligned divisors are
    preferred (bk/bn sit in a block minor dim, bm only in sublane rows);
    the rope epilogue additionally pins block_n to whole heads, and a
    recompute-path norm prologue pins block_k to the full feature dim.
    """
    n_multiple = epilogue.head_dim if epilogue.rope else 1
    bm = _fit_block(m, policy.block_m, prefer=32)          # max sublane
    bn = _fit_block(n, policy.block_n, n_multiple, prefer=tiles.LANE)
    bk = k if prologue.needs_full_k else \
        _fit_block(k, policy.block_k, prefer=tiles.LANE)
    epilogue.check_blocks(bn)
    prologue.check_blocks(bk, k)
    return bm, bn, bk


def mxu_input_dtype(dtype):
    """The dtype operands feed the MXU with (fp8 upcasts to bf16). Saved
    preactivations round through this — exact for fp32 launches, one bf16
    rounding (the same the operands already paid) otherwise."""
    return jnp.bfloat16 if jnp.dtype(dtype).itemsize == 1 else jnp.dtype(dtype)


@functools.partial(jax.jit,
                   static_argnames=("policy", "out_dtype", "interpret",
                                    "epilogue", "prologue", "save_preact"))
def _gemm_pallas(a: jax.Array, b: jax.Array, *extras, policy: KernelPolicy,
                 out_dtype, interpret: bool,
                 epilogue: Epilogue = EPILOGUE_NONE,
                 prologue: Prologue = PROLOGUE_NONE,
                 save_preact: bool = False):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    names = prologue.operand_names() + epilogue.operand_names()
    assert len(extras) == len(names), (names, len(extras))
    block_m, block_n, block_k = _fit_policy(policy, m, n, k, epilogue,
                                            prologue)
    num_rows, num_cols, nk = m // block_m, n // block_n, k // block_k
    swizzle = policy.swizzle

    # Tab. 2 feasibility rule at the policy's pipeline depth, including the
    # prologue/epilogue extra streamed blocks and second accumulator.
    tiles.check_vmem_budget(
        [((block_m, block_k), a.dtype), ((block_k, block_n), b.dtype)]
        + prologue.extra_operand_blocks(block_m, block_k, str(a.dtype))
        + epilogue.extra_operand_blocks(block_m, block_n, block_k,
                                        str(a.dtype)),
        n_buffers=policy.n_buffers,
        scratch_bytes=epilogue.n_accumulators * block_m * block_n * 4,
        what="gemm")

    def row_col(i):
        return swizzle.remap(i, num_rows, num_cols)

    def a_map(i, kk):
        r, _ = row_col(i)
        return (r, kk)

    def b_map(i, kk):
        _, c = row_col(i)
        return (kk, c)

    def o_map(i, kk):
        r, c = row_col(i)
        return (r, c)

    def row_map(i, kk):
        r, _ = row_col(i)
        return (r, 0)

    def col_map(i, kk):
        _, c = row_col(i)
        return (0, c)

    def k_map(i, kk):
        return (0, kk)

    in_specs = [
        tiles.block_spec((block_m, block_k), a_map, a.dtype,
                         allow_ragged_minor=tiles.shape_ragged(
                             m, k, a.dtype)),
        tiles.block_spec((block_k, block_n), b_map, b.dtype,
                         allow_ragged_minor=tiles.shape_ragged(
                             k, n, b.dtype)),
    ]
    for name, arr in zip(names, extras):
        if name in ("gamma", "beta"):
            # prologue row vectors: the kk-th (1, block_k) slice streams
            # alongside the A tile it normalizes
            spec = tiles.block_spec((1, block_k), k_map, arr.dtype,
                                    allow_ragged_minor=True)
        elif name in ("mean", "rstd"):
            # fast-path row stats: one (block_m, 1) f32 column per row block
            spec = tiles.block_spec((block_m, 1), row_map, arr.dtype,
                                    allow_ragged_minor=True)
        elif name == "b2":
            spec = tiles.block_spec((block_k, block_n), b_map, arr.dtype,
                                    allow_ragged_minor=tiles.shape_ragged(
                                        k, n, arr.dtype))
        elif name == "bias":
            spec = tiles.block_spec((1, block_n), col_map, arr.dtype,
                                    allow_ragged_minor=True)
        elif name == "residual":
            spec = tiles.block_spec((block_m, block_n), o_map, arr.dtype,
                                    allow_ragged_minor=tiles.shape_ragged(
                                        m, n, arr.dtype))
        elif name == "scale":
            # per-channel dequant vectors stream as row/col blocks; the
            # scalar is a pinned (1, 1) cell
            smap = {"row": row_map, "col": col_map}.get(
                epilogue.scale_kind, lambda i, kk: (0, 0))
            spec = tiles.block_spec(epilogue.scale_block(block_m, block_n),
                                    smap, arr.dtype, allow_ragged_minor=True)
        else:  # sin / cos: (M, head_dim) row blocks
            spec = tiles.block_spec((block_m, epilogue.head_dim), row_map,
                                    arr.dtype, allow_ragged_minor=True)
        in_specs.append(spec)

    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)
               for _ in range(epilogue.n_accumulators)]
    kernel = functools.partial(_gemm_kernel, nk=nk, out_dtype=out_dtype,
                               epilogue=epilogue, prologue=prologue,
                               save_preact=save_preact)
    out_specs = [tiles.block_spec((block_m, block_n), o_map, out_dtype,
                                  allow_ragged_minor=tiles.shape_ragged(
                                      m, n, out_dtype))]
    out_shape = [jax.ShapeDtypeStruct((m, n), out_dtype)]
    if save_preact:
        # the bwd residual outputs: one (M, N) preactivation per saved
        # accumulator, in the MXU input dtype — fp32 for scale chains
        # (Epilogue.preact_keeps_f32; DESIGN.md §11)
        p_dtype = jnp.float32 if epilogue.preact_keeps_f32 else \
            mxu_input_dtype(a.dtype)
        for _ in range(epilogue.saved_accumulators):
            out_specs.append(tiles.block_spec(
                (block_m, block_n), o_map, p_dtype,
                allow_ragged_minor=tiles.shape_ragged(m, n, p_dtype)))
            out_shape.append(jax.ShapeDtypeStruct((m, n), p_dtype))
    result = pl.pallas_call(
        kernel,
        grid=(num_rows * num_cols, nk),
        in_specs=in_specs,
        out_specs=out_specs if save_preact else out_specs[0],
        out_shape=out_shape if save_preact else out_shape[0],
        scratch_shapes=scratch,
        compiler_params=tiles.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b, *extras)
    return result


def gemm_pallas(a: jax.Array, b: jax.Array, *,
                policy: KernelPolicy | None = None,
                block_m: int | None = None, block_n: int | None = None,
                block_k: int | None = None,
                swizzle: SwizzleConfig = ROW_MAJOR,
                out_dtype=jnp.bfloat16, interpret: bool = True) -> jax.Array:
    """C = A @ B with tiling + grid order given by ``policy`` (Algorithm 1).

    Explicit ``block_*``/``swizzle`` is the deprecated pre-policy surface
    (builds an equivalent explicit policy); with neither a policy nor blocks,
    the autotuner resolves one per shape-bucket.

    This is the *plain* GEMM: a policy that carries an epilogue or prologue
    contributes only its blocks/swizzle here — the chains are ignored (they
    need operands this signature cannot supply). Fused launches go through
    :func:`repro.kernels.gemm.ops.gemm_fused`.
    """
    if policy is None:
        m, k = a.shape
        _, n = b.shape
        legacy = None
        if block_m is not None or block_n is not None or block_k is not None:
            legacy = dict(block_m=min(block_m or 512, m),
                          block_n=min(block_n or 512, n),
                          block_k=min(block_k or 512, k), swizzle=swizzle)
        policy = resolve_policy("gemm", (m, n, k), a.dtype,
                                legacy_blocks=legacy, warn_what="gemm_pallas")
    return _gemm_pallas(a, b, policy=policy, out_dtype=out_dtype,
                        interpret=interpret, epilogue=EPILOGUE_NONE)
