"""Blocked GEMM Pallas kernel with Algorithm-1 grid swizzling (paper §3.4, E.1).

Structure mirrors the paper's BF16 GEMM listing (Fig. 21), TPU-adapted:
  * the thread-block output tile        → the per-grid-step output block
  * the 8-wave ping-pong double buffer  → the Pallas grid pipeline (the
    policy's ``n_buffers`` deep — PINGPONG=2, INTERLEAVE=3)
  * chiplet_transform_chunked + window  → the same Algorithm 1 permutation,
    applied in the BlockSpec index_maps so traversal order (and with it the
    DMA revisit pattern) matches the policy's SwizzleConfig
  * pinned AGPR accumulators            → pinned fp32 VMEM scratch accumulator

Every grid/BlockSpec dimension here is derived from a
:class:`~repro.core.policy.KernelPolicy`; the old ``block_m/n/k`` + ``swizzle``
keywords survive as a deprecation shim that builds an explicit policy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tiles
from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR
from repro.core.policy import KernelPolicy, resolve_policy


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int, out_dtype):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jnp.dot(a.astype(jnp.bfloat16) if a.dtype.itemsize == 1 else a,
                            b.astype(jnp.bfloat16) if b.dtype.itemsize == 1 else b,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _fit_policy(policy: KernelPolicy, m: int, n: int, k: int) -> tuple:
    """Clamp the policy's blocks to the problem (paper tiles assume the
    problem tiles the blocks; small problems shrink to a single block)."""
    bm = min(policy.block_m, m)
    bn = min(policy.block_n, n)
    bk = min(policy.block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"problem {m}x{n}x{k} not divisible by policy blocks "
                         f"{bm}x{bn}x{bk}")
    return bm, bn, bk


@functools.partial(jax.jit,
                   static_argnames=("policy", "out_dtype", "interpret"))
def _gemm_pallas(a: jax.Array, b: jax.Array, *, policy: KernelPolicy,
                 out_dtype, interpret: bool) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    block_m, block_n, block_k = _fit_policy(policy, m, n, k)
    num_rows, num_cols, nk = m // block_m, n // block_n, k // block_k
    swizzle = policy.swizzle

    # Tab. 2 feasibility rule at the policy's pipeline depth.
    tiles.check_vmem_budget(
        [((block_m, block_k), a.dtype), ((block_k, block_n), b.dtype)],
        n_buffers=policy.n_buffers,
        scratch_bytes=block_m * block_n * 4, what="gemm")

    def row_col(i):
        return swizzle.remap(i, num_rows, num_cols)

    def a_map(i, kk):
        r, _ = row_col(i)
        return (r, kk)

    def b_map(i, kk):
        _, c = row_col(i)
        return (kk, c)

    def o_map(i, kk):
        r, c = row_col(i)
        return (r, c)

    kernel = functools.partial(_gemm_kernel, nk=nk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(num_rows * num_cols, nk),
        in_specs=[
            tiles.block_spec((block_m, block_k), a_map, a.dtype,
                             allow_ragged_minor=tiles.shape_ragged(
                                 m, k, a.dtype)),
            tiles.block_spec((block_k, block_n), b_map, b.dtype,
                             allow_ragged_minor=tiles.shape_ragged(
                                 k, n, b.dtype)),
        ],
        out_specs=tiles.block_spec((block_m, block_n), o_map, out_dtype,
                                   allow_ragged_minor=tiles.shape_ragged(
                                       m, n, out_dtype)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tiles.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b)


def gemm_pallas(a: jax.Array, b: jax.Array, *,
                policy: KernelPolicy | None = None,
                block_m: int | None = None, block_n: int | None = None,
                block_k: int | None = None,
                swizzle: SwizzleConfig = ROW_MAJOR,
                out_dtype=jnp.bfloat16, interpret: bool = True) -> jax.Array:
    """C = A @ B with tiling + grid order given by ``policy`` (Algorithm 1).

    Explicit ``block_*``/``swizzle`` is the deprecated pre-policy surface
    (builds an equivalent explicit policy); with neither a policy nor blocks,
    the autotuner resolves one per shape-bucket.
    """
    if policy is None:
        m, k = a.shape
        _, n = b.shape
        legacy = None
        if block_m is not None or block_n is not None or block_k is not None:
            legacy = dict(block_m=min(block_m or 512, m),
                          block_n=min(block_n or 512, n),
                          block_k=min(block_k or 512, k), swizzle=swizzle)
        policy = resolve_policy("gemm", (m, n, k), a.dtype,
                                legacy_blocks=legacy, warn_what="gemm_pallas")
    return _gemm_pallas(a, b, policy=policy, out_dtype=out_dtype,
                        interpret=interpret)
