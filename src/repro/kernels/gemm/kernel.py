"""Blocked GEMM Pallas kernel with Algorithm-1 grid swizzling (paper §3.4, E.1).

Structure mirrors the paper's BF16 GEMM listing (Fig. 21), TPU-adapted:
  * the thread-block output tile        → the per-grid-step output block
  * the 8-wave ping-pong double buffer  → the Pallas grid pipeline (2 buffers)
  * chiplet_transform_chunked + window  → the same Algorithm 1 permutation,
    applied in the BlockSpec index_maps so traversal order (and with it the
    DMA revisit pattern) matches the requested SwizzleConfig
  * pinned AGPR accumulators            → pinned fp32 VMEM scratch accumulator
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR
from repro.core import tiles


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int, out_dtype):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jnp.dot(a.astype(jnp.bfloat16) if a.dtype.itemsize == 1 else a,
                            b.astype(jnp.bfloat16) if b.dtype.itemsize == 1 else b,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "swizzle", "out_dtype",
                     "interpret"),
)
def gemm_pallas(a: jax.Array, b: jax.Array, *, block_m: int = 512,
                block_n: int = 512, block_k: int = 512,
                swizzle: SwizzleConfig = ROW_MAJOR,
                out_dtype=jnp.bfloat16, interpret: bool = True) -> jax.Array:
    """C = A @ B with grid order given by ``swizzle`` (Algorithm 1)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"problem {m}x{n}x{k} not divisible by blocks "
                         f"{block_m}x{block_n}x{block_k}")
    num_rows, num_cols, nk = m // block_m, n // block_n, k // block_k

    tiles.check_vmem_budget(
        [((block_m, block_k), a.dtype), ((block_k, block_n), b.dtype)],
        n_buffers=2, scratch_bytes=block_m * block_n * 4, what="gemm")

    def row_col(i):
        return swizzle.remap(i, num_rows, num_cols)

    def a_map(i, kk):
        r, _ = row_col(i)
        return (r, kk)

    def b_map(i, kk):
        _, c = row_col(i)
        return (kk, c)

    def o_map(i, kk):
        r, c = row_col(i)
        return (r, c)

    kernel = functools.partial(_gemm_kernel, nk=nk, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=(num_rows * num_cols, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_map),
            pl.BlockSpec((block_k, block_n), b_map),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), o_map),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b)
