"""Declarative epilogue/prologue chains for the blocked GEMM megakernel.

HipKittens' biggest wins are in memory-bound settings where fused kernels
avoid HBM round trips (paper Fig. 9); ThunderKittens makes the same case for
"AI kernels = GEMM + a short elementwise chain" on NVIDIA. An
:class:`Epilogue` is that chain, declared as a frozen (hashable, jit-static)
spec and applied inside the GEMM kernel's final ``@pl.when(k == nk-1)``
store — the output tile is transformed while still resident in VMEM, so the
consumer ops (bias, activation, SwiGLU gating, residual add, fp8 dequant,
RoPE rotation) never re-read the activation from HBM.

Canonical chain order (each stage optional):

    acc --[scale]--> --[+bias]--> --[rope]--> --[act | act*acc2]--> --[+residual]--> store

  * ``scale``    — multiply by a runtime scalar. Doubles as the fp8 dequant
                   scale and as the model's residual_scale (out = s·C + res).
  * ``bias``     — add a broadcast (1, N) row vector.
  * ``rope``     — rotary rotation applied per ``head_dim`` column chunk
                   (the fused QKV→RoPE *prologue* of attention: q/k tiles are
                   rotated before they ever hit HBM). sin/cos are streamed as
                   (M, head_dim) row blocks.
  * ``gate``     — dual-output GEMM: the kernel accumulates a second
                   product A@B2 and stores ``act(acc) * acc2`` (SwiGLU/GeGLU
                   fusing the two MLP up-projections into one pass over A).
  * ``activation`` — plain silu/gelu/relu when not gated.
  * ``residual`` — add a streamed (M, N) tile.

The same :meth:`Epilogue.apply` implements the chain for both the Pallas
kernel (on VMEM tiles) and the jnp oracle (on full arrays) — every stage is
elementwise or row-broadcast, so tile-wise application is exact. This
chain-spec protocol (``operand_names`` / ``extra_operand_blocks`` /
``check_blocks`` / ``apply`` / ``extra_read_bytes`` / ``describe``) is
shared with the load-side :class:`~repro.kernels.gemm.prologue.Prologue`
(DESIGN.md §10), which transforms the A tiles on the way *in* the same way
this spec transforms the output tiles on the way out.

Extra-operand convention (the order kernels and ops agree on; prologue
operands precede these in the kernel ref list):
``b2?, bias?, residual?, scale?, sin?, cos?`` — see :meth:`operand_names`.

Legality (DESIGN.md §9): the extra streamed blocks and the second
accumulator count against the VMEM budget via
:meth:`extra_operand_blocks` / :meth:`extra_scratch_accumulators`, which
``KernelPolicy`` consults when ``policy.epilogue`` is set; ``rope`` further
requires ``block_n % head_dim == 0`` (the rotation reshapes the tile to
whole heads), enforced by :meth:`check_blocks`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

ACTIVATIONS = ("none", "silu", "gelu", "relu")
SCALE_KINDS = ("scalar", "row", "col")

# f32-in/f32-out activation bodies; gelu matches models/common.act_fn
# (approximate=True).
_ACT_FNS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def _act_grad(name: str, x, g):
    """cotangent of _ACT_FNS[name] at x — derived with jax.vjp so the
    transpose rule can never drift from the forward body."""
    _, vjp = jax.vjp(_ACT_FNS[name], x)
    return vjp(g)[0]


def rope_rotate(x, sin, cos, head_dim: int):
    """Rotate-half RoPE on a (rows, cols) tile whose columns are whole heads.

    sin/cos: (rows, head_dim) duplicated-halves tables (one row per token
    row of the tile). Identical math to kernels.rope.ref.rope_ref, applied
    per head_dim-sized column chunk.
    """
    rows, cols = x.shape
    half = head_dim // 2
    xh = x.reshape(rows, cols // head_dim, head_dim)
    x1 = xh[..., :half]
    x2 = xh[..., half:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    out = xh * cos[:, None, :] + rotated * sin[:, None, :]
    return out.reshape(rows, cols)


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """A frozen, hashable epilogue chain spec (jit-static by construction)."""

    bias: bool = False
    activation: str = "none"     # 'none' | 'silu' | 'gelu' | 'relu'
    gate: bool = False           # dual-output GEMM: store act(acc) * acc2
    residual: bool = False
    scale: bool = False          # runtime scale: fp8 dequant / residual_scale
    scale_kind: str = "scalar"   # 'scalar' | 'row' (M,1) | 'col' (1,N) —
                                 # per-channel fp8 dequant vectors
    rope: bool = False           # per-head rotary rotation (QKV prologue)
    head_dim: int = 0            # required (and >0, even) when rope=True

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown activation {self.activation!r}; "
                             f"have {ACTIVATIONS}")
        if self.scale_kind not in SCALE_KINDS:
            raise ValueError(f"unknown scale_kind {self.scale_kind!r}; "
                             f"have {SCALE_KINDS}")
        if self.scale_kind != "scalar" and not self.scale:
            raise ValueError("scale_kind is only meaningful with scale=True")
        if self.gate and self.activation == "none":
            raise ValueError("gate=True needs an activation (SwiGLU/GeGLU "
                             "stores act(acc) * acc2)")
        if self.gate and self.bias:
            raise ValueError("gate=True excludes bias (the dual-output "
                             "up-projection GEMM is bias-free)")
        if self.rope:
            if self.gate or self.residual or self.activation != "none":
                raise ValueError("rope composes only with bias/scale (it is "
                                 "the QKV-projection prologue, not an MLP "
                                 "epilogue)")
            if self.head_dim <= 0 or self.head_dim % 2:
                raise ValueError(f"rope=True needs an even head_dim > 0, "
                                 f"got {self.head_dim}")
        elif self.head_dim:
            raise ValueError("head_dim is only meaningful with rope=True")

    # -- identity / shape of the chain -------------------------------------
    @property
    def is_identity(self) -> bool:
        return not (self.bias or self.gate or self.residual or self.scale
                    or self.rope or self.activation != "none")

    @property
    def n_accumulators(self) -> int:
        return 2 if self.gate else 1

    def operand_names(self) -> tuple:
        """Runtime extra operands, in the canonical kernel order."""
        names = []
        if self.gate:
            names.append("b2")
        if self.bias:
            names.append("bias")
        if self.residual:
            names.append("residual")
        if self.scale:
            names.append("scale")
        if self.rope:
            names += ["sin", "cos"]
        return tuple(names)

    # -- VMEM legality accounting (consumed by KernelPolicy) ----------------
    def extra_operand_blocks(self, block_m: int, block_n: int, block_k: int,
                             in_dtype: str) -> list:
        """(shape, dtype) of each extra pipelined block, for vmem budgeting."""
        blocks = []
        if self.gate:
            blocks.append(((block_k, block_n), in_dtype))
        if self.bias:
            blocks.append(((1, block_n), in_dtype))
        if self.residual:
            blocks.append(((block_m, block_n), in_dtype))
        if self.scale:
            blocks.append((self.scale_block(block_m, block_n), "float32"))
        if self.rope:
            blocks += [((block_m, self.head_dim), "float32")] * 2
        return blocks

    def scale_block(self, block_m: int, block_n: int) -> tuple:
        """The streamed f32 scale block per scale_kind: one scalar, an (M, 1)
        per-row column, or a (1, N) per-channel dequant row."""
        if self.scale_kind == "row":
            return (block_m, 1)
        if self.scale_kind == "col":
            return (1, block_n)
        return (1, 1)

    def extra_scratch_accumulators(self) -> int:
        """Accumulators beyond the first (the gate path pins a second)."""
        return self.n_accumulators - 1

    def check_blocks(self, block_n: int) -> None:
        """Raise on block shapes the chain cannot legally tile."""
        if self.rope and block_n % self.head_dim:
            raise ValueError(
                f"rope epilogue needs block_n % head_dim == 0 "
                f"(got block_n={block_n}, head_dim={self.head_dim})")

    # -- modeled HBM traffic of the extra streamed operands -----------------
    def extra_read_bytes(self, m: int, n: int, dtype_bytes: int) -> int:
        """Bytes the fused kernel reads beyond A/B panels and the C store.

        The gate operand (B2) is *not* counted here — it streams like B and
        is accounted at the panel level (doubled B traffic) by the scorer.
        """
        extra = 0
        if self.bias:
            extra += n * dtype_bytes
        if self.residual:
            extra += m * n * dtype_bytes
        if self.scale:
            extra += 4 * {"scalar": 1, "row": m, "col": n}[self.scale_kind]
        if self.rope:
            extra += 2 * m * self.head_dim * 4
        return extra

    # -- the chain itself ---------------------------------------------------
    def apply(self, acc, acc2=None, *, bias=None, residual=None, scale=None,
              sin=None, cos=None):
        """Run the chain on an fp32 accumulator (tile or full array).

        All operands must already be fp32; broadcasting rules make the same
        code exact for a (block_m, block_n) tile and the full (M, N) array.
        """
        out = acc
        if self.scale:
            out = out * scale
        if self.bias:
            out = out + bias
        if self.rope:
            out = rope_rotate(out, sin, cos, self.head_dim)
        if self.gate:
            g2 = acc2 * scale if self.scale else acc2
            out = _ACT_FNS[self.activation](out) * g2
        elif self.activation != "none":
            out = _ACT_FNS[self.activation](out)
        if self.residual:
            out = out + residual
        return out

    # -- the chain transpose (DESIGN.md §11) --------------------------------
    @property
    def needs_saved_preact(self) -> bool:
        """True when the bwd transpose needs the raw fp32 accumulator(s) the
        fwd store consumed: the activation transpose is act'(preact)·g (and
        the gate also needs preact2), and dscale is a <g, preact> reduction.
        rope alone does not qualify — the rotation is invertible, so the
        table cotangents re-derive the pre-rope value from the saved output.
        """
        return self.gate or self.activation != "none" or self.scale

    @property
    def saved_accumulators(self) -> int:
        """How many accumulators the fwd launch stores for the kernel bwd."""
        return self.n_accumulators if self.needs_saved_preact else 0

    @property
    def preact_keeps_f32(self) -> bool:
        """scale chains save fp32 preactivations: dscale is a <g, preact>
        *reduction*, so the summed cotangent inherits the operand's
        precision (act' only modulates g elementwise and tolerates the MXU
        input rounding). One predicate shared by the fwd launch's save, the
        policy VMEM rule, and the bwd traffic model."""
        return self.scale

    def _transpose_core(self, g, preact=None, preact2=None, *, bias=None,
                        scale=None, sin=None, cos=None) -> dict:
        """The shared transpose chain: walks the fwd stage order backwards,
        recomputing the activation/rope input from the saved accumulator.
        Returns every intermediate cotangent the rules below pick from:
        'g_acc'/'g_acc2' (raw-accumulator cotangents, the bwd GEMM streams),
        'g_bias' (pre-bias-point cotangent, column-reduced into dbias),
        'g_scaled'/'g_scaled2' (post-scale-point cotangents, the dscale
        reduction operands). All elementwise/broadcast, so the same code is
        exact on a VMEM tile and on the full array.
        """
        out = {}
        gy = g  # the residual add transposes to identity on the main path
        if self.gate:
            u = preact * scale if self.scale else preact
            v2 = preact2 * scale if self.scale else preact2
            du = _act_grad(self.activation, u, gy * v2)
            dv2 = _ACT_FNS[self.activation](u) * gy
            out["g_scaled"], out["g_scaled2"] = du, dv2
            out["g_acc"] = du * scale if self.scale else du
            out["g_acc2"] = dv2 * scale if self.scale else dv2
            return out
        if self.activation != "none":
            # u = the activation input: scale then bias applied to preact
            u = preact
            if self.scale:
                u = u * scale
            if self.bias:
                u = u + bias
            du = _act_grad(self.activation, u, gy)
        elif self.rope:
            # rotation adjoint = rotation by -theta
            du = rope_rotate(gy, -sin, cos, self.head_dim)
        else:
            du = gy
        out["g_bias"] = du
        out["g_scaled"] = du
        out["g_acc"] = du * scale if self.scale else du
        return out

    def transpose_tile(self, g, preact=None, preact2=None, *, bias=None,
                       scale=None, sin=None, cos=None) -> dict:
        """Tile-local half of the declarative transpose rule (DESIGN.md §11):
        grad_out tile -> the cotangent streams the bwd GEMM launches consume.
        'g_acc' (and 'g_acc2' for the dual-output gate) feed dA = g_acc@Bᵀ
        and dB = Aᵀ@g_acc; 'g_bias' (present iff bias) is the pre-bias-point
        cotangent the dB launch column-reduces into dbias inside its store.
        This is the fwd epilogue run as a *prologue on g*: applied to each g
        tile as it streams into the bwd launches.
        """
        core = self._transpose_core(g, preact, preact2, bias=bias,
                                    scale=scale, sin=sin, cos=cos)
        keep = {"g_acc"}
        if self.gate:
            keep.add("g_acc2")
        if self.bias:
            keep.add("g_bias")
        return {k: v for k, v in core.items() if k in keep}

    def operand_grads(self, g, preact=None, preact2=None, out=None, *,
                      bias=None, residual=None, scale=None, sin=None,
                      cos=None) -> dict:
        """Reduction half of the transpose rule, on full arrays (jnp): the
        cotangents of the chain's extra operands. The kernel path folds the
        dbias column-sum into the dB launch store, so it only consults this
        for dresidual (identity), dscale (a <g, preact> reduction shaped per
        scale_kind) and the rope-table cotangents (which re-derive the
        pre-rope value — from the saved preact when one exists, else by
        inverting the rotation on the saved output). The jnp bwd oracle uses
        every entry, dbias included. Unused entries are DCE'd under jit.
        """
        core = self._transpose_core(g, preact, preact2, bias=bias,
                                    scale=scale, sin=sin, cos=cos)
        grads = {}
        if self.residual:
            grads["residual"] = g
        if self.bias:
            grads["bias"] = jnp.sum(core["g_bias"], axis=0, keepdims=True)
        if self.scale:
            ds = core["g_scaled"] * preact
            if self.gate:
                ds = ds + core["g_scaled2"] * preact2
            axis = {"scalar": (0, 1), "row": (1,), "col": (0,)}[self.scale_kind]
            grads["scale"] = jnp.sum(ds, axis=axis, keepdims=True)
        if self.rope:
            if preact is not None:
                u = preact * scale if self.scale else preact
                if self.bias:
                    u = u + bias
            else:
                u = rope_rotate(out, -sin, cos, self.head_dim)
            rows, cols = u.shape
            hd, half = self.head_dim, self.head_dim // 2
            uh = u.reshape(rows, cols // hd, hd)
            gh = g.reshape(rows, cols // hd, hd)
            rot = jnp.concatenate([-uh[..., half:], uh[..., :half]], axis=-1)
            grads["sin"] = jnp.sum(gh * rot, axis=1)
            grads["cos"] = jnp.sum(gh * uh, axis=1)
        return grads

    def describe(self) -> str:
        """Short tag for reports/benchmark rows, e.g. 'bias+silu*gate+res'."""
        if self.is_identity:
            return "none"
        parts = []
        if self.scale:
            parts.append("scale" if self.scale_kind == "scalar"
                         else f"scale:{self.scale_kind}")
        if self.bias:
            parts.append("bias")
        if self.rope:
            parts.append(f"rope{self.head_dim}")
        if self.gate:
            parts.append(f"{self.activation}*gate")
        elif self.activation != "none":
            parts.append(self.activation)
        if self.residual:
            parts.append("res")
        return "+".join(parts)


EPILOGUE_NONE = Epilogue()
