"""Ring-overlapped collective GEMM (DESIGN.md §16).

The paper's core async-worker pattern — DMA workers stream the next tile
while MMA workers consume the current one — lifted one level up: ``ppermute``
ring hops stream the next operand chunk between ranks while fused
``gemm_fused`` panel launches consume the chunk already resident. Two
variants, matching the two Megatron TP collectives:

* ``all_gather``      row-parallel A: each rank holds an (m_loc, K) row
  block and the full B. The ring rotates the row blocks; at every step each
  rank GEMMs the block it currently holds into the matching output panel.
  After S steps every rank has the full (M, N) product — the all_gather
  never materializes the gathered A in HBM.
* ``reduce_scatter``  contraction-parallel A/B: each rank holds (M, k_loc)
  and (k_loc, N) and owes a partial product. The fp32 panel accumulator
  rides the ring; at step s each rank computes its contribution to panel
  ``(rank - step - 1) % S`` and adds it to the accumulator it just
  received, so panel p collects contributions in the fixed rank order
  p+1, p+2, ..., p — deterministic, unlike ``psum_scatter``.

Bitwise parity (the kernel's oracle contract): every panel GEMM runs a
full-K policy (block_k == K), which makes each output element a single-tile
dot — bitwise-equal to ``jnp.dot`` row panels regardless of how the rows
are batched. The unfused gather-then-gemm path and the jnp oracle therefore
match the ring *bitwise*, per rank, in every mode.

These functions run INSIDE shard_map (they use ``jax.lax.axis_index`` /
``ppermute``); :func:`gemm_collective_sharded` is the host-level wrapper
that builds the shard_map with the right specs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import obs
from repro.core import autotune

VARIANTS = ("all_gather", "reduce_scatter")


def _full_k_policy(m, n, k, dtype):
    """Full-K gemm policy (block_k == K): the bitwise-safety pin — K-tile
    accumulation order is the only fp difference vs jnp.dot, so a single K
    tile makes panel GEMMs exact row panels of the full product."""
    pol = autotune.select_policy("gemm", (m, n, k), dtype)
    if pol.block_k == k:
        return pol
    pinned = dataclasses.replace(
        pol, schedule=dataclasses.replace(pol.schedule, block_k=k))
    if not pinned.is_legal():
        raise ValueError(
            f"gemm_collective: no VMEM-legal full-K policy for "
            f"({m}, {n}, {k}) {dtype} — bitwise parity cannot be pinned")
    return pinned


def _panel_gemm(a, b, *, mode, out_dtype, policy):
    """One panel launch: gemm_fused with the pinned policy, or the jnp
    oracle in reference mode (identical values — that is the point)."""
    if mode == "reference":
        return jnp.dot(a, b, preferred_element_type=jnp.float32
                       if out_dtype == jnp.float32 else None
                       ).astype(out_dtype)
    from .ops import gemm_fused
    from .epilogue import EPILOGUE_NONE

    return gemm_fused(a, b, epilogue=EPILOGUE_NONE, policy=policy,
                      out_dtype=out_dtype, mode=mode)


def _ring_perm(axis_size: int):
    return [(j, (j + 1) % axis_size) for j in range(axis_size)]


# ---------------------------------------------------------------------------
# all_gather variant: row-parallel A, ring rotates the row blocks
# ---------------------------------------------------------------------------

def _ag_ring(x, w, *, axis_name, axis_size, mode, out_dtype, policy):
    """x: (m_loc, K) local rows; w: (K, N) full. Returns the full (M, N)
    product on every rank. At step s the chunk a rank holds originated at
    rank (rank - s) % S."""
    s_ = axis_size
    m_loc, k = x.shape
    n = w.shape[1]
    rank = jax.lax.axis_index(axis_name)
    out = jnp.zeros((s_ * m_loc, n), out_dtype)
    chunk = x
    for step in range(s_):
        origin = (rank - step) % s_
        y = _panel_gemm(chunk, w, mode=mode, out_dtype=out_dtype,
                        policy=policy)
        out = jax.lax.dynamic_update_slice(out, y, (origin * m_loc, 0))
        if step < s_ - 1:
            chunk = jax.lax.ppermute(chunk, axis_name, _ring_perm(s_))
    return out


def _ag_gather_then_gemm(x, w, *, axis_name, axis_size, mode, out_dtype,
                         policy):
    """Unfused baseline: materialize the gathered A, one big GEMM. The
    full-K policy makes its row panels bitwise-equal to the ring's."""
    del axis_size
    ag = jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
    return _panel_gemm(ag, w, mode=mode, out_dtype=out_dtype, policy=policy)


# ---------------------------------------------------------------------------
# reduce_scatter variant: contraction-parallel, fp32 accumulator rides the
# ring; panel p sums contributions in rank order p+1, p+2, ..., p
# ---------------------------------------------------------------------------

def _rs_panel(x, p_idx, m_loc):
    return jax.lax.dynamic_slice_in_dim(x, p_idx * m_loc, m_loc, axis=0)


def _rs_ring(x, w, *, axis_name, axis_size, mode, out_dtype, policy):
    """x: (M, k_loc); w: (k_loc, N). Returns this rank's (M/S, N) panel of
    the summed product, accumulated in fp32 in the fixed ring order."""
    s_ = axis_size
    m, _ = x.shape
    m_loc = m // s_
    rank = jax.lax.axis_index(axis_name)
    acc = None
    for step in range(s_):
        p_idx = (rank - step - 1) % s_
        y = _panel_gemm(_rs_panel(x, p_idx, m_loc), w, mode=mode,
                        out_dtype=jnp.float32, policy=policy)
        if acc is None:
            acc = y
        else:
            acc = jax.lax.ppermute(acc, axis_name, _ring_perm(s_)) + y
    return acc.astype(out_dtype)


def _rs_gather_then_sum(x, w, *, axis_name, axis_size, mode, out_dtype,
                        policy):
    """Unfused baseline: full partial product per rank, all_gather the
    partial panels, then sum this rank's panel in the SAME rank order the
    ring uses (p+1, p+2, ..., p) — order-matched so the paths stay bitwise.
    ``psum_scatter`` would be one op but its addition order is XLA's."""
    s_ = axis_size
    m, _ = x.shape
    m_loc = m // s_
    rank = jax.lax.axis_index(axis_name)
    partial = _panel_gemm(x, w, mode=mode, out_dtype=jnp.float32,
                          policy=policy)
    all_p = jax.lax.all_gather(partial, axis_name, axis=0)  # (S, M, N)
    acc = jnp.zeros((m_loc, w.shape[1]), jnp.float32)
    for i in range(s_):
        src = (rank + 1 + i) % s_
        contrib = jax.lax.dynamic_index_in_dim(all_p, src, 0,
                                               keepdims=False)
        acc = acc + _rs_panel(contrib, rank, m_loc)
    return acc.astype(out_dtype)


def gemm_collective(x, w, *, axis_name: str, axis_size: int, variant: str,
                    mode: str = "pallas_interpret", out_dtype=None,
                    shard=None, plan: str | None = None):
    """Collective GEMM, called inside shard_map (DESIGN.md §16).

    ``variant`` picks the collective ('all_gather' | 'reduce_scatter');
    ``plan`` forces 'ring' (overlapped) or 'gather' (unfused baseline), or
    None to consult ``select_fusion('gemm_collective', ...)`` with the
    interconnect chain term — journaled like every other fusion verdict.
    ``shard`` is the enclosing ShardSpec (memo-key dimension; required when
    ``plan`` is None). Both plans are bitwise-equal by construction.
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; have {VARIANTS}")
    out_dtype = out_dtype or x.dtype
    if variant == "all_gather":
        m_loc, k = x.shape
        n = w.shape[1]
        m = m_loc * axis_size
        pol_shape = (m_loc, n, k)
    else:
        m, k_loc = x.shape
        n = w.shape[1]
        if m % axis_size:
            raise ValueError(
                f"reduce_scatter rows {m} not divisible by ring size "
                f"{axis_size}")
        pol_shape = (m // axis_size, n, k_loc)
        k = k_loc * axis_size
    if plan is None:
        if shard is None:
            raise ValueError("gemm_collective: plan=None requires shard=")
        verdict = autotune.select_fusion("gemm_collective", (m, n, k),
                                         str(x.dtype), shard=shard)
        plan = "ring" if verdict["plan"] == "fused" else "gather"
    policy = (None if mode == "reference"
              else _full_k_policy(*pol_shape, str(x.dtype)))
    fn = {("all_gather", "ring"): _ag_ring,
          ("all_gather", "gather"): _ag_gather_then_gemm,
          ("reduce_scatter", "ring"): _rs_ring,
          ("reduce_scatter", "gather"): _rs_gather_then_sum}[(variant, plan)]
    obs.incr(f"gemm_collective.{variant}.{plan}")
    return fn(x, w, axis_name=axis_name, axis_size=axis_size, mode=mode,
              out_dtype=out_dtype, policy=policy)


def gemm_collective_oracle(x_full, w_full, *, variant: str, axis_size: int,
                           out_dtype=None):
    """Single-host jnp oracle on the UNSHARDED operands. all_gather: the
    plain product, replicated. reduce_scatter: per-rank panels summed over
    the k_loc contributions in the ring's rank order (rank-dependent, so
    the oracle returns the (S, M/S, N) stack of per-rank panels)."""
    out_dtype = out_dtype or x_full.dtype
    if variant == "all_gather":
        return jnp.dot(x_full, w_full).astype(out_dtype)
    m, k = x_full.shape
    n = w_full.shape[1]
    s_ = axis_size
    m_loc, k_loc = m // s_, k // s_
    # per-source partial products, fp32
    parts = [jnp.dot(x_full[:, src * k_loc:(src + 1) * k_loc],
                     w_full[src * k_loc:(src + 1) * k_loc, :],
                     preferred_element_type=jnp.float32)
             for src in range(s_)]
    panels = []
    for rank in range(s_):
        acc = jnp.zeros((m_loc, n), jnp.float32)
        for i in range(s_):
            src = (rank + 1 + i) % s_
            acc = acc + parts[src][rank * m_loc:(rank + 1) * m_loc, :]
        panels.append(acc.astype(out_dtype))
    return jnp.stack(panels)


def gemm_collective_sharded(x, w, *, mesh, axis: str = "model",
                            variant: str = "all_gather",
                            mode: str = "pallas_interpret",
                            out_dtype=None, plan: str | None = None):
    """Host-level wrapper: shard_map with the specs each variant implies.

    all_gather: x rows over ``axis``, w replicated → full (M, N) replicated.
    reduce_scatter: x cols / w rows over ``axis`` → (M, N) rows over axis.
    """
    from repro.distributed.sharding import ShardSpec

    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; have {VARIANTS}")
    s_ = int(mesh.shape[axis])
    shard = ShardSpec.for_axis(mesh, axis, dim="rows" if
                               variant == "all_gather" else "contract",
                               collective=variant)
    if variant == "all_gather":
        in_specs = (P(axis, None), P(None, None))
        out_specs = P(None, None)
    else:
        in_specs = (P(None, axis), P(axis, None))
        out_specs = P(axis, None)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def inner(xl, wl):
        return gemm_collective(xl, wl, axis_name=axis, axis_size=s_,
                               variant=variant, mode=mode,
                               out_dtype=out_dtype, shard=shard, plan=plan)

    return inner(x, w)
