from .ops import gemm  # noqa: F401
from .ref import gemm_ref  # noqa: F401
from .kernel import gemm_pallas  # noqa: F401
