from .epilogue import EPILOGUE_NONE, Epilogue  # noqa: F401
from .prologue import PROLOGUE_NONE, Prologue, norm_prologue  # noqa: F401
from .ops import gemm, gemm_fused  # noqa: F401
from .ref import gemm_fused_ref, gemm_ref  # noqa: F401
from .kernel import gemm_pallas  # noqa: F401
