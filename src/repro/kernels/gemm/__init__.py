from .epilogue import EPILOGUE_NONE, Epilogue  # noqa: F401
from .prologue import PROLOGUE_NONE, Prologue, norm_prologue  # noqa: F401
from .ops import default_bwd_mode, gemm, gemm_fused  # noqa: F401
from .ref import gemm_fused_bwd_ref, gemm_fused_ref, gemm_ref  # noqa: F401
from .kernel import gemm_pallas  # noqa: F401
from .backward import gemm_fused_bwd, resolve_bwd_policies  # noqa: F401
from .collective import (gemm_collective, gemm_collective_oracle,  # noqa: F401
                         gemm_collective_sharded)
