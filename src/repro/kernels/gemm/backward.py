"""Kernel-side fused backward for the GEMM megakernel (DESIGN.md §11).

The forward (DESIGN.md §9-§10) is ``C = epilogue(prologue(A) @ B [, A@B2])``
in one launch. This module is its hand-written chain transpose, run as two
fused Pallas launches instead of the jnp-oracle recompute VJP:

  * **dA launch** — ``dAn = gbar @ Bᵀ [+ gbar2 @ B2ᵀ]`` where the cotangent
    stream ``gbar`` is the *transposed epilogue applied as a prologue on g*:
    act'/gating/scale/rope-adjoint run on each g tile as it streams in,
    consuming the fwd launch's saved preactivations
    (:meth:`Epilogue.transpose_tile`). The store runs the prologue's
    transpose (:meth:`Prologue.transpose`): the norm backward is computed
    tile-wise from the streamed raw-A tile — the normed activation is never
    re-materialized — and the dgamma/dbeta row partials are folded into the
    same store (one partial row per row block; a tiny jnp sum finishes the
    cross-block reduction).
  * **dB launch** — ``dB = Anᵀ @ gbar`` with the norm prologue recomputed on
    the streamed A tiles exactly like the fwd (same full-K rule, same
    precomputed-stats fast path, same MXU-dtype rounding point). The
    dual-GEMM SwiGLU case shares ONE dual-output launch: ``dB`` and ``dB2``
    accumulate side by side from the same A stream, and the dbias
    column-sum is folded into the same store.

dresidual is the identity (g, no launch); dscale and the rope-table
cotangents are tiny jnp reductions over arrays already in HBM
(:meth:`Epilogue.operand_grads`) and are DCE'd when unused.

Both launches resolve their own ``gemm_bwd`` policies through the analytic
autotuner (chain-aware VMEM legality + traffic), pinned to the forward
policy's traversal order so grid swizzling stays a pure scheduling
transform across fwd AND bwd — gradients are bitwise swizzle-invariant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core import autotune, tiles
from repro.core.policy import KernelPolicy
from .epilogue import EPILOGUE_NONE, Epilogue
from .prologue import PROLOGUE_NONE, Prologue
from .kernel import (_fit_block, _upcast, epilogue_f32_kwargs,
                     prologue_f32_kwargs)

_F32 = jnp.float32


def _preacts_f32(epilogue: Epilogue, ins: dict) -> tuple:
    p = ins["preact"][...].astype(_F32) if "preact" in ins else None
    p2 = ins["preact2"][...].astype(_F32) if "preact2" in ins else None
    return p, p2


# ---------------------------------------------------------------------------
# dA launch: dAn = gbar @ Bᵀ (+ gbar2 @ B2ᵀ), norm transpose in the store.
# ---------------------------------------------------------------------------

def _da_kernel(*refs, in_names, out_names, n_ctr, epilogue: Epilogue,
               prologue: Prologue, da_dtype):
    ins = dict(zip(in_names, refs[:len(in_names)]))
    outs = dict(zip(out_names, refs[len(in_names):-1]))
    acc_ref = refs[-1]
    ctr = pl.program_id(1)

    @pl.when(ctr == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    streams = epilogue.transpose_tile(
        ins["g"][...].astype(_F32), *_preacts_f32(epilogue, ins),
        **epilogue_f32_kwargs(epilogue, ins))
    # contract the (bm, bctr) cotangent with the (bko, bctr) weight block
    # over the shared N dim — the in-kernel transpose of B
    dims = (((1,), (1,)), ((), ()))
    bt = _upcast(ins["b"][...]).astype(_F32)
    acc_ref[...] += jax.lax.dot_general(streams["g_acc"], bt, dims,
                                        preferred_element_type=_F32)
    if epilogue.gate:
        b2t = _upcast(ins["b2"][...]).astype(_F32)
        acc_ref[...] += jax.lax.dot_general(streams["g_acc2"], b2t, dims,
                                            preferred_element_type=_F32)

    @pl.when(ctr == n_ctr - 1)
    def _store():
        dan = acc_ref[...]
        if prologue.is_identity:
            outs["da"][...] = dan.astype(da_dtype)
        else:
            a32 = _upcast(ins["a"][...]).astype(_F32)
            tr = prologue.transpose(dan, a32,
                                    **prologue_f32_kwargs(prologue, ins))
            outs["da"][...] = tr["da"].astype(da_dtype)
            for name in prologue.grad_names():
                outs[name][...] = tr[name].astype(outs[name].dtype)


@functools.partial(jax.jit, static_argnames=("policy", "epilogue", "prologue",
                                             "interpret"))
def _gemm_bwd_da(a, b, g, extras, preacts, *, policy: KernelPolicy,
                 epilogue: Epilogue, prologue: Prologue, interpret: bool):
    """dA (+ dgamma/dbeta partials, fast-path dmean/drstd) in one launch."""
    m, k = a.shape
    _, n = b.shape
    ops = dict(zip(prologue.operand_names() + epilogue.operand_names(),
                   extras))
    bm = _fit_block(m, policy.block_m, prefer=32)
    # the prologue transpose's row reductions need whole feature rows of
    # dAn, so the output-column block pins to full K (both stats paths)
    bko = k if not prologue.is_identity else \
        _fit_block(k, policy.block_n, prefer=tiles.LANE)
    bctr = _fit_block(n, policy.block_k,
                      epilogue.head_dim if epilogue.rope else 1,
                      prefer=tiles.LANE)
    num_rows, num_cols, n_ctr = m // bm, k // bko, n // bctr
    swizzle = policy.swizzle

    def row_col(i):
        return swizzle.remap(i, num_rows, num_cols)

    def g_map(i, c):
        return (row_col(i)[0], c)

    def b_map(i, c):
        return (row_col(i)[1], c)

    def o_map(i, c):
        return row_col(i)

    def row_map(i, c):
        return (row_col(i)[0], 0)

    def kcol_map(i, c):
        return (0, row_col(i)[1])

    def ctr_map(i, c):
        return (0, c)

    in_names, in_arrays, in_specs = ["g"], [g], [
        tiles.block_spec((bm, bctr), g_map, g.dtype,
                         allow_ragged_minor=tiles.shape_ragged(m, n, g.dtype))]

    def add(name, arr, blk, imap, ragged=True):
        in_names.append(name)
        in_arrays.append(arr)
        in_specs.append(tiles.block_spec(blk, imap, arr.dtype,
                                         allow_ragged_minor=ragged))

    for i, p in enumerate(preacts):
        add("preact" if i == 0 else "preact2", p, (bm, bctr), g_map,
            tiles.shape_ragged(m, n, p.dtype))
    add("b", b, (bko, bctr), b_map, tiles.shape_ragged(k, n, b.dtype))
    if epilogue.gate:
        add("b2", ops["b2"], (bko, bctr), b_map,
            tiles.shape_ragged(k, n, ops["b2"].dtype))
    if epilogue.bias:
        add("bias", ops["bias"], (1, bctr), ctr_map)
    if epilogue.scale:
        smap = {"row": row_map, "col": ctr_map}.get(
            epilogue.scale_kind, lambda i, c: (0, 0))
        add("scale", ops["scale"], epilogue.scale_block(bm, bctr), smap)
    if epilogue.rope:
        add("sin", ops["sin"], (bm, epilogue.head_dim), row_map)
        add("cos", ops["cos"], (bm, epilogue.head_dim), row_map)
    if not prologue.is_identity:
        add("a", a, (bm, bko), o_map, tiles.shape_ragged(m, k, a.dtype))
        add("gamma", ops["gamma"], (1, bko), kcol_map)
        if prologue.beta:
            add("beta", ops["beta"], (1, bko), kcol_map)
        if prologue.precomputed_stats:
            if prologue.norm == "layernorm":
                add("mean", ops["mean"], (bm, 1), row_map)
            add("rstd", ops["rstd"], (bm, 1), row_map)

    out_names = ["da"]
    out_specs = [tiles.block_spec((bm, bko), o_map, a.dtype,
                                  allow_ragged_minor=tiles.shape_ragged(
                                      m, k, a.dtype))]
    out_shape = [jax.ShapeDtypeStruct((m, k), a.dtype)]
    if not prologue.is_identity:
        for name in prologue.grad_names():
            if name in ("dgamma", "dbeta"):
                # one partial row per (row block, col block); jnp sums them
                out_specs.append(tiles.block_spec((1, bko), o_map, _F32,
                                                  allow_ragged_minor=True))
                out_shape.append(jax.ShapeDtypeStruct((num_rows, k), _F32))
            else:  # dmean / drstd: one (rows, 1) column, exact per row block
                out_specs.append(tiles.block_spec((bm, 1), row_map, _F32,
                                                  allow_ragged_minor=True))
                out_shape.append(jax.ShapeDtypeStruct((m, 1), _F32))
            out_names.append(name)

    tiles.check_vmem_budget(
        [(tuple(s.block_shape), arr.dtype)
         for s, arr in zip(in_specs, in_arrays)],
        n_buffers=policy.n_buffers, scratch_bytes=bm * bko * 4,
        what="gemm_bwd_da")
    kernel = functools.partial(_da_kernel, in_names=tuple(in_names),
                               out_names=tuple(out_names), n_ctr=n_ctr,
                               epilogue=epilogue, prologue=prologue,
                               da_dtype=a.dtype)
    results = pl.pallas_call(
        kernel,
        grid=(num_rows * num_cols, n_ctr),
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        scratch_shapes=[pltpu.VMEM((bm, bko), _F32)],
        compiler_params=tiles.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*in_arrays)
    if len(out_names) == 1:
        return {"da": results}
    return dict(zip(out_names, results))


# ---------------------------------------------------------------------------
# dB launch: dB[, dB2] = Anᵀ @ gbar[, gbar2], dbias folded into the store.
# ---------------------------------------------------------------------------

def _db_kernel(*refs, in_names, out_names, n_ctr, epilogue: Epilogue,
               prologue: Prologue, db_dtype):
    n_scratch = epilogue.n_accumulators + (1 if epilogue.bias else 0)
    ins = dict(zip(in_names, refs[:len(in_names)]))
    outs = dict(zip(out_names, refs[len(in_names):-n_scratch]))
    scratch = refs[-n_scratch:]
    acc_ref = scratch[0]
    acc2_ref = scratch[1] if epilogue.gate else None
    dbias_ref = scratch[-1] if epilogue.bias else None
    ctr = pl.program_id(1)

    @pl.when(ctr == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if epilogue.gate:
            acc2_ref[...] = jnp.zeros_like(acc2_ref)
        if epilogue.bias:
            dbias_ref[...] = jnp.zeros_like(dbias_ref)

    a_t = _upcast(ins["a"][...])
    if not prologue.is_identity:
        # tile-wise norm recompute, with the fwd's exact rounding point:
        # normalize in fp32, round through the MXU input dtype, contract
        a_t = prologue.apply(a_t.astype(_F32),
                             **prologue_f32_kwargs(prologue, ins)
                             ).astype(a_t.dtype)
    an = a_t.astype(_F32)
    streams = epilogue.transpose_tile(
        ins["g"][...].astype(_F32), *_preacts_f32(epilogue, ins),
        **epilogue_f32_kwargs(epilogue, ins))
    # contract the (bctr, bko) normed-A tile with the (bctr, bn) cotangent
    # over the shared M dim — the in-kernel transpose of A
    dims = (((0,), (0,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(an, streams["g_acc"], dims,
                                        preferred_element_type=_F32)
    if epilogue.gate:
        acc2_ref[...] += jax.lax.dot_general(an, streams["g_acc2"], dims,
                                             preferred_element_type=_F32)
    if epilogue.bias:
        dbias_ref[...] += jnp.sum(streams["g_bias"], axis=0, keepdims=True)

    @pl.when(ctr == n_ctr - 1)
    def _store():
        outs["db"][...] = acc_ref[...].astype(db_dtype)
        if epilogue.gate:
            outs["db2"][...] = acc2_ref[...].astype(db_dtype)
        if epilogue.bias:
            outs["dbias"][...] = dbias_ref[...]


@functools.partial(jax.jit, static_argnames=("policy", "epilogue", "prologue",
                                             "interpret"))
def _gemm_bwd_db(a, b, g, extras, preacts, *, policy: KernelPolicy,
                 epilogue: Epilogue, prologue: Prologue, interpret: bool):
    """dB (+ dB2 sharing the launch, + folded dbias) in one launch."""
    m, k = a.shape
    _, n = b.shape
    ops = dict(zip(prologue.operand_names() + epilogue.operand_names(),
                   extras))
    # launch dims: out (K, N), contraction M. The recompute-path norm pins
    # the out-row block to full K (the streamed A tile must span whole
    # feature rows, exactly the fwd rule); the fast path keeps K-blocking.
    bko = k if prologue.needs_full_k else \
        _fit_block(k, policy.block_m, prefer=tiles.LANE)
    bn = _fit_block(n, policy.block_n,
                    epilogue.head_dim if epilogue.rope else 1,
                    prefer=tiles.LANE)
    bctr = _fit_block(m, policy.block_k, prefer=32)
    num_rows, num_cols, n_ctr = k // bko, n // bn, m // bctr
    swizzle = policy.swizzle

    def row_col(i):
        return swizzle.remap(i, num_rows, num_cols)

    def a_map(i, c):
        return (c, row_col(i)[0])

    def g_map(i, c):
        return (c, row_col(i)[1])

    def o_map(i, c):
        return row_col(i)

    def krow_map(i, c):
        return (0, row_col(i)[0])

    def col_map(i, c):
        return (0, row_col(i)[1])

    def ctr_map(i, c):
        return (c, 0)

    in_names, in_arrays, in_specs = ["a"], [a], [
        tiles.block_spec((bctr, bko), a_map, a.dtype,
                         allow_ragged_minor=tiles.shape_ragged(m, k, a.dtype))]

    def add(name, arr, blk, imap, ragged=True):
        in_names.append(name)
        in_arrays.append(arr)
        in_specs.append(tiles.block_spec(blk, imap, arr.dtype,
                                         allow_ragged_minor=ragged))

    if not prologue.is_identity:
        add("gamma", ops["gamma"], (1, bko), krow_map)
        if prologue.beta:
            add("beta", ops["beta"], (1, bko), krow_map)
        if prologue.precomputed_stats:
            if prologue.norm == "layernorm":
                add("mean", ops["mean"], (bctr, 1), ctr_map)
            add("rstd", ops["rstd"], (bctr, 1), ctr_map)
    add("g", g, (bctr, bn), g_map, tiles.shape_ragged(m, n, g.dtype))
    for i, p in enumerate(preacts):
        add("preact" if i == 0 else "preact2", p, (bctr, bn), g_map,
            tiles.shape_ragged(m, n, p.dtype))
    if epilogue.bias:
        add("bias", ops["bias"], (1, bn), col_map)
    if epilogue.scale:
        smap = {"row": ctr_map, "col": col_map}.get(
            epilogue.scale_kind, lambda i, c: (0, 0))
        add("scale", ops["scale"], epilogue.scale_block(bctr, bn), smap)
    if epilogue.rope:
        add("sin", ops["sin"], (bctr, epilogue.head_dim), ctr_map)
        add("cos", ops["cos"], (bctr, epilogue.head_dim), ctr_map)

    out_names = ["db"]
    out_specs = [tiles.block_spec((bko, bn), o_map, b.dtype,
                                  allow_ragged_minor=tiles.shape_ragged(
                                      k, n, b.dtype))]
    out_shape = [jax.ShapeDtypeStruct((k, n), b.dtype)]
    if epilogue.gate:
        out_names.append("db2")
        out_specs.append(tiles.block_spec((bko, bn), o_map, b.dtype,
                                          allow_ragged_minor=tiles.shape_ragged(
                                              k, n, b.dtype)))
        out_shape.append(jax.ShapeDtypeStruct((k, n), b.dtype))
    if epilogue.bias:
        # every out-row block accumulates the same column sum; the store is
        # idempotent across them (last writer wins with identical values)
        out_names.append("dbias")
        out_specs.append(tiles.block_spec((1, bn), col_map, _F32,
                                          allow_ragged_minor=True))
        out_shape.append(jax.ShapeDtypeStruct((1, n), _F32))

    n_acc = epilogue.n_accumulators
    scratch = [pltpu.VMEM((bko, bn), _F32) for _ in range(n_acc)]
    if epilogue.bias:
        scratch.append(pltpu.VMEM((1, bn), _F32))
    tiles.check_vmem_budget(
        [(tuple(s.block_shape), arr.dtype)
         for s, arr in zip(in_specs, in_arrays)],
        n_buffers=policy.n_buffers, scratch_bytes=n_acc * bko * bn * 4,
        what="gemm_bwd_db")
    kernel = functools.partial(_db_kernel, in_names=tuple(in_names),
                               out_names=tuple(out_names), n_ctr=n_ctr,
                               epilogue=epilogue, prologue=prologue,
                               db_dtype=b.dtype)
    results = pl.pallas_call(
        kernel,
        grid=(num_rows * num_cols, n_ctr),
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        scratch_shapes=scratch,
        compiler_params=tiles.compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*in_arrays)
    if len(out_names) == 1:
        return {"db": results}
    return dict(zip(out_names, results))


# ---------------------------------------------------------------------------
# Orchestration: the custom-VJP backward body.
# ---------------------------------------------------------------------------

def resolve_bwd_policies(fwd_policy: KernelPolicy, m: int, n: int, k: int,
                         dtype, epilogue: Epilogue,
                         prologue: Prologue) -> tuple:
    """The (dA, dB) launch policies for a fwd launch: resolved through the
    memoized autotuner under the ``gemm_bwd`` op kind (chain-aware VMEM
    legality + bwd traffic model), with the traversal order pinned to the
    fwd policy's swizzle so the whole fwd+bwd step shares one grid-order
    decision (and gradients stay bitwise swizzle-invariant)."""
    da = autotune.select_policy("gemm_bwd", (m, k, n), str(dtype),
                                epilogue=epilogue, prologue=prologue,
                                variant="da", swizzle=fwd_policy.swizzle)
    db = autotune.select_policy("gemm_bwd", (k, n, m), str(dtype),
                                epilogue=epilogue, prologue=prologue,
                                variant="db", swizzle=fwd_policy.swizzle)
    return da, db


def bwd_policies_available(fwd_policy: KernelPolicy, m: int, n: int, k: int,
                           dtype, epilogue: Epilogue,
                           prologue: Prologue) -> bool:
    """True iff the kernel backward can run for this launch shape. The
    differentiated fwd consults this (deterministic — the memoized probe is
    the same resolution the bwd will do) so it never stores preactivations
    the oracle-fallback VJP would ignore."""
    try:
        resolve_bwd_policies(fwd_policy, m, n, k, dtype, epilogue, prologue)
    except ValueError:
        return False
    return True


def gemm_fused_bwd(a, b, extras, preacts, out, g, *, policy: KernelPolicy,
                   epilogue: Epilogue = EPILOGUE_NONE,
                   prologue: Prologue = PROLOGUE_NONE,
                   interpret: bool = True, policies=None) -> tuple:
    """Run the fused backward: returns ``(da, db, dextras)`` matching the
    fwd's ``(a, b, extras)`` — both bwd GEMMs as fused Pallas launches, the
    remaining operand cotangents as tiny jnp reductions.

    ``policies`` lets the caller pass pre-resolved (dA, dB) policies so the
    legality probe (the only sanctioned fallback point — ops.py catches
    *its* ValueError, not launch errors) happens exactly once.
    """
    m, k = a.shape
    _, n = b.shape
    names = prologue.operand_names() + epilogue.operand_names()
    ops = dict(zip(names, extras))
    da_pol, db_pol = policies if policies is not None else \
        resolve_bwd_policies(policy, m, n, k, a.dtype, epilogue, prologue)
    if obs.enabled():
        # journaled at the dispatch site (the launches themselves are jitted
        # wrappers) — one event per bwd GEMM, same semantics the old
        # monkeypatch counters had
        db_bytes = jnp.dtype(a.dtype).itemsize
        chain = f"{prologue.describe()}|{epilogue.describe()}"
        obs.launch("gemm_bwd_da", variant="da", policy=da_pol, chain=chain,
                   dma_bytes=autotune.gemm_bwd_traffic_bytes(
                       da_pol, m, k, n, db_bytes, "da"),
                   flops=2 * m * n * k)
        obs.launch("gemm_bwd_db", variant="db", policy=db_pol, chain=chain,
                   dma_bytes=autotune.gemm_bwd_traffic_bytes(
                       db_pol, k, n, m, db_bytes, "db"),
                   flops=(2 if epilogue.gate else 1) * 2 * m * n * k)
    da_out = _gemm_bwd_da(a, b, g, extras, preacts, policy=da_pol,
                          epilogue=epilogue, prologue=prologue,
                          interpret=interpret)
    db_out = _gemm_bwd_db(a, b, g, extras, preacts, policy=db_pol,
                          epilogue=epilogue, prologue=prologue,
                          interpret=interpret)

    # jnp half of the transpose rule — only dscale and the rope-table
    # cotangents need it (dbias is folded into the dB store, dresidual is
    # the identity); unused entries are DCE'd under jit anyway
    og = {}
    if epilogue.scale or epilogue.rope:
        f32 = [None if p is None else p.astype(_F32)
               for p in (list(preacts) + [None, None])[:2]]
        ekw = {}
        if epilogue.bias:
            ekw["bias"] = ops["bias"].astype(_F32)
        if epilogue.scale:
            ekw["scale"] = ops["scale"].astype(_F32)
        if epilogue.rope:
            ekw["sin"] = ops["sin"].astype(_F32)
            ekw["cos"] = ops["cos"].astype(_F32)
        og = epilogue.operand_grads(
            g.astype(_F32), f32[0], f32[1],
            None if out is None else out.astype(_F32), **ekw, residual=None)

    dextras = []
    for name in names:
        op = ops[name]
        if name == "gamma":
            grad = jnp.sum(da_out["dgamma"], axis=0, keepdims=True)
        elif name == "beta":
            grad = jnp.sum(da_out["dbeta"], axis=0, keepdims=True)
        elif name == "mean":
            grad = da_out["dmean"]
        elif name == "rstd":
            grad = da_out["drstd"]
        elif name == "b2":
            grad = db_out["db2"]
        elif name == "bias":
            grad = db_out["dbias"]
        elif name == "residual":
            grad = g
        else:  # scale / sin / cos: the jnp reduction half
            grad = og[name]
        dextras.append(jnp.asarray(grad).reshape(op.shape).astype(op.dtype))
    return da_out["da"], db_out["db"], tuple(dextras)
