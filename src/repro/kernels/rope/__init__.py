from .ops import rope, rope_tables  # noqa: F401
from .ref import rope_ref  # noqa: F401
from .kernel import rope_pallas  # noqa: F401
