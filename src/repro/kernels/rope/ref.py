"""Pure-jnp oracle for RoPE + table construction helpers."""
from __future__ import annotations

import jax.numpy as jnp


def rope_tables(positions, dim: int, theta: float = 10000.0):
    """Return (sin, cos) of shape (len(positions), dim) — duplicated halves."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    sin = jnp.concatenate([jnp.sin(angles), jnp.sin(angles)], axis=-1)
    cos = jnp.concatenate([jnp.cos(angles), jnp.cos(angles)], axis=-1)
    return sin, cos


def rope_ref(x, sin, cos):
    """x: (..., S, D); sin/cos: (S, D)."""
    xf = x.astype(jnp.float32)
    d = x.shape[-1]
    x1, x2 = xf[..., : d // 2], xf[..., d // 2:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    return (xf * cos + rotated * sin).astype(x.dtype)
