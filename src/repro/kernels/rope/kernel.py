"""Rotary positional embedding Pallas kernel (paper Fig. 9).

Memory-bound elementwise rotate: out = x*cos + rotate_half(x)*sin with the
(S, D) sin/cos tables streamed once per sequence block and reused across the
(batch, head) grid dims — the same reuse the paper's RoPE kernel gets from
keeping the tables resident.

sin/cos are passed *duplicated across halves* (shape (S, D)) so the kernel's
minor dim stays lane-aligned (128) — the TPU analogue of the paper's "pick
layouts that keep every access pattern conflict-free" rule.

The sequence block comes from a 1-D :class:`~repro.core.policy.KernelPolicy`
(``rope`` kind; block_m = block_s, block_k = head_dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import obs
from repro.core import tiles
from repro.core.policy import KernelPolicy, resolve_policy


def _rope_kernel(x_ref, sin_ref, cos_ref, o_ref):
    x = x_ref[0, 0].astype(jnp.float32)
    sin = sin_ref[...].astype(jnp.float32)
    cos = cos_ref[...].astype(jnp.float32)
    d = x.shape[-1]
    x1 = x[:, : d // 2]
    x2 = x[:, d // 2:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    o_ref[0, 0] = (x * cos + rotated * sin).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("policy", "interpret"))
def _rope(x, sin, cos, *, policy: KernelPolicy, interpret: bool):
    b, h, s, d = x.shape
    assert sin.shape == (s, d) and cos.shape == (s, d), (sin.shape, x.shape)
    block_s = min(policy.block_rows, s)
    assert s % block_s == 0

    x_spec = tiles.block_spec((1, 1, block_s, d),
                              lambda b_, h_, i: (b_, h_, i, 0), x.dtype,
                              allow_ragged_minor=tiles.shape_ragged(
                                  s, d, x.dtype))
    t_spec = tiles.block_spec((block_s, d), lambda b_, h_, i: (i, 0),
                              sin.dtype,
                              allow_ragged_minor=tiles.shape_ragged(
                                  s, d, sin.dtype))
    return pl.pallas_call(
        _rope_kernel,
        grid=(b, h, s // block_s),
        in_specs=[x_spec, t_spec, t_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, sin, cos)


def rope_pallas(x, sin, cos, *, policy: KernelPolicy | None = None,
                block_s: int | None = None, interpret: bool = True):
    """x: (B, H, S, D); sin/cos: (S, D) duplicated halves. Returns rotated x.

    Explicit ``block_s`` is the deprecated pre-policy surface; with neither
    a policy nor a block, the autotuner selects the sequence block.
    """
    if policy is None:
        b, h, s, d = x.shape
        legacy = (None if block_s is None
                  else dict(block_s=min(block_s, s), d=d))
        policy = resolve_policy("rope", (b, h, s, d), x.dtype,
                                legacy_blocks=legacy, warn_what="rope_pallas")
    if obs.enabled():
        from repro.core import perf_model as pm
        b, h, s, d = x.shape
        obs.launch("rope",
                   grid=(b, h, max(1, s // min(policy.block_rows, s))),
                   policy=policy,
                   dma_bytes=pm.rope_traffic(b, h, s, d),
                   flops=6 * b * h * s * d)
    return _rope(x, sin, cos, policy=policy, interpret=interpret)
