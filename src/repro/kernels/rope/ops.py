"""Public RoPE op with mode dispatch + custom VJP.

RoPE is linear in x and the rotation is orthogonal, so the VJP is simply the
rotation by −θ — the same kernel with negated sin.
"""
from __future__ import annotations

import functools

import jax

from .kernel import rope_pallas
from .ref import rope_ref, rope_tables  # noqa: F401


def _run(x, sin, cos, interpret: bool):
    s = x.shape[2]
    block_s = 256
    while s % block_s:
        block_s //= 2
    return rope_pallas(x, sin, cos, block_s=block_s, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _rope(x, sin, cos, interpret):
    return _run(x, sin, cos, interpret)


def _rope_fwd(x, sin, cos, interpret):
    return _run(x, sin, cos, interpret), (sin, cos)


def _rope_bwd(interpret, res, g):
    sin, cos = res
    return _run(g, -sin, cos, interpret), None, None


_rope.defvjp(_rope_fwd, _rope_bwd)


def rope(x, sin, cos, *, mode: str = "pallas_interpret"):
    """Apply rotary embedding. x: (B, H, S, D); sin/cos: (S, D)."""
    if mode == "reference":
        return rope_ref(x, sin, cos)
    return _rope(x, sin, cos, mode == "pallas_interpret")
