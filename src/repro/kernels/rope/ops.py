"""Public RoPE op with mode dispatch + custom VJP.

RoPE is linear in x and the rotation is orthogonal, so the VJP is simply the
rotation by −θ — the same kernel with negated sin (run under the same policy:
the cotangent has the forward's shape, so the forward's tuned block applies).
"""
from __future__ import annotations

import functools

import jax

from repro.core.policy import KernelPolicy
from .kernel import rope_pallas
from .ref import rope_ref, rope_tables  # noqa: F401


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _rope(x, sin, cos, policy, interpret):
    return rope_pallas(x, sin, cos, policy=policy, interpret=interpret)


def _rope_fwd(x, sin, cos, policy, interpret):
    return rope_pallas(x, sin, cos, policy=policy, interpret=interpret), (sin, cos)


def _rope_bwd(policy, interpret, res, g):
    sin, cos = res
    return rope_pallas(g, -sin, cos, policy=policy, interpret=interpret), None, None


_rope.defvjp(_rope_fwd, _rope_bwd)


def rope(x, sin, cos, *, policy: KernelPolicy | None = None,
         mode: str = "pallas_interpret"):
    """Apply rotary embedding. x: (B, H, S, D); sin/cos: (S, D)."""
    if mode == "reference":
        return rope_ref(x, sin, cos)
    return _rope(x, sin, cos, policy, mode == "pallas_interpret")
