from .optimizer import (AdamWConfig, adamw_init, adamw_update,  # noqa: F401
                        cosine_schedule, wsd_schedule, constant_schedule,
                        global_norm, clip_by_global_norm)
from .compression import ef_compress, ef_init, compressed_psum  # noqa: F401
