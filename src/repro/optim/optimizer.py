"""AdamW + LR schedules (incl. MiniCPM's WSD) + global-norm clipping.

Pure-pytree implementation (no optax dependency). Optimizer state layout is
{'m': tree, 'v': tree, 'count': scalar}; ZeRO-1 sharding of m/v over the data
axis is decided by distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(1, warmup)
        frac = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, min_ratio: float = 0.01) -> Callable:
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup, long
    constant plateau, sharp exponential-style decay over the last
    ``decay_frac`` of training."""
    decay_steps = max(1, int(total * decay_frac))
    stable_end = total - decay_steps

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(1, warmup)
        frac = jnp.clip((step - stable_end) / decay_steps, 0, 1)
        decay = peak_lr * jnp.power(min_ratio, frac)  # exp decay to min_ratio
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < stable_end, peak_lr, decay))
        return out
    return lr


def constant_schedule(lr_value: float) -> Callable:
    return lambda step: jnp.asarray(lr_value, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = cfg.schedule(count)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     state["v"], grads)
    bc1 = 1 - cfg.b1 ** cf
    bc2 = 1 - cfg.b2 ** cf

    def upd(p, m_, v_):
        step = m_ / bc1 / (jnp.sqrt(v_ / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
