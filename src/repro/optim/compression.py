"""int8 error-feedback gradient compression (distributed-optimization trick).

On a real fleet the slow hop is the cross-pod data-parallel all-reduce; int8
quantization cuts its bytes 4x. Error feedback (Seide et al. / EF-SGD) keeps
the quantization bias from accumulating: the residual of each step's
quantization is added back into the next step's gradient.

Two layers here:
  * :func:`ef_compress` — pure numerics (quantize → dequantize + EF state),
    applied to gradients before the optimizer. This is exactly what the
    receiving end of a compressed all-reduce sees, so convergence behavior is
    faithfully exercised even on one process.
  * :func:`compressed_psum` — the shard_map collective: quantize per-shard,
    psum int32-accumulated int8 payloads, dequantize. Used by tests on the
    8-device host platform and by the launcher on a real mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _quant(g: jax.Array):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads, ef_state):
    """Quantize+dequantize each leaf with error feedback.

    Returns (dequantized grads, new ef_state). ef_state is a tree of fp32
    residuals with the same structure as grads (zeros initially).
    """
    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def ef_init(grads_or_params):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_or_params)


def compressed_psum(x: jax.Array, mesh, axis: str = "data") -> jax.Array:
    """int8-payload psum over ``axis`` of a replicated-shape array.

    Each participant quantizes its local contribution; int8 payloads are
    summed in int32 (exact), then dequantized with the max scale. 4x fewer
    bytes on the wire than an f32 ring all-reduce.
    """
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=P(*([None] * x.ndim)),
                       out_specs=P(*([None] * x.ndim)), check_rep=False)
    def inner(v):
        q, scale = _quant(v.astype(jnp.float32))
        # all participants must dequantize with a common scale: use the max
        scale = jax.lax.pmax(scale, axis)
        q = jnp.clip(jnp.round(v.astype(jnp.float32) / scale), -127, 127
                     ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return total.astype(jnp.float32) * scale

    return inner(x)
