"""Kernel-launch telemetry: launch journal, spans, counters, plan audit.

Zero-dependency observability for the whole stack (DESIGN.md §13). The
subsystem is compiled-in everywhere — every kernel entry point, the
autotuner, the serving engine, and the trainer call into this module
unconditionally — but the *disabled* path is a guarded no-op: each public
recording function's first action is a plain attribute check against the
module-level recorder stack, and no event object, dict, or formatted
string is constructed unless a recorder is active. ``null_allocations()``
is the tripwire that proves it: the internal allocation helpers bump it
if they ever run with no active recorder, so tests can assert the null
path allocated exactly nothing.

Usage (the sanctioned replacement for monkeypatch launch counting):

    from repro import obs
    with obs.capture() as cap:
        y = model(x)
    assert cap.count("gemm_fused") == 2
    obs.export_chrome_trace(cap, "trace.json")

Four record types share one Recorder:

- ``LaunchEvent``  — one per kernel-entry Python call (trace/dispatch
  semantics: a jitted caller re-using its cache emits nothing, exactly
  like the old monkeypatch counters).
- ``SpanEvent``    — begin/end wall-clock intervals (``obs.span``).
- counters        — monotonic floats (``obs.incr``), exported flat.
- ``PlanDecision`` — every ``select_policy``/``select_fusion`` verdict
  with the losing candidates and their modeled bytes.

Exporters emit Chrome-trace/Perfetto JSON (``traceEvents``) and a flat
counters JSON; both are validated by ``tools/trace_check.py`` in CI.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any

__all__ = [
    "LaunchEvent", "SpanEvent", "PlanDecision", "Recorder",
    "capture", "enabled", "timing_enabled", "launch", "incr", "span",
    "plan_decision", "null_allocations", "reset_null_allocations",
    "export_chrome_trace", "export_counters", "chrome_trace_events",
]


# ---------------------------------------------------------------------------
# Event records
# ---------------------------------------------------------------------------

@dataclass
class LaunchEvent:
    """One kernel-entry call. ``dma_bytes``/``flops`` are the analytic
    perf_model numbers the caller already had in hand (never recomputed
    here); ``wall_s`` is only filled when the capture asked for timing
    (the instrumentation site then blocks on the result)."""
    op: str                       # journal op kind, e.g. "gemm_fused"
    variant: str = ""             # free-form: "da", "paged", "prenorm", ...
    grid: tuple | None = None
    policy: dict | None = None    # KernelPolicy.describe() payload
    chain: str | None = None      # chain-spec summary (epilogue/prologue)
    dma_bytes: int | None = None
    flops: int | None = None
    wall_s: float | None = None
    ts: float = 0.0               # perf_counter seconds at record time

    def to_json(self) -> dict:
        d = {"op": self.op, "ts": self.ts}
        for k in ("variant", "grid", "policy", "chain", "dma_bytes",
                  "flops", "wall_s"):
            v = getattr(self, k)
            if v not in (None, ""):
                d[k] = list(v) if k == "grid" else v
        return d


@dataclass
class SpanEvent:
    name: str
    ts: float                     # begin, perf_counter seconds
    dur: float                    # seconds
    meta: dict | None = None

    def to_json(self) -> dict:
        d = {"name": self.name, "ts": self.ts, "dur": self.dur}
        if self.meta:
            d["meta"] = self.meta
        return d


@dataclass
class PlanDecision:
    """One autotuner verdict. ``kind`` is "policy" (select_policy),
    "fusion" (select_fusion), or "bwd_route" (select_bwd_mode — the
    bwd_mode='auto' kernel-vs-oracle routing); ``candidates`` lists every
    scored loser with its modeled time/bytes so the choice is explainable
    after the fact. ``cached`` marks a memo replay (same decision, zero
    rescoring)."""
    kind: str
    op: str
    shape: tuple
    dtype: str
    chosen: Any
    candidates: list = field(default_factory=list)
    cached: bool = False
    ts: float = 0.0

    def to_json(self) -> dict:
        return {"kind": self.kind, "op": self.op, "shape": list(self.shape),
                "dtype": self.dtype, "chosen": self.chosen,
                "candidates": self.candidates, "cached": self.cached,
                "ts": self.ts}


# ---------------------------------------------------------------------------
# Recorder + module state
# ---------------------------------------------------------------------------

class Recorder:
    """Accumulates events for one ``capture()`` window."""

    def __init__(self, *, timing: bool = False):
        self.timing = timing
        self.launches: list[LaunchEvent] = []
        self.spans: list[SpanEvent] = []
        self.counters: dict[str, float] = {}
        self.plans: list[PlanDecision] = []

    # -- queries ------------------------------------------------------------
    def count(self, op: str | None = None, variant: str | None = None) -> int:
        """Number of journal launches matching ``op`` (and ``variant``)."""
        n = 0
        for e in self.launches:
            if op is not None and e.op != op:
                continue
            if variant is not None and e.variant != variant:
                continue
            n += 1
        return n

    def launch_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.launches:
            out[e.op] = out.get(e.op, 0) + 1
        return out

    def modeled_bytes(self, op: str | None = None) -> int:
        """Sum of journal-carried modeled dma_bytes (op-filtered)."""
        return sum(e.dma_bytes or 0 for e in self.launches
                   if op is None or e.op == op)

    def counter(self, name: str) -> float:
        return self.counters.get(name, 0.0)

    def plans_of(self, kind: str) -> list:
        """Plan decisions of one kind ('policy' | 'fusion' | 'bwd_route'),
        in journal order."""
        return [p for p in self.plans if p.kind == kind]

    def summary(self) -> dict:
        """The ``telemetry`` block embedded in BENCH_<key>.json."""
        return {
            "launches": self.launch_counts(),
            "modeled_dma_bytes": {
                op: self.modeled_bytes(op) for op in self.launch_counts()},
            "counters": dict(sorted(self.counters.items())),
            "plan_decisions": len(self.plans),
            "spans": len(self.spans),
        }


class _State(threading.local):
    def __init__(self):
        self.stack: list[Recorder] = []


_STATE = _State()
_LOCK = threading.Lock()
_NULL_ALLOCS = 0          # bumped only if an event is built while disabled
_EPOCH = time.perf_counter()


def _now() -> float:
    return time.perf_counter() - _EPOCH


def enabled() -> bool:
    """True when at least one ``capture()`` window is active (this thread)."""
    return bool(_STATE.stack)


def timing_enabled() -> bool:
    """True when the innermost active capture asked for wall-clock timing
    (instrumentation sites then ``block_until_ready`` and fill wall_s)."""
    s = _STATE.stack
    return bool(s) and s[-1].timing


def null_allocations() -> int:
    """How many event objects were built with no recorder active. The
    zero-overhead contract (DESIGN.md §13) is that this stays 0: every
    recording helper returns before allocating when disabled."""
    return _NULL_ALLOCS


def reset_null_allocations() -> None:
    global _NULL_ALLOCS
    with _LOCK:
        _NULL_ALLOCS = 0


def _record_launch(ev: LaunchEvent) -> None:
    global _NULL_ALLOCS
    s = _STATE.stack
    if not s:                       # tripwire: caller skipped the guard
        with _LOCK:
            _NULL_ALLOCS += 1
        return
    for rec in s:
        rec.launches.append(ev)


# ---------------------------------------------------------------------------
# Recording API (every function's first line is the disabled-path guard)
# ---------------------------------------------------------------------------

def launch(op: str, *, variant: str = "", grid=None, policy=None,
           chain=None, dma_bytes=None, flops=None, wall_s=None) -> None:
    """Journal one kernel-entry call. ``policy`` may be a KernelPolicy
    (its ``describe()`` runs lazily, only here) or an already-built dict."""
    if not _STATE.stack:
        return
    if policy is not None and not isinstance(policy, dict):
        describe = getattr(policy, "describe", None)
        policy = describe() if describe else {"policy": str(policy)}
    if grid is not None:
        grid = tuple(grid)
    _record_launch(LaunchEvent(op=op, variant=variant, grid=grid,
                               policy=policy, chain=chain,
                               dma_bytes=dma_bytes, flops=flops,
                               wall_s=wall_s, ts=_now()))


def incr(name: str, value: float = 1.0) -> None:
    """Bump a monotonic counter in every active recorder."""
    s = _STATE.stack
    if not s:
        return
    for rec in s:
        rec.counters[name] = rec.counters.get(name, 0.0) + value


def gauge(name: str, value: float) -> None:
    """Record the running max of a value (peak occupancy and friends)."""
    s = _STATE.stack
    if not s:
        return
    for rec in s:
        if value > rec.counters.get(name, float("-inf")):
            rec.counters[name] = value


@contextmanager
def span(name: str, **meta):
    """Wall-clock interval: ``with obs.span("prefill", seq=512): ...``.
    Free when disabled — no timestamps are taken, no dict is built."""
    if not _STATE.stack:
        yield
        return
    t0 = _now()
    try:
        yield
    finally:
        ev = SpanEvent(name=name, ts=t0, dur=_now() - t0,
                       meta=meta or None)
        for rec in _STATE.stack:
            rec.spans.append(ev)


def plan_decision(kind: str, op: str, shape, dtype: str, chosen,
                  candidates=None, cached: bool = False) -> None:
    """Audit one autotuner verdict (select_policy / select_fusion)."""
    s = _STATE.stack
    if not s:
        return
    ev = PlanDecision(kind=kind, op=op, shape=tuple(shape), dtype=dtype,
                      chosen=chosen, candidates=list(candidates or []),
                      cached=cached, ts=_now())
    for rec in s:
        rec.plans.append(ev)


@contextmanager
def capture(*, timing: bool = False):
    """Activate a fresh Recorder for the dynamic extent of the block and
    yield it. Nested captures each see every event recorded inside them
    (events fan out to the whole stack)."""
    rec = Recorder(timing=timing)
    _STATE.stack.append(rec)
    try:
        yield rec
    finally:
        _STATE.stack.remove(rec)


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

_PID = 1
_TID_LAUNCH = 1   # kernel-launch journal track
_TID_SPAN = 2     # span track


def chrome_trace_events(rec: Recorder) -> list[dict]:
    """Flatten a Recorder into Chrome-trace ``traceEvents`` (Perfetto
    opens these directly). Launches are instant events ('i') unless they
    carry wall time (then complete events 'X'); spans are 'X'; counters
    land as one final 'C' sample per series."""
    events: list[dict] = []
    for e in rec.launches:
        args: dict[str, Any] = {}
        for k in ("variant", "chain", "dma_bytes", "flops"):
            v = getattr(e, k)
            if v not in (None, ""):
                args[k] = v
        if e.grid is not None:
            args["grid"] = list(e.grid)
        if e.policy is not None:
            args["policy"] = e.policy
        base = {"name": e.op, "cat": "launch", "pid": _PID,
                "tid": _TID_LAUNCH, "ts": e.ts * 1e6, "args": args}
        if e.wall_s is not None:
            events.append({**base, "ph": "X", "dur": e.wall_s * 1e6})
        else:
            events.append({**base, "ph": "i", "s": "t"})
    for sp in rec.spans:
        events.append({"name": sp.name, "cat": "span", "ph": "X",
                       "pid": _PID, "tid": _TID_SPAN, "ts": sp.ts * 1e6,
                       "dur": sp.dur * 1e6, "args": sp.meta or {}})
    t_end = max([e.ts for e in rec.launches]
                + [sp.ts + sp.dur for sp in rec.spans] + [0.0])
    for name, value in sorted(rec.counters.items()):
        events.append({"name": name, "cat": "counter", "ph": "C",
                       "pid": _PID, "ts": t_end * 1e6,
                       "args": {"value": value}})
    return events


def export_chrome_trace(rec: Recorder, path) -> str:
    """Write Perfetto-loadable Chrome trace JSON; returns the path."""
    doc = {"traceEvents": chrome_trace_events(rec),
           "displayTimeUnit": "ms",
           "otherData": {"producer": "repro.obs",
                         "plan_decisions": [p.to_json() for p in rec.plans]}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return str(path)


def export_counters(rec: Recorder, path) -> str:
    """Write the flat counters JSON (stable sorted keys); returns path."""
    doc = {"counters": dict(sorted(rec.counters.items())),
           "launches": rec.launch_counts()}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return str(path)
