"""Train state: params + AdamW moments + step (+ optional EF buffers).

Plain-dict pytree so checkpointing stays trivially portable. Sharding of
every leaf is decided once here (logical rules + optional ZeRO-1) and reused
by the jitted step, the checkpoint restore path, and the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (shardings_for_tree, zero1_shardings,
                                        fsdp_shardings)
from repro.optim import adamw_init, ef_init


def init_state(model, rng, *, grad_compress: bool = False) -> dict:
    params = model.init(rng)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if grad_compress:
        state["ef"] = ef_init(params)
    return state


def abstract_state(model, *, grad_compress: bool = False) -> dict:
    params = model.abstract()
    zeros = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    state = {"params": params,
             "opt": {"m": zeros(params), "v": zeros(params),
                     "count": jax.ShapeDtypeStruct((), jnp.int32)},
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if grad_compress:
        state["ef"] = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params)
    return state


def state_shardings(model, mesh, *, zero1: bool = True, fsdp: bool = False,
                    grad_compress: bool = False, report=None) -> dict:
    axes = model.axes()
    abs_params = model.abstract()
    p_sh = shardings_for_tree(axes, abs_params, mesh, report=report)
    if fsdp:
        p_sh = fsdp_shardings(p_sh, abs_params, mesh)
    moments = zero1_shardings(p_sh, abs_params, mesh) if zero1 else p_sh
    rep = NamedSharding(mesh, P())
    sh = {"params": p_sh,
          "opt": {"m": moments, "v": moments, "count": rep},
          "step": rep}
    if grad_compress:
        sh["ef"] = p_sh
    return sh


def sharded_init(model, rng, mesh, *, zero1: bool = True,
                 grad_compress: bool = False) -> dict:
    """Initialize directly into the sharded layout (jit with out_shardings —
    no single-host materialization of the full state)."""
    shardings = state_shardings(model, mesh, zero1=zero1,
                                grad_compress=grad_compress)
    fn = jax.jit(lambda r: init_state(model, r, grad_compress=grad_compress),
                 out_shardings=shardings)
    return fn(rng)
