"""Fault-tolerant checkpointing: atomic, versioned, async, resharding-safe.

Layout: <dir>/step_<N>/arrays.npz + manifest.json (sha256 of the payload,
step, leaf paths). Writes go to a tmp dir then ``os.replace`` — a crash
mid-save can never corrupt the latest checkpoint. ``save_async`` snapshots
to host memory synchronously (cheap) and writes in a background thread so
the train loop keeps stepping. Restore takes target shardings, so a run may
resume on a *different* mesh (elastic restart).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading

import numpy as np

import jax


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(state, directory: str, step: int, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(jax.device_get(state))
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        payload = os.path.join(tmp, "arrays.npz")
        np.savez(payload, **flat)
        digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": int(step), "sha256": digest,
                       "keys": sorted(flat)}, f)
        final = os.path.join(directory, f"step_{int(step):08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background; at most one inflight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: BaseException | None = None

    def save(self, state, step: int) -> None:
        self.wait()
        host_state = jax.device_get(state)   # synchronous snapshot

        def work():
            try:
                save(host_state, self.directory, step, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in sorted(os.listdir(directory)):
        if not d.startswith("step_"):
            continue
        man = os.path.join(directory, d, "manifest.json")
        payload = os.path.join(directory, d, "arrays.npz")
        if not (os.path.exists(man) and os.path.exists(payload)):
            continue
        meta = json.load(open(man))
        digest = hashlib.sha256(open(payload, "rb").read()).hexdigest()
        if digest == meta["sha256"]:          # integrity check
            out.append(meta["step"])
    return out


def restore(directory: str, template, *, step: int | None = None,
            shardings=None):
    """Restore into ``template``'s structure; place per ``shardings`` (which
    may describe a different mesh than the one that saved — elastic)."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no valid checkpoints under {directory}")
    step = max(steps) if step is None else step
    payload = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    arrays = np.load(payload)
    flat_tpl, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tpl in flat_tpl:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(tpl.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs template {tpl.shape}")
        leaves.append(arr.astype(tpl.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
