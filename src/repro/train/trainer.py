"""Train-step factory + fault-tolerant training loop.

``make_train_step`` builds the jitted step: microbatched grad accumulation
(lax.scan — keeps the backward of microbatch k overlappable with the grad
reduce-scatter of k-1 under XLA's latency-hiding scheduler), optional int8
error-feedback gradient compression, AdamW, donated state.

``train_loop`` adds the operational layer: checkpoint/restart (async, atomic),
failure injection → restore-latest recovery, straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import autotune
from repro.optim import AdamWConfig, adamw_update, ef_compress
from repro.distributed.sharding import batch_specs
from . import checkpoint as ckpt_lib
from .state import init_state, sharded_init, state_shardings


class SimulatedFailure(RuntimeError):
    """Raised by the failure injector to emulate a node loss."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``factor``× the running median and invokes a
    mitigation hook (on a real fleet: re-shard away from the slow host; here:
    record + notify)."""
    factor: float = 3.0
    warmup: int = 5
    durations: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def observe(self, step: int, seconds: float) -> bool:
        self.durations.append(seconds)
        if len(self.durations) <= self.warmup:
            return False
        med = sorted(self.durations)[len(self.durations) // 2]
        if seconds > self.factor * med:
            self.events.append((step, seconds, med))
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
            return True
        return False


def _split_microbatches(batch: dict, n: int) -> dict:
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(model, opt_cfg: AdamWConfig, *, mesh=None,
                    zero1: bool = True, grad_compress: bool = False,
                    microbatches: int = 1, donate: bool = True):
    """Returns a jitted (state, batch) -> (state, metrics) function."""

    def step_fn(state, batch):
        def loss_fn(params, mb):
            loss, metrics = model.loss(params, mb)
            return loss, metrics

        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def accum(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], mb)
                gsum = jax.tree.map(jnp.add, gsum,
                                    jax.tree.map(lambda g: g.astype(jnp.float32), grads))
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {"ce": loss, "aux": jnp.zeros(())}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)

        new_state = dict(state)
        if grad_compress:
            grads, new_ef = ef_compress(grads, state["ef"])
            new_state["ef"] = new_ef

        new_params, new_opt, om = adamw_update(opt_cfg, grads,
                                               state["opt"], state["params"])
        new_state.update(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        return new_state, {"loss": loss, **metrics, **om}

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    sh = state_shardings(model, mesh, zero1=zero1,
                         grad_compress=grad_compress)
    abs_batch = None  # batch shardings applied by caller via device_put
    return jax.jit(step_fn, in_shardings=(sh, None),
                   out_shardings=(sh, None),
                   donate_argnums=(0,) if donate else ())


@dataclasses.dataclass
class TrainLoopResult:
    state: dict
    losses: list
    restarts: int
    straggler_events: list
    # {(batch, seq): {op: KernelPolicy}} — one entry per compiled bucket
    policies: dict = dataclasses.field(default_factory=dict)


def pin_bucket_policies(model, batch: dict, pinned: dict,
                        log: Callable = print, mesh=None) -> dict:
    """Resolve + pin the kernel policies for this batch's compiled bucket.

    XLA compiles one step function per input shape; the autotuner memoizes
    one policy set per shape-bucket — pinning here makes the pairing
    explicit and reproducible in the training log (DESIGN.md §5). With a
    ``mesh`` carrying a model axis, the plan decisions are scored with the
    sharded collective chain term (DESIGN.md §16) — a different sharding is
    a different bucket, the same way a different dtype is.
    """
    inputs = batch.get("inputs") if isinstance(batch, dict) else batch
    if inputs is None or getattr(inputs, "ndim", 0) < 2:
        return pinned
    key = (int(inputs.shape[0]), int(inputs.shape[1]))
    if key not in pinned:
        from repro.distributed.sharding import train_shard_spec

        shard = train_shard_spec(model.cfg, mesh)
        pols = autotune.policies_for_model(model.cfg, batch=key[0],
                                           seq_len=key[1], shard=shard)
        pinned[key] = pols
        if obs.enabled():   # guard: no f-string on the disabled path
            obs.incr("trainer.bucket_pins")
            obs.incr(f"trainer.bucket_pins.{key[0]}x{key[1]}")
        desc = "; ".join(f"{op}={p.schedule.name}{tuple(p.describe()['blocks'])}"
                         for op, p in sorted(pols.items()))
        log(f"[trainer] bucket {key}: pinned kernel policies "
            f"{desc or '(none)'}")
    return pinned


def train_loop(model, data_iter, num_steps: int, opt_cfg: AdamWConfig, *,
               rng=None, mesh=None, zero1: bool = False,
               grad_compress: bool = False, microbatches: int = 1,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               failure_injector: Optional[FailureInjector] = None,
               watchdog: Optional[StragglerWatchdog] = None,
               max_restarts: int = 3, log_every: int = 10,
               pretuned=None,
               log: Callable = print) -> TrainLoopResult:
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if pretuned is not None:
        # calibrated policy table (path or report dict); installed before
        # the first bucket pin so pinned policies come from measurement
        autotune.use_pretuned(pretuned)
    step_fn = make_train_step(model, opt_cfg, mesh=mesh, zero1=zero1,
                              grad_compress=grad_compress,
                              microbatches=microbatches)

    def fresh_state():
        if mesh is not None:
            return sharded_init(model, rng, mesh, zero1=zero1,
                                grad_compress=grad_compress)
        return init_state(model, rng, grad_compress=grad_compress)

    checkpointer = (ckpt_lib.AsyncCheckpointer(ckpt_dir)
                    if ckpt_dir is not None else None)

    # resume if a valid checkpoint exists
    state = None
    if ckpt_dir is not None and ckpt_lib.available_steps(ckpt_dir):
        template = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
            if hasattr(a, "shape") else a, jax.eval_shape(fresh_state))
        sh = (state_shardings(model, mesh, zero1=zero1,
                              grad_compress=grad_compress)
              if mesh is not None else None)
        state, step0 = ckpt_lib.restore(ckpt_dir, template, shardings=sh)
        data_iter.load_state_dict({"step": step0})
        log(f"[trainer] resumed from checkpoint at step {step0}")
    if state is None:
        state = fresh_state()

    losses: list = []
    restarts = 0
    pinned_policies: dict = {}
    step = int(jax.device_get(state["step"]))
    while step < num_steps:
        try:
            batch = next(data_iter)
            pin_bucket_policies(model, batch, pinned_policies, log=log,
                                mesh=mesh)
            t0 = time.perf_counter()
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            with obs.span("trainer.step", step=step):
                state, metrics = step_fn(state, batch)
                loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            obs.incr("trainer.steps")
            if watchdog is not None:
                watchdog.observe(step, dt)
            losses.append(loss)
            step += 1
            if log_every and step % log_every == 0:
                log(f"[trainer] step {step:5d} loss {loss:.4f} "
                    f"({dt*1e3:.0f} ms)")
            if checkpointer is not None and step % ckpt_every == 0:
                checkpointer.save(state, step)
        except SimulatedFailure as e:
            restarts += 1
            log(f"[trainer] {e} — recovering (restart {restarts})")
            if restarts > max_restarts:
                raise
            if checkpointer is not None:
                checkpointer.wait()
            if ckpt_dir is not None and ckpt_lib.available_steps(ckpt_dir):
                template = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                    jax.eval_shape(fresh_state))
                sh = (state_shardings(model, mesh, zero1=zero1,
                                      grad_compress=grad_compress)
                      if mesh is not None else None)
                state, step0 = ckpt_lib.restore(ckpt_dir, template,
                                                shardings=sh)
                data_iter.load_state_dict({"step": step0})
                step = step0
                log(f"[trainer] restored step {step0}")
            else:
                state = fresh_state()
                data_iter.load_state_dict({"step": 0})
                step = 0
                log("[trainer] no checkpoint — restarted from scratch")

    if checkpointer is not None:
        checkpointer.save(state, step)
        checkpointer.wait()
    return TrainLoopResult(state, losses,
                           restarts,
                           watchdog.events if watchdog else [],
                           policies=pinned_policies)
