from .state import init_state, abstract_state, state_shardings, sharded_init  # noqa: F401
from .trainer import (make_train_step, train_loop, FailureInjector,  # noqa: F401
                      StragglerWatchdog, SimulatedFailure, TrainLoopResult)
from . import checkpoint  # noqa: F401
