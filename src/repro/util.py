"""Small shared utilities."""
from __future__ import annotations

import os


def costing_mode() -> bool:
    """True while the dry-run is costing HLO.

    XLA's cost_analysis counts a rolled ``lax.scan`` body ONCE, not
    trip-count times (verified empirically — exactly 1/L). Under costing
    mode, inner scans (chunked attention, SSD chunk scan) unroll so their
    work is counted; the *layer* scan is handled by the dry-run's L=1/L=2
    extrapolation instead (see launch/dryrun.py).
    """
    return os.environ.get("REPRO_COSTING", "0") == "1"


def scan_unroll() -> bool | int:
    return True if costing_mode() else 1
