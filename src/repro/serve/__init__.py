from .engine import (Engine, GenerationResult, PagedEngine,  # noqa: F401
                     Request, RequestQueue)
from . import kv_cache  # noqa: F401
