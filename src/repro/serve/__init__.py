from .engine import (Engine, GenerationResult, PagedEngine,  # noqa: F401
                     Request, RequestQueue)
from .topology import ShardedPagedEngine  # noqa: F401
from . import kv_cache  # noqa: F401
