from .engine import Engine, Request, RequestQueue, GenerationResult  # noqa: F401
