"""Batched serving engine: prefill + jitted decode loop + request queue.

The engine serves fixed-shape batches (the production pattern for TPU
serving: one compiled prefill and one compiled decode_step per bucket).
Each (batch, prompt_len) bucket also pins the KernelPolicy set its compiled
functions resolve to — the autotuner's per-shape-bucket memoization means
the pinned policy and the policy the kernels trace with are the same object
(DESIGN.md §5), so the report in :attr:`Engine.bucket_policies` is exact.

``RequestQueue`` adds a continuous-batching-lite layer: requests are bucketed
by padded prompt length and flushed as full batches.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, prompt + generated)
    prompt_len: int
    steps: int


class Engine:
    def __init__(self, model, params, *, max_len: int = 4096, mesh=None,
                 donate_cache: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        # (batch, prompt_len) bucket -> {op: KernelPolicy} pinned at first use
        self.bucket_policies: dict[tuple, dict] = {}
        self._decode = jax.jit(
            lambda params, tok, cache, pos: model.decode_step(
                params, tok, cache, pos),
            donate_argnums=(2,) if donate_cache else ())
        self._prefill = jax.jit(
            lambda params, batch, cache: model.prefill(params, batch, cache))

    def _pin_bucket(self, batch: int, prompt_len: int) -> dict:
        """Resolve + memoize the kernel policies for a compiled bucket."""
        key = (batch, prompt_len)
        if key not in self.bucket_policies:
            self.bucket_policies[key] = autotune.policies_for_model(
                self.model.cfg, batch=batch, seq_len=prompt_len)
        return self.bucket_policies[key]

    def _sample(self, logits, temperature: float, rng):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / temperature, axis=-1)

    def generate(self, prompts, max_new_tokens: int, *,
                 temperature: float = 0.0, rng=None,
                 extra_batch: Optional[dict] = None) -> GenerationResult:
        """prompts: (B, S) int32. Greedy (T=0) or temperature sampling."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        self._pin_bucket(b, s)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = self.model.init_cache(b, self.max_len)
        if self.model.cfg.family == "encdec":
            batch = dict(extra_batch or {}, inputs=prompts)
            cache, logits = self._prefill(self.params, batch, cache)
        else:
            cache, logits = self._prefill(self.params, prompts, cache)
        toks = [prompts]
        rngs = jax.random.split(rng, max_new_tokens)
        next_tok = self._sample(logits, temperature, rngs[0])[:, None]
        for i in range(max_new_tokens):
            toks.append(next_tok)
            if i == max_new_tokens - 1:
                break
            cache, logits = self._decode(self.params, next_tok, cache, s + i)
            next_tok = self._sample(logits, temperature, rngs[i + 1])[:, None]
        out = np.asarray(jnp.concatenate(toks, axis=1))
        return GenerationResult(out, s, max_new_tokens)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int


class RequestQueue:
    """Continuous-batching-lite: bucket by padded length, flush full batches."""

    def __init__(self, engine: Engine, batch_size: int,
                 buckets=(128, 512, 2048)):
        self.engine = engine
        self.batch_size = batch_size
        self.buckets = sorted(buckets)
        self.pending: dict[int, list[Request]] = {b: [] for b in self.buckets}
        self.results: dict[int, np.ndarray] = {}

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket")

    def submit(self, req: Request) -> None:
        self.pending[self._bucket(len(req.prompt))].append(req)

    def flush(self, *, force: bool = False) -> int:
        """Serve full (or, with ``force``, padded partial) batches.

        Returns the number of *real* requests served — padding duplicates of
        the last request (which fill out a forced partial batch to the
        compiled batch size) are not counted. A resubmitted uid overwrites
        its previous result with a warning rather than being silently
        dropped.
        """
        served = 0
        for bucket, reqs in self.pending.items():
            while len(reqs) >= self.batch_size or (force and reqs):
                group = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                n_real = len(group)
                while len(group) < self.batch_size:   # pad the last batch
                    group.append(group[-1])
                prompts = np.stack([
                    np.pad(r.prompt, (bucket - len(r.prompt), 0))
                    for r in group])
                max_new = max(r.max_new_tokens for r in group)
                result = self.engine.generate(prompts, max_new)
                for r, row in zip(group[:n_real], result.tokens[:n_real]):
                    if r.uid in self.results:
                        warnings.warn(
                            f"RequestQueue: duplicate uid {r.uid} — "
                            "overwriting previous result", stacklevel=2)
                    self.results[r.uid] = row[bucket - len(r.prompt):]
                served += n_real
        return served
