"""Serving engines: fixed-batch prefill+decode, and paged continuous batching.

Two engines share the request surface (DESIGN.md §8):

* :class:`Engine` serves fixed-shape batches (one compiled prefill and one
  compiled decode_step per (batch, prompt_len) bucket). Each bucket pins the
  KernelPolicy set its compiled functions resolve to — the autotuner's
  per-shape-bucket memoization means the pinned policy and the policy the
  kernels trace with are the same object (DESIGN.md §5), so the report in
  :attr:`Engine.bucket_policies` is exact. Compiled buckets are held in an
  LRU capped by ``max_cached_buckets``: evicting a bucket drops its jitted
  callables (and with them the compiled executables), so a long-lived engine
  serving many shapes stays bounded.
* :class:`PagedEngine` runs continuous batching over the paged KV cache
  (``serve.kv_cache``): new requests are admitted into free batch slots
  each step (single-sequence prefill into freshly allocated pages), finished
  ones retire (pages freed) without disturbing their neighbours, and the
  one compiled decode step serves every slot regardless of its length.
  Decode policies are pinned per (batch_slots, page_count) bucket: the page
  table is sliced to the smallest power-of-two page count covering the
  active slots, so short-context phases run a smaller split-KV grid.

``RequestQueue`` is the continuous-batching-lite layer over :class:`Engine`:
requests are bucketed by padded prompt length and flushed as full batches.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import autotune
from . import kv_cache as kvc


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, prompt + generated)
    prompt_len: int
    steps: int


def _lru_get(lru: collections.OrderedDict, key, build, cap: int,
             stats: dict | None = None):
    """Get-or-build with LRU eviction — evicted entries drop their jitted
    callables (and compiled executables) with them. ``stats`` (an engine's
    hits/misses/evictions dict) is also mirrored into the telemetry
    counters when a capture is active."""
    entry = lru.get(key)
    if entry is None:
        if stats is not None:
            stats["misses"] += 1
        obs.incr("engine.bucket_lru.misses")
        entry = build()
        lru[key] = entry
        while len(lru) > cap:
            lru.popitem(last=False)
            if stats is not None:
                stats["evictions"] += 1
            obs.incr("engine.bucket_lru.evictions")
    else:
        lru.move_to_end(key)
        if stats is not None:
            stats["hits"] += 1
        obs.incr("engine.bucket_lru.hits")
    return entry


class Engine:
    def __init__(self, model, params, *, max_len: int = 4096, mesh=None,
                 donate_cache: bool = True, max_cached_buckets: int = 8,
                 pretuned=None):
        if pretuned is not None:
            # install the calibrated table (path or report dict) before any
            # bucket pins, so every pinned policy set sees it
            autotune.use_pretuned(pretuned)
        self.model = model
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self.donate_cache = donate_cache
        self.max_cached_buckets = max_cached_buckets
        # ONE LRU for every compiled-fn kind, under one shared cap:
        # (batch, prompt_len) -> {policies, prefill} and ("decode", batch)
        # -> {policies, decode}. The decode step's traced shapes depend
        # only on batch (token (B,1), max_len cache), so it gets its own
        # key kind rather than a per-prompt-length recompile — but it
        # competes for the same cap as the prefill buckets, so a long tail
        # of prompt lengths can no longer bloat the cache past the cap.
        self._buckets: collections.OrderedDict = collections.OrderedDict()
        self.lru_stats = {"hits": 0, "misses": 0, "evictions": 0}

    @property
    def bucket_policies(self) -> dict:
        """{key: {op: KernelPolicy}} of the live buckets — prefill keys are
        (batch, prompt_len), decode keys are ("decode", batch)."""
        return {k: e["policies"] for k, e in self._buckets.items()}

    def _bucket(self, batch: int, prompt_len: int) -> dict:
        """Resolve-or-evict the compiled bucket for (batch, prompt_len)."""
        model = self.model

        def build():
            return {
                "policies": autotune.policies_for_model(
                    model.cfg, batch=batch, seq_len=prompt_len,
                    decode_len=self.max_len),
                "prefill": jax.jit(
                    lambda params, batch_, cache: model.prefill(
                        params, batch_, cache)),
            }
        return _lru_get(self._buckets, (batch, prompt_len), build,
                        self.max_cached_buckets, self.lru_stats)

    def _decode_fn(self, batch: int):
        model, cfg = self.model, self.model.cfg

        def build():
            from repro.kernels.attention import resolve_decode_policy
            hkv = cfg.num_kv_heads
            return {
                "policies": {"attention_decode": resolve_decode_policy(
                    batch, hkv, cfg.num_heads // hkv, self.max_len,
                    cfg.head_dim, cfg.compute_dtype)},
                "decode": jax.jit(
                    lambda params, tok, cache, pos: model.decode_step(
                        params, tok, cache, pos),
                    donate_argnums=(2,) if self.donate_cache else ()),
            }
        return _lru_get(self._buckets, ("decode", batch), build,
                        self.max_cached_buckets, self.lru_stats)["decode"]

    def _sample(self, logits, temperature: float, rng):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / temperature, axis=-1)

    def generate(self, prompts, max_new_tokens: int, *,
                 temperature: float = 0.0, rng=None,
                 extra_batch: Optional[dict] = None) -> GenerationResult:
        """prompts: (B, S) int32. Greedy (T=0) or temperature sampling."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        entry = self._bucket(b, s)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = self.model.init_cache(b, self.max_len)
        with obs.span("engine.prefill", batch=b, prompt_len=s):
            if self.model.cfg.family == "encdec":
                batch = dict(extra_batch or {}, inputs=prompts)
                cache, logits = entry["prefill"](self.params, batch, cache)
            else:
                cache, logits = entry["prefill"](self.params, prompts, cache)
        toks = [prompts]
        rngs = jax.random.split(rng, max_new_tokens)
        decode = self._decode_fn(b)
        next_tok = self._sample(logits, temperature, rngs[0])[:, None]
        with obs.span("engine.decode", batch=b, tokens=max_new_tokens):
            for i in range(max_new_tokens):
                toks.append(next_tok)
                if i == max_new_tokens - 1:
                    break
                cache, logits = decode(self.params, next_tok, cache, s + i)
                next_tok = self._sample(logits, temperature,
                                        rngs[i + 1])[:, None]
        out = np.asarray(jnp.concatenate(toks, axis=1))
        return GenerationResult(out, s, max_new_tokens)


@dataclasses.dataclass
class Request:
    """One generation request.

    Sampling contract (docs/serving.md): ``temperature=None`` inherits the
    engine's default; 0.0 is greedy argmax — bitwise deterministic, no rng
    consumed. For temperature > 0, ``seed`` pins a per-request PRNG stream:
    :class:`PagedEngine` folds the sequence's absolute position into
    ``PRNGKey(seed)`` per emitted token, so the draw is independent of
    batch composition and admission order. Unseeded sampled requests draw
    from the engine's shared stream (reproducible per engine ``rng`` but
    schedule-dependent). :class:`RequestQueue` batches share one stream
    seeded by the batch's first seeded request.
    """
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: Optional[float] = None      # None = engine default
    seed: Optional[int] = None


class RequestQueue:
    """Continuous-batching-lite: bucket by padded length, flush full batches."""

    def __init__(self, engine: Engine, batch_size: int,
                 buckets=(128, 512, 2048)):
        self.engine = engine
        self.batch_size = batch_size
        self.buckets = sorted(buckets)
        self.pending: dict[int, list[Request]] = {b: [] for b in self.buckets}
        self.results: dict[int, np.ndarray] = {}

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket")

    def submit(self, req: Request) -> None:
        self.pending[self._bucket(len(req.prompt))].append(req)

    @property
    def engine_temperature(self) -> float:
        """The engine's default temperature (dense Engine: greedy)."""
        return getattr(self.engine, "temperature", 0.0)

    def flush(self, *, force: bool = False) -> int:
        """Serve full (or, with ``force``, padded partial) batches.

        Returns the number of *real* requests served — padding duplicates of
        the last request (which fill out a forced partial batch to the
        compiled batch size) are not counted. A resubmitted uid overwrites
        its previous result with a warning rather than being silently
        dropped.
        """
        served = 0
        for bucket, reqs in self.pending.items():
            # partition by effective temperature (order-preserving): one
            # compiled batch shares one sampling config, so mixing greedy
            # and sampled requests in a batch would silently ignore the
            # per-request temperature (the bug this plumbing fixes)
            by_temp: dict = {}
            for r in reqs:
                t = (r.temperature if r.temperature is not None
                     else self.engine_temperature)
                by_temp.setdefault(t, []).append(r)
            reqs[:] = []
            for temp, treqs in by_temp.items():
                while len(treqs) >= self.batch_size or (force and treqs):
                    group = treqs[: self.batch_size]
                    del treqs[: self.batch_size]
                    served += self._serve_batch(bucket, group, temp)
                reqs.extend(treqs)            # leftovers wait for more
        return served

    def _serve_batch(self, bucket: int, group: list, temperature: float
                     ) -> int:
        n_real = len(group)
        while len(group) < self.batch_size:   # pad the last batch
            group.append(group[-1])
        prompts = np.stack([
            np.pad(r.prompt, (bucket - len(r.prompt), 0))
            for r in group])
        max_new = max(r.max_new_tokens for r in group)
        seeds = [r.seed for r in group[:n_real] if r.seed is not None]
        rng = jax.random.PRNGKey(seeds[0]) if seeds else None
        result = self.engine.generate(prompts, max_new,
                                      temperature=temperature, rng=rng)
        for r, row in zip(group[:n_real], result.tokens[:n_real]):
            if r.uid in self.results:
                warnings.warn(
                    f"RequestQueue: duplicate uid {r.uid} — "
                    "overwriting previous result", stacklevel=2)
            self.results[r.uid] = row[bucket - len(r.prompt):]
        return n_real


# ---------------------------------------------------------------------------
# Continuous batching over the paged KV cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Host-side record of one active batch slot."""
    req: Request
    n_pages: int                 # pages currently backing the sequence
    generated: list              # sampled token ids (ints)
    next_token: int              # token to feed at the next decode step
    pages: list = dataclasses.field(default_factory=list)
    # next prompt position to prefill; -1 once prefill is complete. A slot
    # mid-prefill is masked out of the shared decode step (its page-table
    # row and length are zeroed for that launch) so decode appends cannot
    # scribble over pages the chunk loop is still filling.
    prefill_cursor: int = -1

    @property
    def prefilling(self) -> bool:
        return self.prefill_cursor >= 0


def _pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


class PagedEngine:
    """Continuous batching: paged KV cache + one compiled decode step.

    Admission: each :meth:`step` first moves pending requests into free
    batch slots while the allocator can cover their prompt pages (the
    prefill runs at the exact prompt length, compiled once per length —
    padding the tokens would contaminate recurrent-layer state). Decode:
    one compiled ``decode_step_paged`` serves every slot; the page table is
    sliced to the pinned (batch_slots, page_count) bucket so short-context
    phases run a smaller split-KV grid. Growth: a slot crossing a page
    boundary gets its next page just-in-time; if the pool is exhausted the
    youngest stalled slot is preempted (recompute policy — its pages are
    freed and a continuation request rejoins the queue front). Retirement:
    a slot that reaches ``max_new_tokens`` frees its pages and its result
    appears in :attr:`results` — its neighbours never notice.

    Serving fast paths (DESIGN.md §14, all opt-in; defaults reproduce the
    plain engine bitwise):
      * ``prefix_cache=True`` — full KV pages of completed prompts are kept
        in a refcounted trie; later prompts sharing a page-aligned prefix
        skip its prefill and share the physical pages.
      * ``chunk_tokens=C`` — prompts prefill in fixed C-token chunks, one
        per step, interleaved with decode (the mid-prefill slot is masked
        out of the shared decode launch), bounding decode stall per step.
      * ``draft_model=... , spec_tokens=k`` — greedy speculative decoding:
        the draft proposes k-1 tokens, the target verifies them in a single
        k-token decode, and each round emits 1..k tokens per sequence.
    """

    def __init__(self, model, params, *, batch_slots: int = 4,
                 page_size: int = 64, max_pages_per_seq: int = 8,
                 n_pages: Optional[int] = None, temperature: float = 0.0,
                 rng=None, max_cached_buckets: int = 8,
                 prefix_cache: bool = False,
                 chunk_tokens: Optional[int] = None,
                 draft_model=None, draft_params=None, spec_tokens: int = 0,
                 pretuned=None):
        if pretuned is not None:
            # calibrated policy table (path or report dict), installed
            # before the first page-count bucket pins its split-KV policy
            autotune.use_pretuned(pretuned)
        if model.init_paged_cache is None:
            raise ValueError(
                f"{model.cfg.name}: no paged decode surface (decoder-only "
                "LM/VLM backbones only)")
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        # +1: physical page 0 is the reserved null page
        self.n_pages = (n_pages if n_pages is not None
                        else batch_slots * max_pages_per_seq + 1)
        self.temperature = temperature
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.max_cached_buckets = max_cached_buckets

        # ---- serving fast paths (DESIGN.md §14; all off by default —
        # defaults reproduce the exact-length one-shot engine bitwise) ----
        attn_only = all(model.cfg.layer_kind(i) in ("attn", "local", "moe")
                        for i in range(model.cfg.num_layers))
        if prefix_cache and not attn_only:
            raise ValueError(
                "prefix caching shares position-addressable KV pages; "
                f"{model.cfg.name} has recurrent layers")
        if chunk_tokens is not None:
            if not attn_only:
                raise ValueError(
                    "chunked prefill re-enters the prompt mid-stream; "
                    f"{model.cfg.name}'s recurrent state cannot")
            if chunk_tokens <= 0 or chunk_tokens % page_size:
                raise ValueError(
                    f"chunk_tokens={chunk_tokens} must be a positive "
                    f"multiple of page_size={page_size}")
        self.prefix = kvc.PrefixCache(page_size) if prefix_cache else None
        self.chunk_tokens = chunk_tokens
        self.draft_model = draft_model
        self.draft_params = draft_params
        self.spec_tokens = spec_tokens
        if draft_model is not None:
            if spec_tokens < 2:
                raise ValueError("speculative decoding needs spec_tokens"
                                 " >= 2 (1 draft + 1 correction minimum)")
            if not attn_only:
                raise ValueError("speculative verify needs an attention-"
                                 f"only stack; {model.cfg.name} is hybrid")
            if temperature != 0.0:
                raise ValueError(
                    "speculative decoding acceptance is defined for greedy "
                    "sampling (temperature=0.0) in this engine")
            if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError("draft and target must share a vocabulary")
        self._spec = draft_model is not None

        self.cache = model.init_paged_cache(batch_slots, self.n_pages,
                                            page_size)
        self.draft_cache = (draft_model.init_paged_cache(
            batch_slots, self.n_pages, page_size) if self._spec else None)
        self.alloc = kvc.PageAllocator(self.n_pages)
        self.state = kvc.init_page_state(batch_slots, max_pages_per_seq)
        self.slots: dict[int, _Slot] = {}       # slot id -> active record
        self.pending: collections.deque = collections.deque()
        self.results: dict[int, np.ndarray] = {}
        self.steps = 0
        self.preemptions = 0
        self.admissions = 0
        self.tokens_generated = 0
        self.chunks_prefilled = 0
        self.spec_rounds = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_participations = 0    # (slot, round) pairs
        self.peak_pages_in_use = 0
        self.lru_stats = {"hits": 0, "misses": 0, "evictions": 0}
        # One LRU, one cap, many key kinds: (batch_slots, page_count) ->
        # decode, ("prefill", S) -> exact prefill, ("chunk", C) -> chunked/
        # suffix prefill, ("verify", page_count) -> k-token verify, and
        # "draft_*" twins of each for the speculative draft model.
        # Compiled fns are evicted with their entry.
        self._buckets: collections.OrderedDict = collections.OrderedDict()

    # -- bucket pinning ----------------------------------------------------
    @property
    def bucket_policies(self) -> dict:
        return {k: e["policies"] for k, e in self._buckets.items()}

    def _touch(self, key, build) -> dict:
        return _lru_get(self._buckets, key, build, self.max_cached_buckets,
                        self.lru_stats)

    def _note_occupancy(self) -> None:
        used = self.n_pages - 1 - self.alloc.free_pages
        if used > self.peak_pages_in_use:
            self.peak_pages_in_use = used
        obs.gauge("engine.peak_pages_in_use", used)

    def _decode_bucket(self, mp_bucket: int, *, draft: bool = False) -> dict:
        """Compiled decode + pinned split-KV policy for a page-count bucket."""
        from repro.kernels.attention import resolve_decode_policy
        model = self.draft_model if draft else self.model
        cfg = model.cfg

        def build():
            hkv = cfg.num_kv_heads
            policy = resolve_decode_policy(
                self.batch_slots, hkv, cfg.num_heads // hkv,
                mp_bucket * self.page_size, cfg.head_dim, cfg.compute_dtype,
                page_size=self.page_size)
            return {
                "policies": {"attention_decode": policy},
                "decode": jax.jit(
                    lambda params, tok, cache, pt, lens:
                        model.decode_step_paged(params, tok, cache, pt,
                                                lens),
                    donate_argnums=(2,)),   # pools are the dominant buffers
            }
        key = (("draft_decode", mp_bucket) if draft
               else (self.batch_slots, mp_bucket))
        return self._touch(key, build)

    def _prefill_bucket(self, padded_len: int, *, draft: bool = False
                        ) -> dict:
        model = self.draft_model if draft else self.model

        def build():
            return {
                "policies": autotune.policies_for_model(
                    model.cfg, batch=1, seq_len=padded_len,
                    decode_len=self.max_pages_per_seq * self.page_size),
                "prefill": jax.jit(
                    lambda params, toks, cache, rows, slot, n:
                        model.prefill_paged(params, toks, cache, rows,
                                            slot, n),
                    donate_argnums=(2,)),
            }
        key = ("draft_prefill" if draft else "prefill", padded_len)
        return self._touch(key, build)

    def _chunk_bucket(self, chunk_len: int, *, draft: bool = False) -> dict:
        """Compiled chunk/suffix prefill: ONE instance per chunk length
        serves every chunk index and every prefix-match offset (``start``
        and ``last_index`` are traced operands, not trace constants)."""
        from repro.kernels.attention import resolve_decode_policy
        model = self.draft_model if draft else self.model
        cfg = model.cfg

        def build():
            hkv = cfg.num_kv_heads
            policy = resolve_decode_policy(
                1, hkv, cfg.num_heads // hkv,
                self.max_pages_per_seq * self.page_size, cfg.head_dim,
                cfg.compute_dtype, page_size=self.page_size,
                q_tokens=chunk_len)
            return {
                "policies": {"attention_decode": policy},
                "chunk": jax.jit(
                    lambda params, toks, cache, rows, start, last:
                        model.prefill_paged_chunk(params, toks, cache, rows,
                                                  start, last),
                    donate_argnums=(2,)),
            }
        key = ("draft_chunk" if draft else "chunk", chunk_len)
        return self._touch(key, build)

    def _verify_bucket(self, mp_bucket: int) -> dict:
        """Compiled k-token verify step (the speculative target pass)."""
        from repro.kernels.attention import resolve_decode_policy
        model, cfg = self.model, self.model.cfg

        def build():
            hkv = cfg.num_kv_heads
            policy = resolve_decode_policy(
                self.batch_slots, hkv, cfg.num_heads // hkv,
                mp_bucket * self.page_size, cfg.head_dim, cfg.compute_dtype,
                page_size=self.page_size, q_tokens=self.spec_tokens)
            return {
                "policies": {"attention_decode": policy},
                "verify": jax.jit(
                    lambda params, toks, cache, pt, lens:
                        model.decode_step_paged(params, toks, cache, pt,
                                                lens),
                    donate_argnums=(2,)),
            }
        return self._touch(("verify", mp_bucket), build)

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if self._spec:
            # a verify round may overshoot the budget by up to
            # spec_tokens - 1 stale positions before retirement truncates
            total += self.spec_tokens
        cap = min(self.max_pages_per_seq, self.n_pages - 1) * self.page_size
        if total > cap:
            raise ValueError(
                f"request {req.uid}: {total} tokens exceed per-sequence "
                f"capacity {cap} (max_pages_per_seq * page_size)")
        if self._spec and (req.temperature not in (None, 0.0)):
            raise ValueError(
                f"request {req.uid}: speculative decoding requires greedy "
                "requests (temperature 0.0)")
        self.pending.append(req)

    def _effective_temperature(self, req: Request) -> float:
        return self.temperature if req.temperature is None else req.temperature

    def _sample_slot(self, logits_row, req: Request, position: int) -> int:
        """Sample one token for one sequence (docs/serving.md contract).

        ``position`` is the token's absolute sequence position — the
        fold_in index for seeded requests, so the draw is invariant to
        batch composition, admission order, and recompute preemption.
        """
        t = self._effective_temperature(req)
        if t == 0.0:
            return int(jnp.argmax(logits_row))
        if req.seed is not None:
            key = jax.random.fold_in(jax.random.PRNGKey(req.seed), position)
        else:
            self.rng, key = jax.random.split(self.rng)
        return int(jax.random.categorical(key, logits_row / t))

    def _match_prefix(self, req: Request) -> list:
        """Trie lookup (pages retained for the caller) + counters."""
        if self.prefix is None:
            return []
        matched = self.prefix.match(req.prompt, self.alloc)
        obs.incr("engine.prefix.lookups")
        if matched:
            obs.incr("engine.prefix.hits")
            obs.incr("engine.prefix.tokens_saved",
                     len(matched) * self.page_size)
        return matched

    def _admit(self) -> int:
        """Move pending requests into free slots; returns how many joined."""
        admitted = 0
        while self.pending:
            free = [s for s in range(self.batch_slots) if s not in self.slots]
            if not free:
                break
            req = self.pending[0]
            plen = len(req.prompt)
            n = kvc.num_pages_needed(plen, self.page_size)
            matched = self._match_prefix(req)       # retained for this slot
            n_new = n - len(matched)
            if not self.alloc.can_alloc(n_new):
                if self.prefix is not None:
                    self.prefix.evict(self.alloc,
                                      n_new - self.alloc.free_pages)
                if not self.alloc.can_alloc(n_new):
                    if matched:
                        self.alloc.free(matched)    # drop this admission's
                    break                           # refs; wait for retire
            self.pending.popleft()
            slot = free[0]
            pages = matched + self.alloc.alloc(n_new)
            matched_len = len(matched) * self.page_size
            if matched or self.chunk_tokens is not None:
                # suffix/chunked prefill through the compiled chunk fn:
                # only positions >= matched_len are computed. Without
                # chunking the whole suffix goes in one padded chunk now;
                # with chunking the slot joins mid-prefill and advances
                # one chunk per step.
                self.state = kvc.assign_slot(self.state, slot, pages,
                                             matched_len)
                rec = _Slot(req=req, n_pages=n, generated=[], next_token=-1,
                            pages=pages, prefill_cursor=matched_len)
                self.slots[slot] = rec
                if self.chunk_tokens is None:
                    self._advance_prefill(slot, rec)   # completes in one go
            else:
                # exact-length prefill (compiled per prompt length): padding
                # the tokens to a page multiple would contaminate recurrent-
                # layer (ssm/rglru) slot state with the pad positions; the
                # partial last page is zero-filled by write_prefill_pages.
                self.state = kvc.assign_slot(self.state, slot, pages, plen)
                toks = np.asarray(req.prompt, np.int32)[None, :]
                entry = self._prefill_bucket(plen)
                with obs.span("engine.prefill", uid=req.uid, prompt_len=plen):
                    self.cache, logits = entry["prefill"](
                        self.params, jnp.asarray(toks), self.cache,
                        self.state["page_table"][slot], slot, plen)
                if self._spec:
                    dentry = self._prefill_bucket(plen, draft=True)
                    self.draft_cache, _ = dentry["prefill"](
                        self.draft_params, jnp.asarray(toks),
                        self.draft_cache, self.state["page_table"][slot],
                        slot, plen)
                first = self._sample_slot(logits[0], req, plen)
                self.slots[slot] = _Slot(req=req, n_pages=n,
                                         generated=[first], next_token=first,
                                         pages=pages)
                # the admission's first token is sampled off the prefill
                # logits, not a decode step — count it here so
                # tokens_generated covers every emitted token
                self.tokens_generated += 1
                obs.incr("engine.tokens_generated")
                if self.prefix is not None:
                    self.prefix.insert(req.prompt, pages, self.alloc)
            admitted += 1
            self.admissions += 1
            obs.incr("engine.admissions")
            self._note_occupancy()
        return admitted

    def _advance_prefill(self, slot: int, rec: _Slot) -> None:
        """Run ONE prefill chunk for a mid-prefill slot (the whole padded
        suffix at once when interleaved chunking is off). On the final
        chunk: sample the first token, mark the slot decode-ready, and
        register the prompt's full pages in the prefix trie."""
        req = rec.req
        plen = len(req.prompt)
        start = rec.prefill_cursor
        if self.chunk_tokens is not None:
            c = self.chunk_tokens
        else:
            c = _pow2(kvc.num_pages_needed(plen - start,
                                           self.page_size)) * self.page_size
        end = min(plen, start + c)
        toks = np.zeros((1, c), np.int32)
        toks[0, : end - start] = np.asarray(req.prompt[start:end], np.int32)
        last = (plen - 1 - start) if end == plen else 0
        entry = self._chunk_bucket(c)
        with obs.span("engine.prefill_chunk", uid=req.uid, start=start,
                      chunk=c):
            self.cache, logits = entry["chunk"](
                self.params, jnp.asarray(toks), self.cache,
                self.state["page_table"][slot],
                jnp.int32(start), jnp.int32(last))
        if self._spec:
            dentry = self._chunk_bucket(c, draft=True)
            self.draft_cache, _ = dentry["chunk"](
                self.draft_params, jnp.asarray(toks), self.draft_cache,
                self.state["page_table"][slot],
                jnp.int32(start), jnp.int32(last))
        self.chunks_prefilled += 1
        obs.incr("engine.chunks_prefilled")
        self.state["lengths"] = self.state["lengths"].at[slot].set(
            min(end, plen))
        if end >= plen:
            rec.prefill_cursor = -1
            first = self._sample_slot(logits[0], req, plen)
            rec.generated = [first]
            rec.next_token = first
            self.tokens_generated += 1
            obs.incr("engine.tokens_generated")
            if self.prefix is not None:
                self.prefix.insert(req.prompt, rec.pages, self.alloc)
        else:
            rec.prefill_cursor = end

    def _try_grow(self, tokens_ahead: int = 1) -> list:
        """Allocate next pages for slots crossing a page boundary; returns
        the slots whose growth the exhausted pool could not cover.
        ``tokens_ahead`` > 1 (speculative rounds) reserves headroom for the
        whole verify block. Mid-prefill slots already hold every page their
        prompt needs, so they never grow (and never stall)."""
        stalled = []
        lengths = np.asarray(self.state["lengths"])   # one host transfer
        for slot in sorted(self.slots):
            rec = self.slots[slot]
            if rec.prefilling:
                continue
            need = int(lengths[slot]) + tokens_ahead
            while need > rec.n_pages * self.page_size:
                if not self.alloc.can_alloc(1) and self.prefix is not None:
                    # cached-but-unreferenced prefix pages are reclaimable
                    self.prefix.evict(self.alloc, 1)
                if self.alloc.can_alloc(1):
                    page = self.alloc.alloc(1)[0]
                    self.state["page_table"] = \
                        self.state["page_table"].at[slot, rec.n_pages].set(page)
                    rec.pages.append(page)
                    rec.n_pages += 1
                else:
                    stalled.append(slot)
                    break
        return stalled

    def _preempt(self, slot: int) -> None:
        """Recompute preemption (the vLLM policy): free the slot's pages and
        requeue a continuation — prompt := prompt + generated-so-far, budget
        := the remaining tokens — at the front of the queue. Re-admission
        re-prefills the lost KV; greedy decoding makes the continuation
        exact. Retirement later rebuilds the full result from the
        continuation's (longer) prompt, so the output is unchanged.

        Frees drop one reference per page: pages shared with the prefix
        trie (or another sequence) survive with their remaining refs, so a
        preemption never invalidates a neighbour's prefix."""
        rec = self.slots[slot]
        self.alloc.free(rec.pages)
        self.state = kvc.release_slot(self.state, slot)
        gen = rec.generated[: rec.req.max_new_tokens]
        cont = Request(
            rec.req.uid,
            np.concatenate([np.asarray(rec.req.prompt, np.int32),
                            np.asarray(gen, np.int32)]),
            max(0, rec.req.max_new_tokens - len(gen)),
            temperature=rec.req.temperature,
            seed=rec.req.seed)
        self.pending.appendleft(cont)
        self.preemptions += 1
        obs.incr("engine.preemptions")
        del self.slots[slot]

    def _retire(self, slot: int, rec: _Slot) -> None:
        self.alloc.free(rec.pages)      # per-page ref drop, not a hard free
        self.state = kvc.release_slot(self.state, slot)
        gen = rec.generated[: rec.req.max_new_tokens]   # spec overshoot
        self.results[rec.req.uid] = np.concatenate(
            [np.asarray(rec.req.prompt, np.int32),
             np.asarray(gen, np.int32)])
        del self.slots[slot]

    def _launch_views(self, active: list, mp_bucket: int):
        """(page_table, lengths, act) for a decode/verify launch. Mid-prefill
        slots are masked out by zeroing their rows: masked rows write to the
        null page and attend to nothing, so a chunk-interleaved slot never
        perturbs the batch it shares a launch with. With no mid-prefill
        slots the views are passed through untouched (the bitwise-identical
        fast path)."""
        pt = self.state["page_table"][:, :mp_bucket]
        lens = self.state["lengths"]
        act = np.zeros((self.batch_slots,), np.int32)
        for s in active:
            act[s] = 1
        act = jnp.asarray(act)
        if any(r.prefilling for r in self.slots.values()):
            pt = pt * act[:, None]
            lens = lens * act
        return pt, lens, act

    def _decode_one(self, active: list, mp_bucket: int) -> None:
        """One single-token decode step for every decode-ready slot."""
        entry = self._decode_bucket(mp_bucket)
        pt, lens, act = self._launch_views(active, mp_bucket)
        tokens = np.zeros((self.batch_slots, 1), np.int32)
        for slot in active:
            tokens[slot, 0] = self.slots[slot].next_token
        n_active = len(active)
        with obs.span("engine.decode_step", active_slots=n_active,
                      mp_bucket=mp_bucket):
            self.cache, logits = entry["decode"](
                self.params, jnp.asarray(tokens), self.cache, pt, lens)
            self.state["lengths"] = self.state["lengths"] + act
            sampled = {}
            greedy = None
            for slot in active:
                rec = self.slots[slot]
                if self._effective_temperature(rec.req) == 0.0:
                    if greedy is None:      # one batched argmax for all
                        greedy = np.asarray(jnp.argmax(logits, axis=-1))
                    sampled[slot] = int(greedy[slot])
                else:
                    pos = len(rec.req.prompt) + len(rec.generated)
                    sampled[slot] = self._sample_slot(logits[slot], rec.req,
                                                      pos)
        self.tokens_generated += n_active
        obs.incr("engine.tokens_generated", n_active)
        for slot in active:
            rec = self.slots[slot]
            rec.generated.append(sampled[slot])
            rec.next_token = sampled[slot]

    def _spec_round(self, active: list, mp_bucket: int) -> None:
        """One speculative round: k draft micro-steps propose d1..d_{k-1},
        the target verifies [t0, d1..d_{k-1}] in a single k-token decode,
        and each sequence keeps the longest agreeing prefix plus the
        target's first divergent token (1..k tokens per round).

        The draft runs k appends (the last feeds d_{k-1} with its logits
        discarded) so the draft cache has no hole at the round's final
        position. Rejected positions leave stale KV above the accepted
        length in both pools; the next round's appends start at the new
        length and cover every stale position before anything reads it."""
        k = self.spec_tokens
        dentry = self._decode_bucket(mp_bucket, draft=True)
        ventry = self._verify_bucket(mp_bucket)
        pt, lens, act = self._launch_views(active, mp_bucket)
        base = np.asarray(self.state["lengths"])

        proposals = {s: [] for s in active}
        cur = np.zeros((self.batch_slots, 1), np.int32)
        for s in active:
            cur[s, 0] = self.slots[s].next_token
        with obs.span("engine.spec_draft", active_slots=len(active),
                      k=k, mp_bucket=mp_bucket):
            for i in range(k):
                self.draft_cache, dlogits = dentry["decode"](
                    self.draft_params, jnp.asarray(cur), self.draft_cache,
                    pt, lens + i * act if i else lens)
                if i == k - 1:
                    break               # KV-only append for d_{k-1}
                greedy = np.asarray(jnp.argmax(dlogits, axis=-1))
                for s in active:
                    proposals[s].append(int(greedy[s]))
                    cur[s, 0] = int(greedy[s])

        vt = np.zeros((self.batch_slots, k), np.int32)
        for s in active:
            vt[s, 0] = self.slots[s].next_token
            vt[s, 1:] = proposals[s]
        with obs.span("engine.spec_verify", active_slots=len(active),
                      k=k, mp_bucket=mp_bucket):
            self.cache, vlogits = ventry["verify"](
                self.params, jnp.asarray(vt), self.cache, pt, lens)
        preds = np.asarray(jnp.argmax(vlogits, axis=-1))    # (B, k)

        new_lengths = base.copy()
        for s in active:
            rec = self.slots[s]
            ds, ps = proposals[s], preds[s]
            j = 0
            while j < k - 1 and ds[j] == int(ps[j]):
                j += 1
            emitted = ds[:j] + [int(ps[j])]
            rec.generated.extend(emitted)
            rec.next_token = emitted[-1]
            new_lengths[s] = int(base[s]) + j + 1
            self.spec_proposed += k - 1
            self.spec_accepted += j
            self.spec_emitted += len(emitted)
            self.spec_participations += 1
            self.tokens_generated += len(emitted)
            obs.incr("engine.tokens_generated", len(emitted))
        self.state["lengths"] = jnp.asarray(new_lengths, jnp.int32)
        self.spec_rounds += 1
        obs.incr("engine.spec.rounds")
        obs.incr("engine.spec.proposed", (k - 1) * len(active))
        obs.incr("engine.spec.accepted",
                 sum(int(new_lengths[s] - base[s]) - 1 for s in active))

    def step(self) -> bool:
        """Admit, advance mid-prefill slots by one chunk, decode one step
        (or one speculative round) for every decode-ready slot, retire
        finished. Returns False when there is nothing left to do."""
        self._admit()
        # chunk-interleaved prefill: one fixed-size chunk per slot per step
        # bounds the decode stall at one chunk instead of one full prompt
        for slot in sorted(self.slots):
            rec = self.slots[slot]
            if rec.prefilling:
                self._advance_prefill(slot, rec)
        # retire slots that completed at admission (max_new_tokens == 1)
        for slot in [s for s, r in self.slots.items()
                     if not r.prefilling
                     and len(r.generated) >= r.req.max_new_tokens]:
            self._retire(slot, self.slots[slot])
        if not self.slots:
            if self.pending:
                self._admit()
                if not self.slots:
                    raise RuntimeError(
                        "paged engine stalled: pending requests but no "
                        "admissible slot (page pool too small?)")
                return True
            return False

        # page growth; on pool exhaustion preempt the youngest stalled slot
        # (freeing its pages) until the survivors fit. A lone slot never
        # stalls: submit() bounds any single sequence to the pool size.
        ahead = self.spec_tokens if self._spec else 1
        stalled = self._try_grow(ahead)
        while stalled:
            self._preempt(stalled[-1])
            stalled = self._try_grow(ahead)
        if not self.slots:
            return bool(self.pending)   # everything preempted; re-admit next
        active = [s for s, r in sorted(self.slots.items())
                  if not r.prefilling]
        if not active:
            self.steps += 1
            return True                 # all slots mid-prefill; decode next
        max_pages = max(self.slots[s].n_pages for s in active)
        mp_bucket = min(self.max_pages_per_seq, _pow2(max_pages))
        self._note_occupancy()
        if self._spec:
            self._spec_round(active, mp_bucket)
        else:
            self._decode_one(active, mp_bucket)
        self.steps += 1

        for slot in list(self.slots):
            rec = self.slots[slot]
            if rec.prefilling:
                continue
            if len(rec.generated) >= rec.req.max_new_tokens:
                self._retire(slot, rec)
        return bool(self.slots or self.pending)

    def report(self) -> dict:
        """Engine-level metrics (the run report, DESIGN.md §13): counts are
        cumulative since construction, mirrored into the telemetry counters
        whenever a capture is active."""
        out = {
            "steps": self.steps,
            "admissions": self.admissions,
            "preemptions": self.preemptions,
            "tokens_generated": self.tokens_generated,
            "peak_pages_in_use": self.peak_pages_in_use,
            "page_pool_size": self.n_pages - 1,
            "bucket_lru": dict(self.lru_stats),
            "completed": len(self.results),
        }
        if self.prefix is not None:
            p = self.prefix
            out["prefix_cache"] = {
                "lookups": p.lookups,
                "hits": p.hits,
                "hit_rate": p.hits / p.lookups if p.lookups else 0.0,
                "matched_tokens": p.matched_tokens,
                "pages_held": p.pages_held,
            }
        if self.chunk_tokens is not None:
            out["chunked_prefill"] = {"chunk_tokens": self.chunk_tokens,
                                      "chunks": self.chunks_prefilled}
        if self._spec:
            out["speculative"] = {
                "k": self.spec_tokens,
                "rounds": self.spec_rounds,
                "proposed": self.spec_proposed,
                "accepted": self.spec_accepted,
                "accept_rate": (self.spec_accepted / self.spec_proposed
                                if self.spec_proposed else 0.0),
                # emitted tokens per sequence per verify round, in [1, k]
                "mean_tokens_per_round":
                    (self.spec_emitted / self.spec_participations
                     if self.spec_participations else 0.0),
            }
        return out

    def run(self) -> dict:
        """Drive :meth:`step` until idle; returns {uid: tokens} results.
        :meth:`report` carries the run's engine metrics."""
        with obs.span("engine.run"):
            while self.step():
                pass
        return self.results