"""Serving engines: fixed-batch prefill+decode, and paged continuous batching.

Two engines share the request surface (DESIGN.md §8):

* :class:`Engine` serves fixed-shape batches (one compiled prefill and one
  compiled decode_step per (batch, prompt_len) bucket). Each bucket pins the
  KernelPolicy set its compiled functions resolve to — the autotuner's
  per-shape-bucket memoization means the pinned policy and the policy the
  kernels trace with are the same object (DESIGN.md §5), so the report in
  :attr:`Engine.bucket_policies` is exact. Compiled buckets are held in an
  LRU capped by ``max_cached_buckets``: evicting a bucket drops its jitted
  callables (and with them the compiled executables), so a long-lived engine
  serving many shapes stays bounded.
* :class:`PagedEngine` runs continuous batching over the paged KV cache
  (``serve.kv_cache``): new requests are admitted into free batch slots
  each step (single-sequence prefill into freshly allocated pages), finished
  ones retire (pages freed) without disturbing their neighbours, and the
  one compiled decode step serves every slot regardless of its length.
  Decode policies are pinned per (batch_slots, page_count) bucket: the page
  table is sliced to the smallest power-of-two page count covering the
  active slots, so short-context phases run a smaller split-KV grid.

``RequestQueue`` is the continuous-batching-lite layer over :class:`Engine`:
requests are bucketed by padded prompt length and flushed as full batches.
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import autotune
from . import kv_cache as kvc


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, prompt + generated)
    prompt_len: int
    steps: int


def _lru_get(lru: collections.OrderedDict, key, build, cap: int,
             stats: dict | None = None):
    """Get-or-build with LRU eviction — evicted entries drop their jitted
    callables (and compiled executables) with them. ``stats`` (an engine's
    hits/misses/evictions dict) is also mirrored into the telemetry
    counters when a capture is active."""
    entry = lru.get(key)
    if entry is None:
        if stats is not None:
            stats["misses"] += 1
        obs.incr("engine.bucket_lru.misses")
        entry = build()
        lru[key] = entry
        while len(lru) > cap:
            lru.popitem(last=False)
            if stats is not None:
                stats["evictions"] += 1
            obs.incr("engine.bucket_lru.evictions")
    else:
        lru.move_to_end(key)
        if stats is not None:
            stats["hits"] += 1
        obs.incr("engine.bucket_lru.hits")
    return entry


class Engine:
    def __init__(self, model, params, *, max_len: int = 4096, mesh=None,
                 donate_cache: bool = True, max_cached_buckets: int = 8):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self.donate_cache = donate_cache
        self.max_cached_buckets = max_cached_buckets
        # (batch, prompt_len) bucket -> {policies, prefill}; LRU — least-
        # recently-used buckets are evicted together with their compiled
        # functions once the cap is exceeded. The decode step's traced
        # shapes depend only on batch (token (B,1), max_len cache), so its
        # jits live in a separate per-batch LRU rather than being
        # re-compiled per prompt length.
        self._buckets: collections.OrderedDict = collections.OrderedDict()
        self._decode_jits: collections.OrderedDict = collections.OrderedDict()
        self.lru_stats = {"hits": 0, "misses": 0, "evictions": 0}

    @property
    def bucket_policies(self) -> dict:
        """{(batch, prompt_len): {op: KernelPolicy}} of the live buckets."""
        return {k: e["policies"] for k, e in self._buckets.items()}

    def _bucket(self, batch: int, prompt_len: int) -> dict:
        """Resolve-or-evict the compiled bucket for (batch, prompt_len)."""
        model = self.model

        def build():
            return {
                "policies": autotune.policies_for_model(
                    model.cfg, batch=batch, seq_len=prompt_len,
                    decode_len=self.max_len),
                "prefill": jax.jit(
                    lambda params, batch_, cache: model.prefill(
                        params, batch_, cache)),
            }
        return _lru_get(self._buckets, (batch, prompt_len), build,
                        self.max_cached_buckets, self.lru_stats)

    def _decode_fn(self, batch: int):
        model = self.model

        def build():
            return jax.jit(
                lambda params, tok, cache, pos: model.decode_step(
                    params, tok, cache, pos),
                donate_argnums=(2,) if self.donate_cache else ())
        return _lru_get(self._decode_jits, batch, build,
                        self.max_cached_buckets, self.lru_stats)

    def _sample(self, logits, temperature: float, rng):
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / temperature, axis=-1)

    def generate(self, prompts, max_new_tokens: int, *,
                 temperature: float = 0.0, rng=None,
                 extra_batch: Optional[dict] = None) -> GenerationResult:
        """prompts: (B, S) int32. Greedy (T=0) or temperature sampling."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s = prompts.shape
        entry = self._bucket(b, s)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = self.model.init_cache(b, self.max_len)
        with obs.span("engine.prefill", batch=b, prompt_len=s):
            if self.model.cfg.family == "encdec":
                batch = dict(extra_batch or {}, inputs=prompts)
                cache, logits = entry["prefill"](self.params, batch, cache)
            else:
                cache, logits = entry["prefill"](self.params, prompts, cache)
        toks = [prompts]
        rngs = jax.random.split(rng, max_new_tokens)
        decode = self._decode_fn(b)
        next_tok = self._sample(logits, temperature, rngs[0])[:, None]
        with obs.span("engine.decode", batch=b, tokens=max_new_tokens):
            for i in range(max_new_tokens):
                toks.append(next_tok)
                if i == max_new_tokens - 1:
                    break
                cache, logits = decode(self.params, next_tok, cache, s + i)
                next_tok = self._sample(logits, temperature,
                                        rngs[i + 1])[:, None]
        out = np.asarray(jnp.concatenate(toks, axis=1))
        return GenerationResult(out, s, max_new_tokens)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new_tokens: int


class RequestQueue:
    """Continuous-batching-lite: bucket by padded length, flush full batches."""

    def __init__(self, engine: Engine, batch_size: int,
                 buckets=(128, 512, 2048)):
        self.engine = engine
        self.batch_size = batch_size
        self.buckets = sorted(buckets)
        self.pending: dict[int, list[Request]] = {b: [] for b in self.buckets}
        self.results: dict[int, np.ndarray] = {}

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket")

    def submit(self, req: Request) -> None:
        self.pending[self._bucket(len(req.prompt))].append(req)

    def flush(self, *, force: bool = False) -> int:
        """Serve full (or, with ``force``, padded partial) batches.

        Returns the number of *real* requests served — padding duplicates of
        the last request (which fill out a forced partial batch to the
        compiled batch size) are not counted. A resubmitted uid overwrites
        its previous result with a warning rather than being silently
        dropped.
        """
        served = 0
        for bucket, reqs in self.pending.items():
            while len(reqs) >= self.batch_size or (force and reqs):
                group = reqs[: self.batch_size]
                del reqs[: self.batch_size]
                n_real = len(group)
                while len(group) < self.batch_size:   # pad the last batch
                    group.append(group[-1])
                prompts = np.stack([
                    np.pad(r.prompt, (bucket - len(r.prompt), 0))
                    for r in group])
                max_new = max(r.max_new_tokens for r in group)
                result = self.engine.generate(prompts, max_new)
                for r, row in zip(group[:n_real], result.tokens[:n_real]):
                    if r.uid in self.results:
                        warnings.warn(
                            f"RequestQueue: duplicate uid {r.uid} — "
                            "overwriting previous result", stacklevel=2)
                    self.results[r.uid] = row[bucket - len(r.prompt):]
                served += n_real
        return served


# ---------------------------------------------------------------------------
# Continuous batching over the paged KV cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Host-side record of one active batch slot."""
    req: Request
    n_pages: int                 # pages currently backing the sequence
    generated: list              # sampled token ids (ints)
    next_token: int              # token to feed at the next decode step


def _pow2(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


class PagedEngine:
    """Continuous batching: paged KV cache + one compiled decode step.

    Admission: each :meth:`step` first moves pending requests into free
    batch slots while the allocator can cover their prompt pages (the
    prefill runs at the exact prompt length, compiled once per length —
    padding the tokens would contaminate recurrent-layer state). Decode:
    one compiled ``decode_step_paged`` serves every slot; the page table is
    sliced to the pinned (batch_slots, page_count) bucket so short-context
    phases run a smaller split-KV grid. Growth: a slot crossing a page
    boundary gets its next page just-in-time; if the pool is exhausted the
    youngest stalled slot is preempted (recompute policy — its pages are
    freed and a continuation request rejoins the queue front). Retirement:
    a slot that reaches ``max_new_tokens`` frees its pages and its result
    appears in :attr:`results` — its neighbours never notice.
    """

    def __init__(self, model, params, *, batch_slots: int = 4,
                 page_size: int = 64, max_pages_per_seq: int = 8,
                 n_pages: Optional[int] = None, temperature: float = 0.0,
                 rng=None, max_cached_buckets: int = 8):
        if model.init_paged_cache is None:
            raise ValueError(
                f"{model.cfg.name}: no paged decode surface (decoder-only "
                "LM/VLM backbones only)")
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        # +1: physical page 0 is the reserved null page
        self.n_pages = (n_pages if n_pages is not None
                        else batch_slots * max_pages_per_seq + 1)
        self.temperature = temperature
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.max_cached_buckets = max_cached_buckets

        self.cache = model.init_paged_cache(batch_slots, self.n_pages,
                                            page_size)
        self.alloc = kvc.PageAllocator(self.n_pages)
        self.state = kvc.init_page_state(batch_slots, max_pages_per_seq)
        self.slots: dict[int, _Slot] = {}       # slot id -> active record
        self.pending: collections.deque = collections.deque()
        self.results: dict[int, np.ndarray] = {}
        self.steps = 0
        self.preemptions = 0
        self.admissions = 0
        self.tokens_generated = 0
        self.peak_pages_in_use = 0
        self.lru_stats = {"hits": 0, "misses": 0, "evictions": 0}
        # (batch_slots, page_count) -> {policies, decode}; ("prefill", S)
        # -> {policies, prefill}. LRU, compiled fns evicted with the entry.
        self._buckets: collections.OrderedDict = collections.OrderedDict()

    # -- bucket pinning ----------------------------------------------------
    @property
    def bucket_policies(self) -> dict:
        return {k: e["policies"] for k, e in self._buckets.items()}

    def _touch(self, key, build) -> dict:
        return _lru_get(self._buckets, key, build, self.max_cached_buckets,
                        self.lru_stats)

    def _note_occupancy(self) -> None:
        used = self.n_pages - 1 - self.alloc.free_pages
        if used > self.peak_pages_in_use:
            self.peak_pages_in_use = used
        obs.gauge("engine.peak_pages_in_use", used)

    def _decode_bucket(self, mp_bucket: int) -> dict:
        """Compiled decode + pinned split-KV policy for a page-count bucket."""
        from repro.kernels.attention import resolve_decode_policy
        model, cfg = self.model, self.model.cfg

        def build():
            hkv = cfg.num_kv_heads
            policy = resolve_decode_policy(
                self.batch_slots, hkv, cfg.num_heads // hkv,
                mp_bucket * self.page_size, cfg.head_dim, cfg.compute_dtype,
                page_size=self.page_size)
            return {
                "policies": {"attention_decode": policy},
                "decode": jax.jit(
                    lambda params, tok, cache, pt, lens:
                        model.decode_step_paged(params, tok, cache, pt,
                                                lens),
                    donate_argnums=(2,)),   # pools are the dominant buffers
            }
        return self._touch((self.batch_slots, mp_bucket), build)

    def _prefill_bucket(self, padded_len: int) -> dict:
        model = self.model

        def build():
            return {
                "policies": autotune.policies_for_model(
                    model.cfg, batch=1, seq_len=padded_len,
                    decode_len=self.max_pages_per_seq * self.page_size),
                "prefill": jax.jit(
                    lambda params, toks, cache, rows, slot, n:
                        model.prefill_paged(params, toks, cache, rows,
                                            slot, n),
                    donate_argnums=(2,)),
            }
        return self._touch(("prefill", padded_len), build)

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        cap = min(self.max_pages_per_seq, self.n_pages - 1) * self.page_size
        if total > cap:
            raise ValueError(
                f"request {req.uid}: {total} tokens exceed per-sequence "
                f"capacity {cap} (max_pages_per_seq * page_size)")
        self.pending.append(req)

    def _sample(self, logits) -> np.ndarray:
        if self.temperature == 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.rng, sub = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1))

    def _admit(self) -> int:
        """Move pending requests into free slots; returns how many joined."""
        admitted = 0
        while self.pending:
            free = [s for s in range(self.batch_slots) if s not in self.slots]
            if not free:
                break
            req = self.pending[0]
            n = kvc.num_pages_needed(len(req.prompt), self.page_size)
            if not self.alloc.can_alloc(n):
                break                       # wait for a retirement
            self.pending.popleft()
            slot = free[0]
            pages = self.alloc.alloc(n)
            plen = len(req.prompt)
            self.state = kvc.assign_slot(self.state, slot, pages, plen)
            # exact-length prefill (compiled per prompt length): padding the
            # tokens to a page multiple would contaminate recurrent-layer
            # (ssm/rglru) slot state with the pad positions; the partial
            # last page is zero-filled by write_prefill_pages instead.
            toks = np.asarray(req.prompt, np.int32)[None, :]
            entry = self._prefill_bucket(plen)
            with obs.span("engine.prefill", uid=req.uid, prompt_len=plen):
                self.cache, logits = entry["prefill"](
                    self.params, jnp.asarray(toks), self.cache,
                    self.state["page_table"][slot], slot, plen)
            first = int(self._sample(logits)[0])
            self.slots[slot] = _Slot(req=req, n_pages=n, generated=[first],
                                     next_token=first)
            admitted += 1
            self.admissions += 1
            # the admission's first token is sampled off the prefill logits,
            # not a decode step — count it here so tokens_generated covers
            # every emitted token
            self.tokens_generated += 1
            obs.incr("engine.admissions")
            obs.incr("engine.tokens_generated")
            self._note_occupancy()
        return admitted

    def _try_grow(self) -> list:
        """Allocate next pages for slots crossing a page boundary; returns
        the slots whose growth the exhausted pool could not cover."""
        stalled = []
        lengths = np.asarray(self.state["lengths"])   # one host transfer
        for slot in sorted(self.slots):
            rec = self.slots[slot]
            need = int(lengths[slot]) + 1
            if need > rec.n_pages * self.page_size:
                if self.alloc.can_alloc(1):
                    page = self.alloc.alloc(1)[0]
                    self.state["page_table"] = \
                        self.state["page_table"].at[slot, rec.n_pages].set(page)
                    rec.n_pages += 1
                else:
                    stalled.append(slot)
        return stalled

    def _preempt(self, slot: int) -> None:
        """Recompute preemption (the vLLM policy): free the slot's pages and
        requeue a continuation — prompt := prompt + generated-so-far, budget
        := the remaining tokens — at the front of the queue. Re-admission
        re-prefills the lost KV; greedy decoding makes the continuation
        exact. Retirement later rebuilds the full result from the
        continuation's (longer) prompt, so the output is unchanged."""
        rec = self.slots[slot]
        row = np.asarray(self.state["page_table"][slot])
        self.alloc.free([int(p) for p in row[: rec.n_pages]])
        self.state = kvc.release_slot(self.state, slot)
        cont = Request(
            rec.req.uid,
            np.concatenate([np.asarray(rec.req.prompt, np.int32),
                            np.asarray(rec.generated, np.int32)]),
            rec.req.max_new_tokens - len(rec.generated))
        self.pending.appendleft(cont)
        self.preemptions += 1
        obs.incr("engine.preemptions")
        del self.slots[slot]

    def _retire(self, slot: int, rec: _Slot) -> None:
        row = np.asarray(self.state["page_table"][slot])
        self.alloc.free([int(p) for p in row[: rec.n_pages]])
        self.state = kvc.release_slot(self.state, slot)
        self.results[rec.req.uid] = np.concatenate(
            [np.asarray(rec.req.prompt, np.int32),
             np.asarray(rec.generated, np.int32)])
        del self.slots[slot]

    def step(self) -> bool:
        """Admit, decode one token for every active slot, retire finished.

        Returns False when there is nothing left to do (idle engine).
        """
        self._admit()
        # retire slots that completed at admission (max_new_tokens == 1)
        for slot in [s for s, r in self.slots.items()
                     if len(r.generated) >= r.req.max_new_tokens]:
            self._retire(slot, self.slots[slot])
        if not self.slots:
            if self.pending:
                self._admit()
                if not self.slots:
                    raise RuntimeError(
                        "paged engine stalled: pending requests but no "
                        "admissible slot (page pool too small?)")
                return True
            return False

        # page growth; on pool exhaustion preempt the youngest stalled slot
        # (freeing its pages) until the survivors fit. A lone slot never
        # stalls: submit() bounds any single sequence to the pool size.
        stalled = self._try_grow()
        while stalled:
            self._preempt(stalled[-1])
            stalled = self._try_grow()
        if not self.slots:
            return bool(self.pending)   # everything preempted; re-admit next
        max_pages = max(r.n_pages for r in self.slots.values())
        mp_bucket = min(self.max_pages_per_seq, _pow2(max_pages))
        entry = self._decode_bucket(mp_bucket)
        self._note_occupancy()

        tokens = np.zeros((self.batch_slots, 1), np.int32)
        for slot, rec in self.slots.items():
            tokens[slot, 0] = rec.next_token
        n_active = len(self.slots)
        with obs.span("engine.decode_step", active_slots=n_active,
                      mp_bucket=mp_bucket):
            self.cache, logits = entry["decode"](
                self.params, jnp.asarray(tokens), self.cache,
                self.state["page_table"][:, :mp_bucket],
                self.state["lengths"])
            self.state["lengths"] = self.state["lengths"] + jnp.asarray(
                [1 if s in self.slots else 0
                 for s in range(self.batch_slots)], jnp.int32)
            sampled = self._sample(logits)
        self.steps += 1
        self.tokens_generated += n_active
        obs.incr("engine.tokens_generated", n_active)

        for slot in list(self.slots):
            rec = self.slots[slot]
            tok = int(sampled[slot])
            rec.generated.append(tok)
            rec.next_token = tok
            if len(rec.generated) >= rec.req.max_new_tokens:
                self._retire(slot, rec)
        return bool(self.slots or self.pending)

    def report(self) -> dict:
        """Engine-level metrics (the run report, DESIGN.md §13): counts are
        cumulative since construction, mirrored into the telemetry counters
        whenever a capture is active."""
        return {
            "steps": self.steps,
            "admissions": self.admissions,
            "preemptions": self.preemptions,
            "tokens_generated": self.tokens_generated,
            "peak_pages_in_use": self.peak_pages_in_use,
            "page_pool_size": self.n_pages - 1,
            "bucket_lru": dict(self.lru_stats),
            "completed": len(self.results),
        }

    def run(self) -> dict:
        """Drive :meth:`step` until idle; returns {uid: tokens} results.
        :meth:`report` carries the run's engine metrics."""
        with obs.span("engine.run"):
            while self.step():
                pass
        return self.results