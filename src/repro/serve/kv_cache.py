"""Paged KV cache: fixed-size pages, per-sequence page tables (DESIGN.md §8).

The dense per-bucket decode cache allocates batch × max_len slots up front,
so one long request inflates every sequence in its compiled bucket. The
paged layout instead backs each layer's KV with a shared physical pool of
fixed-size pages:

    k_pages, v_pages : (n_pages, kv_heads, page_size, head_dim)   per layer
    page_table       : (batch_slots, max_pages)  int32  — physical page ids
    lengths          : (batch_slots,)            int32  — tokens written

Physical **page 0 is reserved as the null page**: never allocated, pointed
at by every unused page-table entry, harmlessly absorbing the masked writes
of inactive batch slots. This is what lets sequences of different lengths
share one compiled decode step — ragged occupancy lives in the page table
and length mask, not in array shapes.

Split of responsibilities:
  * array ops (:func:`append_paged_kv`, :func:`write_prefill_pages`,
    :func:`gather_pages`) are pure jax and jit-safe — they run inside the
    compiled decode/prefill steps;
  * bookkeeping (:class:`PageAllocator`, :func:`assign_slot`,
    :func:`release_slot`) runs on the host between steps, where continuous
    batching makes its admit/retire decisions.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

NULL_PAGE = 0


def num_pages_needed(n_tokens: int, page_size: int) -> int:
    return max(1, -(-n_tokens // page_size))


def init_page_pool(n_pages: int, kv_heads: int, page_size: int,
                   head_dim: int, dtype) -> dict:
    """One layer's physical K/V pools (page 0 included, reserved null)."""
    shape = (n_pages, kv_heads, page_size, head_dim)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def init_page_state(batch_slots: int, max_pages: int) -> dict:
    """Per-sequence table + lengths, all slots empty (null-page rows)."""
    return {"page_table": jnp.zeros((batch_slots, max_pages), jnp.int32),
            "lengths": jnp.zeros((batch_slots,), jnp.int32)}


# ---------------------------------------------------------------------------
# Pure-jax array ops (run inside compiled steps)
# ---------------------------------------------------------------------------

def append_paged_kv(k_pages, v_pages, k_new, v_new, page_table, lengths):
    """Append one token's K/V per sequence at its write position.

    k_new/v_new: (B, kv_heads, 1, head_dim); the write lands in page
    ``page_table[b, lengths[b] // page_size]`` at offset
    ``lengths[b] % page_size``. Inactive slots (empty table rows) scatter
    into the reserved null page — duplicate null-page writes race but the
    null page is never read unmasked, so the race is benign.
    """
    b = k_new.shape[0]
    page_size = k_pages.shape[2]
    lengths = jnp.asarray(lengths, jnp.int32)
    pidx = page_table[jnp.arange(b), lengths // page_size]
    off = lengths % page_size
    k_pages = k_pages.at[pidx, :, off].set(k_new[:, :, 0, :])
    v_pages = v_pages.at[pidx, :, off].set(v_new[:, :, 0, :])
    return k_pages, v_pages


def write_prefill_pages(k_pages, v_pages, k, v, page_rows):
    """Write one sequence's prefill K/V into its allocated pages.

    k/v: (1, kv_heads, S, head_dim); ``page_rows``: (max_pages,) — the
    sequence's page-table row (first ceil(S / page_size) entries real).
    S is padded up to a whole number of pages; tokens past the true length
    are garbage until overwritten by appends, and stay masked by
    ``lengths`` until then.
    """
    _, hkv, s, d = k.shape
    page_size = k_pages.shape[2]
    n = num_pages_needed(s, page_size)
    pad = n * page_size - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # (1, hkv, n*page, d) -> (n, hkv, page, d)
    kr = k.reshape(hkv, n, page_size, d).transpose(1, 0, 2, 3)
    vr = v.reshape(hkv, n, page_size, d).transpose(1, 0, 2, 3)
    rows = jnp.asarray(page_rows, jnp.int32)[:n]
    return k_pages.at[rows].set(kr), v_pages.at[rows].set(vr)


def gather_pages(pages, page_table):
    """Contiguous (B, kv_heads, max_pages*page_size, head_dim) view — the
    einsum-reference path and debugging aid (the kernel never materializes
    this)."""
    b, mp = page_table.shape
    _, hkv, page_size, d = pages.shape
    return jnp.transpose(pages[page_table], (0, 2, 1, 3, 4)
                         ).reshape(b, hkv, mp * page_size, d)


# ---------------------------------------------------------------------------
# Host-side bookkeeping (between compiled steps)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PageAllocator:
    """Free-list allocator over physical pages 1..n_pages-1 (0 = null)."""

    n_pages: int

    def __post_init__(self):
        self._free = list(range(self.n_pages - 1, 0, -1))  # pop() -> low ids

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list:
        if n > len(self._free):
            raise MemoryError(
                f"paged KV cache exhausted: need {n} pages, "
                f"{len(self._free)} free of {self.n_pages - 1}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"freeing invalid page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
            self._free.append(p)


def assign_slot(state: dict, slot: int, pages, prompt_len: int) -> dict:
    """Point ``slot``'s table row at freshly allocated ``pages``."""
    row = jnp.zeros((state["page_table"].shape[1],), jnp.int32)
    row = row.at[: len(pages)].set(jnp.asarray(pages, jnp.int32))
    return {"page_table": state["page_table"].at[slot].set(row),
            "lengths": state["lengths"].at[slot].set(prompt_len)}


def release_slot(state: dict, slot: int) -> dict:
    """Reset ``slot`` to an empty (null-page, zero-length) row."""
    mp = state["page_table"].shape[1]
    return {"page_table": state["page_table"].at[slot].set(
                jnp.zeros((mp,), jnp.int32)),
            "lengths": state["lengths"].at[slot].set(0)}
