"""Paged KV cache: fixed-size pages, per-sequence page tables (DESIGN.md §8).

The dense per-bucket decode cache allocates batch × max_len slots up front,
so one long request inflates every sequence in its compiled bucket. The
paged layout instead backs each layer's KV with a shared physical pool of
fixed-size pages:

    k_pages, v_pages : (n_pages, kv_heads, page_size, head_dim)   per layer
    page_table       : (batch_slots, max_pages)  int32  — physical page ids
    lengths          : (batch_slots,)            int32  — tokens written

Physical **page 0 is reserved as the null page**: never allocated, pointed
at by every unused page-table entry, harmlessly absorbing the masked writes
of inactive batch slots. This is what lets sequences of different lengths
share one compiled decode step — ragged occupancy lives in the page table
and length mask, not in array shapes.

Split of responsibilities:
  * array ops (:func:`append_paged_kv`, :func:`write_prefill_pages`,
    :func:`gather_pages`) are pure jax and jit-safe — they run inside the
    compiled decode/prefill steps;
  * bookkeeping (:class:`PageAllocator`, :func:`assign_slot`,
    :func:`release_slot`) runs on the host between steps, where continuous
    batching makes its admit/retire decisions.
"""
from __future__ import annotations

import collections
import dataclasses

import jax.numpy as jnp

NULL_PAGE = 0


def num_pages_needed(n_tokens: int, page_size: int) -> int:
    return max(1, -(-n_tokens // page_size))


def init_page_pool(n_pages: int, kv_heads: int, page_size: int,
                   head_dim: int, dtype) -> dict:
    """One layer's physical K/V pools (page 0 included, reserved null)."""
    shape = (n_pages, kv_heads, page_size, head_dim)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def init_page_state(batch_slots: int, max_pages: int) -> dict:
    """Per-sequence table + lengths, all slots empty (null-page rows)."""
    return {"page_table": jnp.zeros((batch_slots, max_pages), jnp.int32),
            "lengths": jnp.zeros((batch_slots,), jnp.int32)}


# ---------------------------------------------------------------------------
# Pure-jax array ops (run inside compiled steps)
# ---------------------------------------------------------------------------

def append_paged_kv(k_pages, v_pages, k_new, v_new, page_table, lengths):
    """Append T tokens' K/V per sequence at its write position.

    k_new/v_new: (B, kv_heads, T, head_dim); token t of sequence b lands in
    page ``page_table[b, (lengths[b]+t) // page_size]`` at offset
    ``(lengths[b]+t) % page_size``. T is a static shape, so the multi-token
    case (speculative verify) unrolls to T single-token scatters. Inactive
    slots (empty table rows) scatter into the reserved null page —
    duplicate null-page writes race but the null page is never read
    unmasked, so the race is benign.
    """
    b = k_new.shape[0]
    t_tokens = k_new.shape[2]
    page_size = k_pages.shape[2]
    lengths = jnp.asarray(lengths, jnp.int32)
    for t in range(t_tokens):
        pos = lengths + t
        pidx = page_table[jnp.arange(b), pos // page_size]
        off = pos % page_size
        k_pages = k_pages.at[pidx, :, off].set(k_new[:, :, t, :])
        v_pages = v_pages.at[pidx, :, off].set(v_new[:, :, t, :])
    return k_pages, v_pages


def write_prefill_pages(k_pages, v_pages, k, v, page_rows, start_page=0):
    """Write one sequence's prefill K/V into its allocated pages.

    k/v: (1, kv_heads, S, head_dim); ``page_rows``: (max_pages,) — the
    sequence's page-table row (first ceil(S / page_size) entries real).
    S is padded up to a whole number of pages; tokens past the true length
    are garbage until overwritten by appends, and stay masked by
    ``lengths`` until then.

    ``start_page`` (traced ok) offsets the destination within the row:
    chunked prefill writes chunk c of C tokens with
    ``start_page = c * C // page_size`` and the same compiled function
    serves every chunk index. Rows past the end of ``page_rows`` read as
    the null page, so a padded final chunk writes harmlessly to page 0.
    """
    _, hkv, s, d = k.shape
    page_size = k_pages.shape[2]
    n = num_pages_needed(s, page_size)
    pad = n * page_size - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # (1, hkv, n*page, d) -> (n, hkv, page, d)
    kr = k.reshape(hkv, n, page_size, d).transpose(1, 0, 2, 3)
    vr = v.reshape(hkv, n, page_size, d).transpose(1, 0, 2, 3)
    all_rows = jnp.asarray(page_rows, jnp.int32)
    if isinstance(start_page, int) and start_page == 0:
        rows = all_rows[:n]
    else:
        idx = jnp.asarray(start_page, jnp.int32) + jnp.arange(n)
        # rows beyond the table read as null page (absorbs padded chunks)
        rows = jnp.where(idx < all_rows.shape[0],
                         all_rows[jnp.clip(idx, 0, all_rows.shape[0] - 1)],
                         NULL_PAGE)
    return k_pages.at[rows].set(kr), v_pages.at[rows].set(vr)


def gather_pages(pages, page_table):
    """Contiguous (B, kv_heads, max_pages*page_size, head_dim) view — the
    einsum-reference path and debugging aid (the kernel never materializes
    this)."""
    b, mp = page_table.shape
    _, hkv, page_size, d = pages.shape
    return jnp.transpose(pages[page_table], (0, 2, 1, 3, 4)
                         ).reshape(b, hkv, mp * page_size, d)


# ---------------------------------------------------------------------------
# Host-side bookkeeping (between compiled steps)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PageAllocator:
    """Refcounted free-list allocator over pages 1..n_pages-1 (0 = null).

    ``alloc`` hands out pages with refcount 1; ``retain`` adds a reference
    (prefix-cache sharing: a matched page is held by the trie *and* every
    sequence whose table row points at it); ``free`` drops one reference
    and only returns the page to the free list when the count hits zero.
    Freeing an unallocated page is a hard error — double frees corrupt
    shared prefixes silently otherwise.
    """

    n_pages: int

    def __post_init__(self):
        self._free = list(range(self.n_pages - 1, 0, -1))  # pop() -> low ids
        self._refs = [0] * self.n_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list:
        if n > len(self._free):
            raise MemoryError(
                f"paged KV cache exhausted: need {n} pages, "
                f"{len(self._free)} free of {self.n_pages - 1}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def retain(self, page: int) -> int:
        """Add a reference to an already-allocated page; returns new count."""
        if not 0 < page < self.n_pages:
            raise ValueError(f"retaining invalid page id {page}")
        if self._refs[page] == 0:
            raise ValueError(f"retaining unallocated page {page}")
        self._refs[page] += 1
        return self._refs[page]

    def refcount(self, page: int) -> int:
        if not 0 <= page < self.n_pages:
            raise ValueError(f"invalid page id {page}")
        return self._refs[page]

    def free(self, pages) -> None:
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"freeing invalid page id {p}")
            if self._refs[p] == 0:
                raise ValueError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)


class PrefixCache:
    """Trie of immutable full KV pages keyed by their exact token content.

    Each node is one *full* page of a previously prefilled prompt, keyed by
    the chain of page-token-tuples leading to it — exact token match, no
    hash collisions. A node holds one reference on its page (via
    :meth:`PageAllocator.retain` at insert), so cached pages survive the
    sequences that created them and are handed out to later requests whose
    prompts share the prefix.

    COW rule: only whole pages are ever shared, and :meth:`match` stops at
    ``(len(tokens) - 1) // page_size`` full pages so at least the final
    prompt token is always recomputed privately (its logits seed the first
    sampled token). Decode appends land at positions >= the matched region,
    i.e. in private pages — shared pages are immutable by construction.

    Eviction is LRU over *leaf* nodes whose page is referenced only by the
    trie (refcount 1): interior nodes are never dropped before their
    children, so no cached page becomes unreachable.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._nodes = collections.OrderedDict()  # key -> {page, children}
        self.lookups = 0
        self.hits = 0
        self.matched_tokens = 0

    def __len__(self):
        return len(self._nodes)

    @property
    def pages_held(self) -> int:
        return len(self._nodes)

    def _key_chain(self, tokens):
        """Full-page token tuples of ``tokens``, shareable region only."""
        n_share = max(0, (len(tokens) - 1) // self.page_size)
        ps = self.page_size
        return [tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                for i in range(n_share)]

    def match(self, tokens, alloc: PageAllocator) -> list:
        """Longest cached page-prefix of ``tokens``; retains each hit.

        Returns the list of matched physical page ids (possibly empty).
        Every returned page has had ``alloc.retain`` called on it — the
        caller owns one reference per page and must ``free`` them when the
        sequence retires or is preempted.
        """
        self.lookups += 1
        pages, key = [], ()
        for chunk in self._key_chain(tokens):
            key = key + (chunk,)
            node = self._nodes.get(key)
            if node is None:
                break
            alloc.retain(node["page"])
            self._nodes.move_to_end(key)
            pages.append(node["page"])
        if pages:
            self.hits += 1
            self.matched_tokens += len(pages) * self.page_size
        return pages

    def insert(self, tokens, pages, alloc: PageAllocator) -> int:
        """Register ``tokens``'s full pages (backed by ``pages``) for reuse.

        ``pages`` is the sequence's page-table prefix (one id per page of
        the prompt). Nodes already present are skipped (the sequence got
        those exact pages from :meth:`match`); new nodes retain their page
        so it outlives the sequence. Returns the number of new nodes.
        """
        added = 0
        key = ()
        for i, chunk in enumerate(self._key_chain(tokens)):
            key = key + (chunk,)
            node = self._nodes.get(key)
            if node is not None:
                self._nodes.move_to_end(key)
                continue
            alloc.retain(pages[i])
            self._nodes[key] = {"page": int(pages[i]), "children": 0}
            if len(key) > 1:
                self._nodes[key[:-1]]["children"] += 1
            added += 1
        return added

    def evict(self, alloc: PageAllocator, need: int) -> int:
        """Drop up to ``need`` LRU leaf pages held only by the trie.

        Returns how many pages were actually returned to the free list.
        Pages still referenced by a live sequence (refcount > 1) are
        skipped — dropping the trie's reference would not free them and
        would orphan a shareable page.
        """
        freed = 0
        progress = True
        while freed < need and progress:
            progress = False
            for key in list(self._nodes):  # OrderedDict: LRU first
                node = self._nodes[key]
                if node["children"] or alloc.refcount(node["page"]) != 1:
                    continue
                alloc.free([node["page"]])
                del self._nodes[key]
                if len(key) > 1:
                    self._nodes[key[:-1]]["children"] -= 1
                freed += 1
                progress = True
                if freed >= need:
                    break
        return freed


def assign_slot(state: dict, slot: int, pages, prompt_len: int) -> dict:
    """Point ``slot``'s table row at freshly allocated ``pages``."""
    row = jnp.zeros((state["page_table"].shape[1],), jnp.int32)
    row = row.at[: len(pages)].set(jnp.asarray(pages, jnp.int32))
    return {"page_table": state["page_table"].at[slot].set(row),
            "lengths": state["lengths"].at[slot].set(prompt_len)}


def release_slot(state: dict, slot: int) -> dict:
    """Reset ``slot`` to an empty (null-page, zero-length) row."""
    mp = state["page_table"].shape[1]
    return {"page_table": state["page_table"].at[slot].set(
                jnp.zeros((mp,), jnp.int32)),
            "lengths": state["lengths"].at[slot].set(0)}
