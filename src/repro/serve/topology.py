"""Per-host page-pool topology for paged serving (DESIGN.md §16).

A multi-host deployment does not share one page pool: each host owns a
pool sized to its HBM, its own page table, and its own batch slots. The
:class:`ShardedPagedEngine` models exactly that — N per-host
:class:`~repro.serve.engine.PagedEngine` instances (host-sharded pools +
sharded page tables) behind one request surface, with batch admission over
the data axis: each incoming request is placed on the host with the most
free pages (ties: fewest queued requests, then lowest host id — a
deterministic least-loaded rule, the data-parallel analogue of the
single-engine least-slot admission).

Everything downstream of placement is the unmodified single-host engine,
so per-host behaviour (preemption, prefix caching, chunked prefill,
speculation) and results stay bitwise-identical to running that host's
request stream through a standalone PagedEngine.
"""
from __future__ import annotations

from typing import Optional

from repro import obs
from .engine import PagedEngine, Request


class ShardedPagedEngine:
    """Data-axis sharded paged serving: one PagedEngine per host.

    ``n_hosts`` is the data-axis extent (host count). All other keyword
    arguments are forwarded to every per-host :class:`PagedEngine` — each
    host gets its own ``batch_slots`` and ``n_pages`` pool, so the
    aggregate capacity is ``n_hosts ×`` the single-engine figures.
    """

    def __init__(self, model, params, *, n_hosts: int = 2,
                 rng=None, **engine_kw):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.n_hosts = n_hosts
        if rng is not None:
            engine_kw["rng"] = rng
        self.hosts = [PagedEngine(model, params, **engine_kw)
                      for _ in range(n_hosts)]
        self.placements: dict[int, int] = {}    # uid -> host id
        self.admissions_by_host = [0] * n_hosts

    # -- admission over the data axis ------------------------------------

    def _place(self) -> int:
        """Deterministic least-loaded host: most free pages, then fewest
        queued requests, then lowest id."""
        def load(i: int):
            h = self.hosts[i]
            return (-h.alloc.free_pages, len(h.pending), i)
        return min(range(self.n_hosts), key=load)

    def submit(self, req: Request) -> None:
        host = self._place()
        if req.uid in self.placements:
            raise ValueError(f"request {req.uid} already submitted "
                             f"(host {self.placements[req.uid]})")
        self.hosts[host].submit(req)
        self.placements[req.uid] = host
        self.admissions_by_host[host] += 1
        obs.incr("sharded_engine.submitted")

    # -- stepping / results ----------------------------------------------

    def step(self) -> bool:
        """Advance every host one step; True while any host has work."""
        busy = False
        for h in self.hosts:
            # note: no short-circuit — every host steps every tick
            busy = h.step() or busy
        return busy

    @property
    def results(self) -> dict:
        merged: dict = {}
        for h in self.hosts:
            merged.update(h.results)
        return merged

    def run(self) -> dict:
        with obs.span("sharded_engine.run"):
            while self.step():
                pass
        return self.results

    # -- reporting --------------------------------------------------------

    def report(self) -> dict:
        """Aggregate metrics plus the per-host reports — the cross-host
        balance (admissions_by_host spread) is the health signal."""
        per_host = [h.report() for h in self.hosts]
        agg = {k: sum(r[k] for r in per_host)
               for k in ("steps", "admissions", "preemptions",
                         "tokens_generated", "completed", "page_pool_size")}
        agg["n_hosts"] = self.n_hosts
        agg["admissions_by_host"] = list(self.admissions_by_host)
        agg["placements"] = dict(self.placements)
        agg["per_host"] = per_host
        return agg
