"""Build the EXPERIMENTS.md §Dry-run/§Roofline tables from dryrun JSONs.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(dirname: str) -> list[dict]:
    recs = []
    for f in sorted(os.listdir(dirname)):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(dirname, f))))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def roofline_table(recs: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | bound | compute ms | memory ms | coll ms | "
            "HLO GFLOP/chip | HBM GiB/chip | coll GiB/chip | temp GiB | "
            "6ND/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                        f"{r['reason'][:60]}… | | | | | | | | | |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | **FAILED** | | | | | | | | | |")
            continue
        rf = r["roofline"]
        step = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / step if step else 0
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['bound']} "
            f"| {rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} "
            f"| {rf['collective_s']*1e3:.1f} "
            f"| {rf['flops_per_chip']/1e9:.0f} "
            f"| {fmt_bytes(rf['hbm_bytes_per_chip'])} "
            f"| {fmt_bytes(rf['collective_bytes_per_chip'])} "
            f"| {fmt_bytes(r['memory']['temp_size_in_bytes'])} "
            f"| {ratio:.2f} | {frac:.2f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {rf['bound']} | | | | | | | | | |")
    return "\n".join(rows)


def summary(recs: list[dict]) -> str:
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    fl = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    return f"{ok} compiled OK, {sk} documented skips, {fl} failures"


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print(f"## Summary: {summary(recs)}\n")
    for mesh in ("single", "multi"):
        print(f"### Mesh: {mesh} "
              f"({'16x16=256 chips' if mesh == 'single' else '2x16x16=512 chips'})\n")
        print(roofline_table(recs, mesh))
        print()


if __name__ == "__main__":
    main()
