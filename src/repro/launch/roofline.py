"""Roofline-term extraction from compiled dry-run artifacts.

compute  = HLO_FLOPs_per_chip   / peak_FLOP/s            (197e12 bf16, v5e)
memory   = HLO_bytes_per_chip   / HBM_bw                 (819e9 B/s)
collective = wire_bytes_per_chip / (links × link_bw)     (4 × 50e9 B/s)

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (the SPMD module is
the per-device program, so these are already per-chip). Collective wire
bytes are parsed from the optimized HLO text: for each collective op we take
the largest tensor shape appearing on the op line as the logical full
payload and weight it ×2 for all-reduce (ring: send+receive each ~payload),
×1 otherwise.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*[^=]*\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)(-start)?\(")

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_LINK_BW = 50e9           # B/s per link
ICI_LINKS = 4                # 2D torus


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        shapes = [_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(line)]
        if not shapes:
            continue
        payload = max(shapes)
        mult = 2.0 if kind == "all-reduce" else 1.0
        b = payload * mult
        stats.total_bytes += b
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + b
        stats.count += 1
    return stats


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    by_kind: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, *, peak=PEAK_FLOPS, hbm=HBM_BW,
                           link_bw=ICI_LINK_BW, links=ICI_LINKS) -> Roofline:
    costs = cost_dict(compiled)
    flops = float(costs.get("flops", 0.0))
    hbm_bytes = float(costs.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(compiled.as_text())
    compute_s = flops / peak
    memory_s = hbm_bytes / hbm
    collective_s = coll.total_bytes / (link_bw * links)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    return Roofline(flops, hbm_bytes, coll.total_bytes, compute_s, memory_s,
                    collective_s, max(terms, key=terms.get), coll.by_kind)


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes"):
        val = getattr(ma, key, None)
        if val is not None:
            out[key] = int(val)
    return out


def policy_cell_report(cfg, shape) -> dict:
    """The KernelPolicy each kernel family resolves to for an (arch, shape)
    cell, with the policy's own modeled roofline position. This is what the
    dry-run records next to the HLO-derived terms: the HLO terms say where
    the *model* sits, these say how each *kernel* plans to get there."""
    from repro import obs
    from repro.core import autotune

    with obs.span("roofline.policy_report", kind=getattr(shape, "kind", "")):
        policies = autotune.policies_for_model(
            cfg, batch=shape.global_batch, seq_len=shape.seq_len)
        dtype = getattr(cfg, "compute_dtype", "bfloat16")
        report = {}
        for op, pol in sorted(policies.items()):
            entry = pol.describe()
            sig = _policy_signature(cfg, shape, op, dtype)
            if sig is not None:
                score = autotune.score_policy(sig, pol)
                entry["modeled_time_s"] = score.time_s
                entry["modeled_dma_bytes"] = score.dma_bytes
                entry.update(dict(score.detail))
            report[op] = entry
    return report


def fusion_cell_report(cfg, shape) -> dict:
    """Per-cell fusion factors for the hot GEMM chains (DESIGN.md §9-§11).

    For each chain the fusion subsystem can fuse (MLP/SwiGLU up+down,
    QKV→RoPE — each with and without the block's pre-norm folded into the
    first GEMM's A-tile prologue) this reports the modeled HBM traffic of
    the fused megakernel plan vs the unfused eager chain, and which plan
    the autotuner picks from dma_bytes alone. The ``norm_*`` cells are the
    prologue fusion factors: the same chain scored with the pre-norm on
    both sides (folded vs standalone). Train-shaped cells additionally
    carry ``*_bwd`` rows: the kernel-side fused backward (DESIGN.md §11 —
    saved-preact streams + two fused bwd GEMM launches per fwd GEMM) vs
    the oracle-recompute VJP, from the same byte models. Recorded next to
    the HLO roofline terms by the dry-run: the HLO terms say where the
    model sits, these say how much of the memory term the fused paths
    remove.
    """
    from repro import obs
    from repro.core import autotune

    dtype = getattr(cfg, "compute_dtype", "bfloat16")
    tokens = shape.global_batch * shape.seq_len
    dm = getattr(cfg, "d_model", 0)
    d_ff = getattr(cfg, "d_ff", 0) or 0
    norm_kind = getattr(cfg, "norm", "rmsnorm")
    train = getattr(shape, "kind", "train") == "train"
    report = {}

    def cell(plan):
        return {"plan": plan["plan"],
                "fused_bytes": plan["fused_bytes"],
                "unfused_bytes": plan["unfused_bytes"],
                "traffic_reduction": round(plan["traffic_reduction"], 3)}

    def chain(name, kind, chain_shape, **kw):
        report[name] = cell(autotune.select_fusion(kind, chain_shape, dtype,
                                                   **kw))
        if train:  # the bwd chains only run on the training path
            report[name + "_bwd"] = cell(autotune.select_fusion(
                kind, chain_shape, dtype, backward=True, **kw))

    with obs.span("roofline.fusion_report", kind=getattr(shape, "kind", "")):
        if dm and d_ff:
            gated = getattr(cfg, "mlp_act", "swiglu") in ("swiglu", "geglu")
            chain("mlp", "mlp", (tokens, dm, d_ff, gated))
            chain("norm_mlp", "mlp", (tokens, dm, d_ff, gated),
                  prenorm=norm_kind)
        h = getattr(cfg, "num_heads", 0)
        d = getattr(cfg, "head_dim", 0) or 0
        if dm and h and d:
            hkv = getattr(cfg, "num_kv_heads", h) or h
            if getattr(cfg, "rope_style", "none") == "half":
                chain("qkv_rope", "qkv_rope", (tokens, dm, h, hkv, d))
                chain("norm_qkv_rope", "qkv_rope", (tokens, dm, h, hkv, d),
                      prenorm=norm_kind)
            else:
                # rope-free archs (BERT/Whisper/enc-dec, 'partial' rope): the
                # packed-QKV chain only wins through the folded pre-norm, so
                # only the norm_* cell is informative (DESIGN.md §12)
                chain("norm_qkv", "qkv", (tokens, dm, h, hkv, d),
                      prenorm=norm_kind)
            # the attention op's own fused-vs-unfused plan (flash kernel vs
            # materialized-scores eager path, DESIGN.md §12); softcap widens
            # the unfused side's pass count
            softcap = bool(getattr(cfg, "attn_logit_softcap", None))
            chain("attention", "attention",
                  (shape.global_batch, h, hkv,
                   shape.seq_len, shape.seq_len, d),
                  causal=True, softcap=softcap)
    return report


def _policy_signature(cfg, shape, op, dtype):
    from repro.core.autotune import OpSignature

    b, s = shape.global_batch, shape.seq_len
    h = getattr(cfg, "num_heads", 0)
    d = getattr(cfg, "head_dim", 0) or 0
    try:
        if op in ("attention_fwd", "attention_bwd"):
            return OpSignature(op, (b, h, s, s, d), dtype, causal=True)
        if op == "attention_decode":
            hkv = getattr(cfg, "num_kv_heads", h) or h
            return OpSignature(op, (b, hkv, h // hkv, s, d), dtype)
        if op == "rope":
            return OpSignature(op, (b, h, s, d), dtype)
        if op == "fused_norm":
            return OpSignature(op, (b * s, cfg.d_model), dtype)
    except ValueError:
        return None
    return None


def model_flops_per_step(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per optimizer step; decode counts
    one token per sequence; prefill counts forward-only (2·N·D)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 new token/seq
