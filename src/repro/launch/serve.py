"""Serving launcher: batched generation with the request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --requests 16 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Engine, Request, RequestQueue


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, max_len=args.prompt_len + args.new_tokens + 8)
    queue = RequestQueue(engine, args.batch_size,
                         buckets=(args.prompt_len,))

    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = rng.integers(args.prompt_len // 2, args.prompt_len + 1)
        queue.submit(Request(uid, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32), args.new_tokens))
    served = queue.flush(force=True)
    print(f"[serve] served {served} requests "
          f"({len(queue.results)} unique results)")
    for uid in sorted(queue.results)[:4]:
        print(f"  req {uid}: {queue.results[uid][-args.new_tokens:]}")


if __name__ == "__main__":
    main()
