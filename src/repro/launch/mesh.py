"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The single-pod mesh is 16x16 = 256 chips (v5e pod),
('data', 'model'); the multi-pod mesh is 2x16x16 = 512 chips with a leading
'pod' axis that composes with 'data' for hierarchical data parallelism
(DESIGN.md §6).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the {'multi' if multi_pod else 'single'}"
            f"-pod mesh, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_axis: int = 1):
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
