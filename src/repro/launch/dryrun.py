import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below is ordinary code — including the
# docstring, which therefore can't use `from __future__` afterwards.

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build the *real* step function (train_step = loss + grads +
AdamW update; serve_step = prefill or one-token decode with the KV cache),
give it ShapeDtypeStruct inputs with production shardings, and
``.lower().compile()`` it for the 16x16 (single-pod, 256-chip) and 2x16x16
(multi-pod, 512-chip) meshes. The compiled artifact yields
``memory_analysis()`` (proves it fits) and ``cost_analysis()`` + HLO text
(feeds §Roofline). Failures here are sharding bugs in the system.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, ALL_SHAPES, get_config, get_shape,
                           shape_applicable)
from repro.models import build_model
from repro.models.lm import _is_uniform
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        data_axis_names, shardings_for_tree)
from repro.optim import AdamWConfig, adamw_update, constant_schedule
from repro.train.state import abstract_state, state_shardings
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rf


def _abstract_cache(model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def build_cell(arch_or_cfg, shape_name: str, mesh, *, zero1: bool = True):
    """Returns (jitted_fn, abstract_args) for the cell's step function."""
    cfg = (get_config(arch_or_cfg) if isinstance(arch_or_cfg, str)
           else arch_or_cfg)
    shape = get_shape(shape_name)
    daxes = data_axis_names(mesh)
    model = build_model(cfg, mode="reference", mesh=mesh, data_axes=daxes)
    abs_batch = model.batch_specs(shape)
    b_sh = batch_specs(abs_batch, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(schedule=constant_schedule(1e-4))
        st_sh = state_shardings(model, mesh, zero1=zero1, fsdp=cfg.fsdp)
        abs_st = abstract_state(model)

        def train_step(state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(state["params"])
            new_params, new_opt, om = adamw_update(
                opt_cfg, grads, state["opt"], state["params"])
            return {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}, {"loss": loss, **om}

        fn = jax.jit(train_step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
        return fn, (abs_st, abs_batch)

    # serving cells: params only (no optimizer state)
    p_sh = shardings_for_tree(model.axes(), model.abstract(), mesh)
    abs_params = model.abstract()

    if shape.kind == "prefill":
        abs_cache = _abstract_cache(model, shape.global_batch, shape.seq_len)
        c_sh = cache_specs(abs_cache, mesh,
                           stacked=(cfg.family == "encdec"
                                    or _is_uniform(cfg)))

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(c_sh, None), donate_argnums=(2,))
        return fn, (abs_params, abs_batch, abs_cache)

    # decode: one new token against a KV cache of seq_len
    abs_cache = _abstract_cache(model, shape.global_batch, shape.seq_len)
    c_sh = cache_specs(abs_cache, mesh,
                       stacked=(cfg.family == "encdec" or _is_uniform(cfg)))
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = batch_specs(tok, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    fn = jax.jit(decode_step, in_shardings=(p_sh, tok_sh, c_sh, None),
                 out_shardings=(c_sh, None), donate_argnums=(2,))
    return fn, (abs_params, tok, abs_cache, pos)


def _cost_once(cfg, shape_name: str, mesh) -> rf.Roofline:
    fn, args = build_cell(cfg, shape_name, mesh)
    with mesh:
        compiled = fn.lower(*args).compile()
    return rf.roofline_from_compiled(compiled)


def _extrapolated_costs(cfg, shape_name: str, mesh) -> rf.Roofline:
    """XLA cost_analysis counts a rolled scan body ONCE (verified: exactly
    1/L), so the layer-scan's work must be recovered. We cost the model at
    L=pattern and L=2·pattern layers and extrapolate linearly — exact for
    stacked-scan layouts. Inner scans are unrolled via REPRO_COSTING.
    Loop-layout archs (recurrentgemma) are already unrolled — cost directly.
    """
    from repro.models.lm import _layout
    layout = (_layout(cfg) if cfg.family != "encdec" else
              ("scan", ("encdec",), cfg.num_layers))
    os.environ["REPRO_COSTING"] = "1"
    try:
        if layout[0] != "scan" or layout[2] <= 2:
            return _cost_once(cfg, shape_name, mesh)
        _, pattern, n_groups = layout
        plen = len(pattern)
        if cfg.family == "encdec":
            cfg1 = dataclasses.replace(cfg, num_layers=plen,
                                       encoder_layers=max(1, cfg.encoder_layers
                                                          // cfg.num_layers))
            cfg2 = dataclasses.replace(cfg, num_layers=2 * plen,
                                       encoder_layers=max(2, 2 * cfg.encoder_layers
                                                          // cfg.num_layers))
        else:
            cfg1 = dataclasses.replace(cfg, num_layers=plen)
            cfg2 = dataclasses.replace(cfg, num_layers=2 * plen)
        r1 = _cost_once(cfg1, shape_name, mesh)
        r2 = _cost_once(cfg2, shape_name, mesh)

        def extrap(a, b):
            per = max(0.0, b - a)
            return a + (n_groups - 1) * per

        flops = extrap(r1.flops_per_chip, r2.flops_per_chip)
        hbm = extrap(r1.hbm_bytes_per_chip, r2.hbm_bytes_per_chip)
        coll = extrap(r1.collective_bytes_per_chip,
                      r2.collective_bytes_per_chip)
        by_kind = {k: extrap(r1.by_kind.get(k, 0.0), r2.by_kind.get(k, 0.0))
                   for k in set(r1.by_kind) | set(r2.by_kind)}
        compute_s = flops / rf.PEAK_FLOPS
        memory_s = hbm / rf.HBM_BW
        collective_s = coll / (rf.ICI_LINK_BW * rf.ICI_LINKS)
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        return rf.Roofline(flops, hbm, coll, compute_s, memory_s,
                           collective_s, max(terms, key=terms.get), by_kind)
    finally:
        os.environ.pop("REPRO_COSTING", None)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, verbose: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        record.update(status="skipped", reason=reason)
        return record
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    # the whole cell runs under a telemetry capture: the plan-audit journal
    # explains *why* each policy/fusion choice below was made (every
    # select_policy/select_fusion verdict with its losing candidates)
    from repro import obs
    with obs.capture() as cap:
        # full compile: proves the production config lowers + memory analysis
        fn, args = build_cell(cfg, shape_name, mesh)
        with mesh:
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            mem = rf.memory_dict(compiled)
        # costing compiles: scan-corrected roofline terms
        roof = _extrapolated_costs(cfg, shape_name, mesh)
        dt = time.time() - t0
        model_flops = rf.model_flops_per_step(cfg, shape)
        hlo_flops_total = roof.flops_per_chip * n_chips
        # the kernel policies this cell resolves to (autotuner per bucket)
        policies = rf.policy_cell_report(cfg, shape)
        # fused-vs-unfused modeled traffic for the hot GEMM chains, incl.
        # the norm-prologue cells and — on train shapes — the *_bwd cells
        # scoring the kernel-side fused backward vs the oracle-recompute
        # VJP (DESIGN.md §9-§11)
        fusion = rf.fusion_cell_report(cfg, shape)
    record.update(
        status="ok", n_chips=n_chips, compile_s=round(dt, 1),
        memory=mem, roofline=roof.as_dict(),
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / hlo_flops_total
                            if hlo_flops_total else None),
        params=cfg.param_count(), active_params=cfg.active_param_count(),
        policies=policies, fusion=fusion,
        launches=cap.launch_counts(),
        plan_decisions=[p.to_json() for p in cap.plans],
    )
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: "
              f"bound={roof.bound} compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"(compiled in {dt:.0f}s)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/chip={roof.flops_per_chip:.3e} "
              f"bytes/chip={roof.hbm_bytes_per_chip:.3e} "
              f"coll_bytes/chip={roof.collective_bytes_per_chip:.3e} "
              f"by_kind={ {k: f'{v:.2e}' for k, v in roof.by_kind.items()} }")
        pol_str = "; ".join(
            f"{op}: {p['schedule']}{tuple(p['blocks'])} {p['swizzle']}"
            for op, p in policies.items())
        print(f"  policies: {pol_str or 'none (attention-free, no norm)'}")
        fus_str = "; ".join(
            f"{chain}: {f['plan']} {f['traffic_reduction']}x"
            for chain, f in fusion.items())
        print(f"  fusion: {fus_str or 'none (no fusable GEMM chains)'}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--set", default="", dest="overrides",
                    help="perf levers, e.g. ce_chunk=512,remat_policy=dots,"
                         "rglru_f32_gates=False")
    args = ap.parse_args()

    overrides = {}
    for kv in filter(None, args.overrides.split(",")):
        k, v = kv.split("=")
        overrides[k] = (int(v) if v.lstrip("-").isdigit()
                        else v == "True" if v in ("True", "False") else v)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s.name) for a in ARCH_IDS for s in ALL_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    records = []
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            try:
                rec = run_cell(arch, shape, mesh_kind, overrides=overrides)
            except Exception as e:  # a failure here is a sharding bug
                failures += 1
                rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[dryrun] FAILED {arch} × {shape} × {mesh_kind}: {e}")
            records.append(rec)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                name = f"{arch}__{shape}__{mesh_kind}.json"
                with open(os.path.join(args.out, name), "w") as f:
                    json.dump(rec, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    skipped = sum(1 for r in records if r["status"] == "skipped")
    print(f"[dryrun] done: {ok} ok, {skipped} skipped (documented), "
          f"{failures} failed of {len(records)} cells")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
