"""Training launcher.

CPU-scale runs execute for real (``--smoke`` reduced configs or the paper's
llama-100m). Production-scale configs are launched with the same code path on
a real TPU fleet; on this host use ``repro.launch.dryrun`` for those.

  PYTHONPATH=src python -m repro.launch.train --arch llama-100m \
      --steps 200 --batch 8 --seq 512 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.data.pipeline import DataConfig, DataIterator
from repro.optim import AdamWConfig, cosine_schedule, wsd_schedule
from repro.train import train_loop, FailureInjector, StragglerWatchdog
from repro.launch.mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--kernels", choices=["reference", "pallas_interpret"],
                    default="reference")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated node failures at these steps")
    ap.add_argument("--mesh", action="store_true",
                    help="train data-parallel over all local devices")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    sched = (wsd_schedule if args.schedule == "wsd" else cosine_schedule)(
        args.lr, args.warmup, args.steps)
    opt = AdamWConfig(schedule=sched)

    mesh = make_host_mesh() if args.mesh else None
    model = build_model(cfg, mode=args.kernels, mesh=mesh)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    it = DataIterator(dcfg, mesh=mesh)

    res = train_loop(
        model, it, args.steps, opt, mesh=mesh, zero1=args.zero1,
        grad_compress=args.grad_compress, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        failure_injector=FailureInjector(tuple(args.fail_at)),
        watchdog=StragglerWatchdog())
    print(f"[train] finished: {len(res.losses)} steps, "
          f"first loss {res.losses[0]:.4f}, last loss {res.losses[-1]:.4f}, "
          f"restarts {res.restarts}, stragglers {len(res.straggler_events)}")


if __name__ == "__main__":
    main()
