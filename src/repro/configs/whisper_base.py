"""whisper-base [audio; arXiv:2212.04356]: enc-dec, conv frontend stubbed.

6L enc + 6L dec, d_model=512, 8H (kv=8), d_ff=2048, vocab=51865.
NOTE: real Whisper caps decoder positions at 448; the assigned shape set
exercises the *backbone* at 4k/32k decoder lengths, so the learned position
table is sized to max_seq_len (deviation recorded in DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, encoder_layers=6, d_model=512,
    num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865,
    mlp_act="gelu", norm="layernorm", rope_style="none",
    tie_embeddings=True, encoder_seq=1500, max_target_positions=448,
    max_seq_len=32768 + 8,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    num_layers=2, encoder_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    mlp_act="gelu", norm="layernorm", rope_style="none",
    tie_embeddings=True, encoder_seq=32, max_target_positions=64,
    max_seq_len=128,
)
