"""internvl2-2b [vlm; arXiv:2404.16821; hf]: InternViT (stub) + InternLM2.

LM backbone: 24L, d_model=2048, 16H (kv=8), d_ff=8192, vocab=92553.
The ViT frontend is a STUB per the assignment: input_specs() supplies 256
precomputed patch embeddings prepended to the token sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    num_patches=256,
    mlp_act="swiglu", norm="rmsnorm", rope_theta=1e6,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-2b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    num_patches=8,
    mlp_act="swiglu", norm="rmsnorm",
    max_seq_len=256,
)
