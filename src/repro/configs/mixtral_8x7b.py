"""mixtral-8x7b [moe; arXiv:2401.04088; hf]: 8 experts top-2, SWA.

32L, d_model=4096, 32H (kv=8), d_ff=14336 per expert, vocab=32000.
Sliding-window attention (4096) keeps decode memory O(window) — this arch
RUNS the long_500k cell (ring-buffer KV cache).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="lm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25, shard="ffn"),
    attn_window=4096, sub_quadratic=True,
    mlp_act="swiglu", norm="rmsnorm", rope_theta=1e6,
    max_seq_len=524288,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-8x7b-smoke", family="lm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
    attn_window=32, sub_quadratic=True,
    mlp_act="swiglu", norm="rmsnorm",
    max_seq_len=256,
)
