"""Paper end-to-end validation configs (§4: Llama pretraining parity).

The paper pretrains Llama-1B on SlimPajama to validate kernel stability.
We mirror that with a ~100M llama-family model trained for a few hundred
steps on the synthetic pipeline (examples/train_e2e.py), comparing the
Pallas-kernel path against the pure-XLA reference path.
"""
from .base import ModelConfig

LLAMA_100M = ModelConfig(
    name="llama-100m", family="lm",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=2048, vocab_size=32000,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True,
    max_seq_len=2048,
)

LLAMA_1B = ModelConfig(
    name="llama-1b", family="lm",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True,
    max_seq_len=8192,
)

# The paper's second §4 validation model: BERT-base (110M), encoder-only MLM.
BERT_110M = ModelConfig(
    name="bert-110m", family="encoder",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=30522,
    mlp_act="gelu", norm="layernorm", rope_style="none",
    tie_embeddings=True, max_seq_len=512,
)
