"""llama4-maverick-400b-a17b [moe; hf:meta-llama; unverified].

48L, d_model=5120, 40H (kv=8), d_ff=8192 per expert, vocab=202048,
MoE 128 experts top-1, interleaved dense/MoE layers (Maverick's
interleave_moe_layer_step=2 — this is what makes the total land at ~400B
with 128 experts). Early-fusion multimodality is out of scope for the
assigned LM shapes (text backbone only).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="lm",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    block_pattern=("attn", "moe"),
    moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25),
    mlp_act="swiglu", norm="rmsnorm", rope_theta=500000.0,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-maverick-smoke", family="lm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    block_pattern=("attn", "moe"),
    moe=MoEConfig(num_experts=8, top_k=1, capacity_factor=2.0),
    mlp_act="swiglu", norm="rmsnorm",
    max_seq_len=256,
)
