"""granite-8b [dense; arXiv:2405.04324; hf]: llama-arch code model.

36L, d_model=4096, 32H (kv=8), d_ff=14336, vocab=49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="lm",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=49152,
    mlp_act="swiglu", norm="rmsnorm", rope_theta=10000.0,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-8b-smoke", family="lm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    mlp_act="swiglu", norm="rmsnorm",
    max_seq_len=256,
)
