"""Config registry: ``get_config('<arch-id>'[, smoke=True])``."""
from __future__ import annotations

import importlib

from .base import (ModelConfig, MoEConfig, SSMConfig, RGLRUConfig,  # noqa: F401
                   ShapeConfig, KernelsConfig, ALL_SHAPES, TRAIN_4K,
                   PREFILL_32K, DECODE_32K, LONG_500K, shape_applicable)

ARCH_IDS = (
    "whisper-base",
    "minicpm-2b",
    "chatglm3-6b",
    "granite-8b",
    "qwen2-72b",
    "llama4-maverick-400b-a17b",
    "mixtral-8x7b",
    "mamba2-130m",
    "recurrentgemma-2b",
    "internvl2-2b",
)

_MODULES = {
    "whisper-base": "whisper_base",
    "minicpm-2b": "minicpm_2b",
    "chatglm3-6b": "chatglm3_6b",
    "granite-8b": "granite_8b",
    "qwen2-72b": "qwen2_72b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-2b": "internvl2_2b",
    "llama-100m": "llama_paper",
    "llama-1b": "llama_paper",
    "bert-110m": "llama_paper",
}


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if name == "llama-100m":
        return mod.LLAMA_100M
    if name == "llama-1b":
        return mod.LLAMA_1B
    if name == "bert-110m":
        return mod.BERT_110M
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
