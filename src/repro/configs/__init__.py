"""Config registry: ``get_config('<arch-id>'[, smoke=True])``, plus the
shipped pretuned-table resolver (``pretuned_table_path`` /
``load_shipped_pretuned`` — docs/autotuning.md)."""
from __future__ import annotations

import importlib
import os

from .base import (ModelConfig, MoEConfig, SSMConfig, RGLRUConfig,  # noqa: F401
                   ShapeConfig, KernelsConfig, ALL_SHAPES, TRAIN_4K,
                   PREFILL_32K, DECODE_32K, LONG_500K, shape_applicable)

ARCH_IDS = (
    "whisper-base",
    "minicpm-2b",
    "chatglm3-6b",
    "granite-8b",
    "qwen2-72b",
    "llama4-maverick-400b-a17b",
    "mixtral-8x7b",
    "mamba2-130m",
    "recurrentgemma-2b",
    "internvl2-2b",
)

_MODULES = {
    "whisper-base": "whisper_base",
    "minicpm-2b": "minicpm_2b",
    "chatglm3-6b": "chatglm3_6b",
    "granite-8b": "granite_8b",
    "qwen2-72b": "qwen2_72b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-2b": "internvl2_2b",
    "llama-100m": "llama_paper",
    "llama-1b": "llama_paper",
    "bert-110m": "llama_paper",
}


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if name == "llama-100m":
        return mod.LLAMA_100M
    if name == "llama-1b":
        return mod.LLAMA_1B
    if name == "bert-110m":
        return mod.BERT_110M
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


_PRETUNED_DIR = os.path.join(os.path.dirname(__file__), "pretuned")


def pretuned_table_path(arch: str | None = None) -> str | None:
    """Path of the shipped pretuned policy table for ``arch`` (default: the
    active jax backend), or None when no table was calibrated for it.
    Tables are written by ``tools/calibrate.py`` and live next to the model
    configs so a checkout carries its calibration."""
    if arch is None:
        import jax
        arch = jax.default_backend()
    path = os.path.join(_PRETUNED_DIR, f"{arch}.json")
    return path if os.path.exists(path) else None


def load_shipped_pretuned(arch: str | None = None) -> bool:
    """Install the shipped pretuned table for ``arch`` into the autotuner.
    Returns False (leaving selection analytic) when no table is shipped or
    the table is rejected (schema/arch mismatch — see the obs counters)."""
    path = pretuned_table_path(arch)
    if path is None:
        return False
    from repro.core import autotune
    return autotune.load_pretuned(path, arch=arch)
