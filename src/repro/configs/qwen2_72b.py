"""qwen2-72b [dense; arXiv:2407.10671; hf]: GQA with QKV bias.

80L, d_model=8192, 64H (kv=8), d_ff=29568, vocab=152064, rope theta 1e6.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="lm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    mlp_act="swiglu", norm="rmsnorm", qkv_bias=True, rope_theta=1e6,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-72b-smoke", family="lm",
    num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    mlp_act="swiglu", norm="rmsnorm", qkv_bias=True, rope_theta=1e6,
    max_seq_len=256,
)
