"""recurrentgemma-2b [hybrid; arXiv:2402.19427; hf]: RG-LRU + local attn 1:2.

26L, d_model=2560, 10H (kv=1 — MQA), d_ff=7680 (GeGLU), vocab=256000.
Block pattern (rg, rg, local): two recurrent blocks per local-attention
block (window 2048). Sub-quadratic ⇒ long_500k RUNS.
"""
import math
from .base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="lm",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000,
    block_pattern=("rg", "rg", "local"),
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, c_exponent=8.0,
                      local_window=2048),
    mlp_act="geglu", norm="rmsnorm", tie_embeddings=True,
    emb_scale=math.sqrt(2560), sub_quadratic=True,
    max_seq_len=524288,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-2b-smoke", family="lm",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=512,
    block_pattern=("rg", "rg", "local"),
    rglru=RGLRUConfig(lru_width=64, conv_width=4, c_exponent=8.0,
                      local_window=32),
    mlp_act="geglu", norm="rmsnorm", tie_embeddings=True,
    emb_scale=8.0, sub_quadratic=True,
    max_seq_len=256,
)
