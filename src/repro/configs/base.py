"""Config system: one dataclass describes every supported architecture.

Each assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published dims) and ``SMOKE_CONFIG`` (a reduced same-family config
for CPU smoke tests). ``repro.configs.get_config(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class KernelsConfig:
    """Kernel dispatch: 'reference' | 'pallas_interpret' | 'pallas_tpu'."""
    mode: str = "reference"
    block_q: int = 128
    block_kv: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    impl: str = "auto"  # 'dense' | 'ep' | 'tp' | 'auto'
    # weight sharding: 'expert' (EP: expert dim over model axis — needs
    # E % |model| == 0) or 'ffn' (Megatron TP within each expert — for archs
    # like Mixtral where E=8 < |model|=16)
    shard: str = "expert"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: Optional[int] = None   # defaults to d_model
    conv_width: int = 4
    c_exponent: float = 8.0
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # 'lm' | 'encdec' | 'vlm'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    # block pattern, cycled over layers: 'attn' (dense attn+mlp),
    # 'moe' (attn + moe ffn), 'ssm' (mamba2 block), 'rg' (RG-LRU block),
    # 'local' (windowed attn + mlp)
    block_pattern: Sequence[str] = ("attn",)
    mlp_act: str = "swiglu"     # 'swiglu' | 'geglu' | 'gelu'
    norm: str = "rmsnorm"       # 'rmsnorm' | 'layernorm'
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rope_style: str = "half"    # 'half' | 'partial' (chatglm 2d: rope on half the head dim) | 'none'
    attn_window: Optional[int] = None        # sliding-window attention
    attn_logit_softcap: Optional[float] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec (whisper): encoder stack dims (decoder uses the main fields)
    encoder_layers: int = 0
    encoder_seq: int = 1500      # precomputed frame embeddings (frontend stub)
    max_target_positions: int = 448
    # vlm: number of prepended patch embeddings (frontend stub)
    num_patches: int = 0
    # numerics / training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    dropout_p: float = 0.0
    # --- perf levers (EXPERIMENTS.md §Perf; defaults = faithful baseline) ---
    ce_chunk: int = 0            # >0: chunked CE loss, logits never (B,S,V)
    remat_policy: str = "full"   # 'full' | 'dots' (save matmul outputs) | 'none'
    rglru_f32_gates: bool = True # False: bf16 gate matmuls (fp32 carries kept)
    rglru_chunk: int = 0         # >0: two-level RG-LRU scan (see rglru.py)
    embed_shard: str = "vocab"   # 'vocab' | 'embed': d-shard the table so the
                                 # gather ends in an all-gather (1x) instead of
                                 # an all-reduce (2x); untied archs only
    kv_shard: bool = True        # False: replicate wk/wv (kv_heads < |model|
                                 # makes head-sharding impossible; GSPMD then
                                 # all-gathers KV every layer — replicating the
                                 # small KV weights removes those collectives)
    fsdp: bool = False           # shard params over 'data' too (ZeRO-3 —
                                 # per-layer weight all-gathers inside scan);
                                 # required where TP-sharded params > HBM
    vocab_pad_multiple: int = 0  # Megatron-style: pad V up so the embedding/
                                 # LM head shard over 'model' (minicpm's
                                 # V=122753 and internvl2's 92553 otherwise
                                 # replicate the largest matmul in the model)

    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        if not m:
            return self.vocab_size
        return -(-self.vocab_size // m) * m
    # misc per-arch quirks
    emb_scale: float = 1.0       # minicpm scale_emb
    residual_scale: float = 1.0  # minicpm scale_depth / sqrt(L)
    logit_scale_div: float = 1.0 # minicpm dim_model_base logits scaling
    max_seq_len: int = 8192
    sub_quadratic: bool = False  # True => long_500k shape is runnable

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(1, self.num_kv_heads) == 0

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D roofline bookkeeping)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        h, hkv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d            # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d       # lm head
        def attn_p():
            return d * h * hd + 2 * d * hkv * hd + h * hd * d
        def mlp_p(ff):
            n_in = 2 if self.mlp_act in ("swiglu", "geglu") else 1
            return n_in * d * ff + ff * d
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "local"):
                total += attn_p() + mlp_p(f) + 2 * d
            elif kind == "moe":
                e = self.moe.num_experts
                total += attn_p() + d * e + e * mlp_p(f) + 2 * d
            elif kind == "ssm":
                s = self.ssm
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                total += conv_dim * s.d_conv + 3 * nheads + d_in + d_in * d + d
            elif kind == "rg":
                w = self.rglru.lru_width or d
                total += 2 * d * w + w * d + 2 * w + w * self.rglru.conv_width
                total += mlp_p(f) + 2 * d
        # encoder stack (whisper)
        for _ in range(self.encoder_layers):
            total += attn_p() + mlp_p(f) + 2 * d
        if self.encoder_layers:  # cross-attention in every decoder layer
            total += self.num_layers * attn_p()
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts FFNs)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_in = 2 if self.mlp_act in ("swiglu", "geglu") else 1
        per_expert = n_in * d * f + f * d
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if self.layer_kind(i) == "moe")
        inactive = n_moe_layers * (self.moe.num_experts - self.moe.top_k) * per_expert
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell is live; else reason (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention (documented skip)"
    return True, ""
