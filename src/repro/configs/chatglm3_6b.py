"""chatglm3-6b [dense; arXiv:2406.12793; hf]: 2d (partial) RoPE, 2-group GQA.

28L, d_model=4096, 32H (kv=2), d_ff=13696, vocab=65024, qkv bias.
ChatGLM applies rotary embedding to half of each head's dims
(rope_style='partial').
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="lm",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    mlp_act="swiglu", norm="rmsnorm", qkv_bias=True,
    rope_style="partial",
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="chatglm3-6b-smoke", family="lm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    mlp_act="swiglu", norm="rmsnorm", qkv_bias=True,
    rope_style="partial",
    max_seq_len=256,
)
