"""minicpm-2b [dense; arXiv:2404.06395; hf]: llama-like, WSD schedule.

40L, d_model=2304, 36H (kv=36 — MHA), d_ff=5760, vocab=122753.
MiniCPM quirks: scale_emb=12, residual scale_depth=1.4/sqrt(L), logits
divided by d_model/dim_model_base = 2304/256 = 9, tied embeddings.
Training uses the WSD (warmup-stable-decay) schedule — see repro.optim.
"""
import math
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="lm",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True,
    emb_scale=12.0, residual_scale=1.4 / math.sqrt(40),
    logit_scale_div=2304 / 256,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm-2b-smoke", family="lm",
    num_layers=3, d_model=96, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=512,
    mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True,
    emb_scale=12.0, residual_scale=1.4 / math.sqrt(3),
    logit_scale_div=96 / 32,
    max_seq_len=256,
)
