"""mamba2-130m [ssm; arXiv:2405.21060]: SSD (state-space duality), attn-free.

24L, d_model=768, ssm_state=128, head_dim=64, expand=2, vocab=50280.
Attention-free ⇒ the paper's attention kernels are inapplicable (DESIGN.md
§4); SSD chunk matmuls inherit the GEMM treatment. Constant-size state ⇒
long_500k RUNS.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="lm",
    num_layers=24, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=0, vocab_size=50280,
    block_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=128),
    norm="rmsnorm", rope_style="none", tie_embeddings=True,
    sub_quadratic=True,
    max_seq_len=524288,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-130m-smoke", family="lm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=512,
    block_pattern=("ssm",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=1,
                  chunk=16),
    norm="rmsnorm", rope_style="none", tie_embeddings=True,
    sub_quadratic=True,
    max_seq_len=256,
)
