"""KernelPolicy: one object that fully determines a kernel's tiling strategy.

HipKittens' central claim (§3.3-3.4, Tab. 2-3) is that peak AMD performance
comes from choosing the right *schedule* (8-wave ping-pong vs 4-wave
interleave) and *traversal order* (Algorithm-1 swizzle) per workload. In this
repo those two axes — plus tile dtypes and the VMEM-budget legality rule that
bounds them (Tab. 2's register-budget argument, TPU-adapted) — compose into a
single frozen, hashable :class:`KernelPolicy`:

    policy = KernelPolicy(op="gemm",
                          schedule=Schedule(...),   # pipeline depth + blocks
                          swizzle=SwizzleConfig(...),  # Algorithm 1 params
                          in_dtype="bfloat16", acc_dtype="float32")

Every Pallas kernel in ``repro.kernels`` consumes a policy instead of loose
block ints; :mod:`repro.core.autotune` enumerates legal policies for an op
signature and ranks them with the analytic models. A policy is *inspectable*
(``describe()``), *legal by construction* (``check()`` routes through
``tiles.check_vmem_budget``) and *static-argument friendly* (frozen/hashable,
so ``jax.jit`` can close over it).

Block-field conventions per op kind (the Schedule's three block dims are
reused so one Schedule type serves every kernel family):

  op               block_m        block_n         block_k
  ---------------  -------------  --------------  -------------------
  gemm             output rows    output cols     contraction block
  attention_fwd    block_q        block_kv        head_dim
  attention_bwd    block_q        block_kv        head_dim
  attention_decode q rows (GQA    KV-split size   head_dim
                   group, padded) (slots/step)
  fused_norm       block_rows     (unused: 0)     feature dim d
  rope             block_s        (unused: 0)     head_dim

See DESIGN.md §5 for the policy resolution order.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from . import tiles
from .grid_swizzle import ROW_MAJOR, SwizzleConfig
from .schedule import PINGPONG, Schedule

# Kernel kinds a policy can describe. attention fwd/bwd are separate kinds
# because the bwd pass has a ~2.5x larger scratch working set (dk+dv or dq
# accumulators) and may legally need smaller tiles than fwd.
# attention_decode is the split-KV flash-decode kind (q_len=1, GQA group
# packed into the q tile): its perf model is bandwidth-, not FLOP-,
# dominated, and block_n carries the KV-split size (one split per grid step).
# gemm_bwd is one launch of the fused backward (DESIGN.md §11): block dims
# follow the *launch's own* (out_rows, out_cols, contraction) GEMM shape —
# (M, K, N) for dA, (K, N, M) for dB — and the chain's saved-preactivation
# streams ride the cotangent panel in the VMEM accounting.
OP_KINDS = ("gemm", "gemm_bwd", "attention_fwd", "attention_bwd",
            "attention_decode", "fused_norm", "rope")

_ACC_BYTES = {"float32": 4, "bfloat16": 2}


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """A complete, legal-by-construction tiling strategy for one kernel kind.

    ``epilogue`` is a fused store chain. On gemm/gemm_bwd policies it is any
    frozen object with the :class:`repro.kernels.gemm.epilogue.Epilogue`
    protocol (``extra_operand_blocks``/``extra_scratch_accumulators``/
    ``describe``); on the attention kinds it is the
    :class:`repro.kernels.attention.epilogue.AttnEpilogue` protocol
    (softcap/sink stages inside the online-softmax loop and store,
    DESIGN.md §12). ``prologue`` (gemm only) is the symmetric fused
    A-operand chain — any frozen object with the
    :class:`repro.kernels.gemm.prologue.Prologue` protocol
    (``extra_operand_blocks``/``needs_full_k``/``describe``).
    All are duck-typed here so ``repro.core`` never imports
    ``repro.kernels``; their extra streamed blocks and the epilogue's second
    accumulator count against the VMEM legality rule exactly like the A/B
    panels (DESIGN.md §9-§10).
    """

    op: str
    schedule: Schedule
    swizzle: SwizzleConfig = ROW_MAJOR
    in_dtype: str = "bfloat16"
    acc_dtype: str = "float32"
    epilogue: Optional[object] = None
    prologue: Optional[object] = None

    def __post_init__(self):
        if self.op not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.op!r}; have {OP_KINDS}")
        if self.acc_dtype not in _ACC_BYTES:
            raise ValueError(f"unsupported acc_dtype {self.acc_dtype!r}")
        if self.epilogue is not None and self.op not in (
                "gemm", "gemm_bwd", "attention_fwd", "attention_bwd",
                "attention_decode"):
            raise ValueError(f"epilogue chains only apply to gemm/gemm_bwd/"
                             f"attention policies, not {self.op!r}")
        if self.prologue is not None and self.op not in ("gemm", "gemm_bwd"):
            raise ValueError(f"prologue chains only apply to gemm/gemm_bwd "
                             f"policies, not {self.op!r}")

    # -- block accessors (names per the op-kind table in the module doc) ----
    @property
    def block_m(self) -> int:
        return self.schedule.block_m

    @property
    def block_n(self) -> int:
        return self.schedule.block_n

    @property
    def block_k(self) -> int:
        return self.schedule.block_k

    @property
    def block_q(self) -> int:
        return self.schedule.block_m

    @property
    def block_kv(self) -> int:
        return self.schedule.block_n

    @property
    def block_rows(self) -> int:
        return self.schedule.block_m

    @property
    def n_buffers(self) -> int:
        return self.schedule.n_buffers

    # -- working-set accounting --------------------------------------------
    def operand_blocks(self) -> list:
        """(shape, dtype) of each pipelined operand block, per op kind."""
        s = self.schedule
        if self.op == "gemm":
            blocks = [((s.block_m, s.block_k), self.in_dtype),
                      ((s.block_k, s.block_n), self.in_dtype)]
            if self.prologue is not None:
                blocks += self.prologue.extra_operand_blocks(
                    s.block_m, s.block_k, self.in_dtype)
            if self.epilogue is not None:
                blocks += self.epilogue.extra_operand_blocks(
                    s.block_m, s.block_n, s.block_k, self.in_dtype)
            return blocks
        if self.op == "gemm_bwd":
            # one bwd launch of the fused backward (DESIGN.md §11): a primal
            # panel and a cotangent panel, the saved preactivation streams
            # riding the cotangent's pipeline slot (fp32 for scale chains —
            # Epilogue.preact_keeps_f32), a second weight panel for the
            # dual-GEMM gate, the raw-A block the norm-prologue dA launch
            # streams for its tile-wise transpose, and the prologue's
            # gamma/beta/stats blocks. Approximate but conservative — the
            # launch builders re-enforce the exact budget at trace time
            # (tiles.check_vmem_budget in kernels/gemm/backward).
            blocks = [((s.block_m, s.block_k), self.in_dtype),
                      ((s.block_k, s.block_n), self.in_dtype)]
            if self.epilogue is not None:
                n_saved = getattr(self.epilogue, "saved_accumulators", 0)
                p_dtype = ("float32"
                           if getattr(self.epilogue, "preact_keeps_f32",
                                      False) else self.in_dtype)
                blocks += [((s.block_k, s.block_n), p_dtype)] * n_saved
                # the chain's streamed operand blocks (b2 panel, bias row,
                # scale block, sin/cos rows, residual tile) — the fwd-shaped
                # estimate over-counts the bwd slightly (dresidual never
                # streams), which errs on the reject side
                blocks += self.epilogue.extra_operand_blocks(
                    s.block_m, s.block_n, s.block_k, self.in_dtype)
            if self.prologue is not None and not getattr(
                    self.prologue, "is_identity", True):
                blocks.append(((s.block_m, s.block_n), self.in_dtype))
                blocks += self.prologue.extra_operand_blocks(
                    s.block_m, s.block_k, self.in_dtype)
            return blocks
        if self.op in ("attention_fwd", "attention_bwd", "attention_decode"):
            d = s.block_k  # head_dim by convention
            blocks = [((s.block_m, d), self.in_dtype),   # q (or do) block
                      ((s.block_n, d), self.in_dtype),   # k block
                      ((s.block_n, d), self.in_dtype)]   # v block
            if self.op == "attention_bwd":
                blocks.append(((s.block_m, d), self.in_dtype))  # do block
            if self.epilogue is not None:
                # attention epilogue chains stream at most a per-head sink
                # scalar (softcap is vector work on resident tiles)
                blocks += self.epilogue.extra_operand_blocks(
                    s.block_m, s.block_n, d, self.in_dtype)
            return blocks
        if self.op == "fused_norm":
            # x + residual in, normed + residual out: 4 row-blocks in flight
            return [((s.block_m, s.block_k), self.in_dtype)] * 4
        if self.op == "rope":
            # x block + sin/cos tables + out block
            return [((s.block_m, s.block_k), self.in_dtype),
                    ((s.block_m, s.block_k), "float32"),
                    ((s.block_m, s.block_k), "float32"),
                    ((s.block_m, s.block_k), self.in_dtype)]
        raise AssertionError(self.op)

    def scratch_bytes(self) -> int:
        """Pinned accumulator scratch (the TPU analogue of HK's pinned AGPRs)."""
        s = self.schedule
        acc = _ACC_BYTES[self.acc_dtype]
        if self.op in ("gemm", "gemm_bwd"):
            n_acc = 1 + (self.epilogue.extra_scratch_accumulators()
                         if self.epilogue is not None else 0)
            return n_acc * s.block_m * s.block_n * acc
        if self.op == "attention_fwd":
            # acc (bq, d) + running max/sum (bq, LANE) each
            return s.block_m * s.block_k * acc + 2 * s.block_m * tiles.LANE * acc
        if self.op == "attention_bwd":
            # dq pass: (bq, d); dkv pass: 2x (bkv, d) — budget for the larger
            return max(s.block_m * s.block_k, 2 * s.block_n * s.block_k) * acc
        # fused_norm / rope / attention_decode keep no cross-iteration
        # scratch (decode grid cells are independent: partials + m/l stats
        # are written straight out and merged by the jnp combine step).
        return 0

    def vmem_bytes(self) -> int:
        """Modeled VMEM working set of the pipelined pallas_call."""
        return tiles.pipeline_vmem_bytes(
            self.operand_blocks(), n_buffers=self.schedule.n_buffers,
            scratch_bytes=self.scratch_bytes())

    def is_legal(self, budget: Optional[int] = None) -> bool:
        """True iff the working set fits the (producer-taxed) VMEM budget."""
        budget = budget if budget is not None else self.schedule.vmem_budget()
        try:
            self.check(budget=budget)
        except ValueError:
            return False
        return True

    def check(self, budget: Optional[int] = None) -> int:
        """Raise ValueError on VMEM overflow; returns bytes used otherwise."""
        budget = budget if budget is not None else self.schedule.vmem_budget()
        return tiles.check_vmem_budget(
            self.operand_blocks(), n_buffers=self.schedule.n_buffers,
            scratch_bytes=self.scratch_bytes(), budget=budget,
            what=f"{self.op} policy {self.schedule.name!r}")

    # -- shape fitting ------------------------------------------------------
    def fits(self, *dims: int) -> bool:
        """True iff each problem dim is divisible by the matching block dim.

        gemm: fits(m, n, k); attention: fits(sq, skv); 1-D ops: fits(rows).
        """
        blocks = (self.block_m, self.block_n, self.block_k)
        return all(d % b == 0 for d, b in zip(dims, blocks) if b)

    def describe(self) -> dict:
        """JSON-able summary for dryrun/roofline/benchmark reports."""
        s, sw = self.schedule, self.swizzle
        return {
            "op": self.op,
            "epilogue": (self.epilogue.describe()
                         if self.epilogue is not None else "none"),
            "prologue": (self.prologue.describe()
                         if self.prologue is not None else "none"),
            "schedule": s.name,
            "blocks": [s.block_m, s.block_n, s.block_k],
            "n_buffers": s.n_buffers,
            "swizzle": ("row_major" if not (sw.enable_window or sw.enable_chiplet)
                        else f"W{sw.window}/C{sw.chunk}"
                             f"{'/xcd' if sw.enable_chiplet else ''}"),
            "in_dtype": self.in_dtype,
            "acc_dtype": self.acc_dtype,
            "vmem_mib": round(self.vmem_bytes() / 2**20, 2),
        }

    def cache_key(self) -> tuple:
        return (self.op, self.schedule, self.swizzle, self.in_dtype,
                self.acc_dtype, self.epilogue, self.prologue)


# ---------------------------------------------------------------------------
# Construction helpers + the deprecation shim used by the kernels' old kwargs.
# ---------------------------------------------------------------------------

def make_policy(op: str, *, block_m: int, block_n: int = 0, block_k: int = 0,
                n_buffers: int = 2, swizzle: SwizzleConfig = ROW_MAJOR,
                in_dtype: str = "bfloat16", acc_dtype: str = "float32",
                name: str = "explicit",
                epilogue: Optional[object] = None,
                prologue: Optional[object] = None) -> KernelPolicy:
    """Build a policy from explicit block dims (no legality enforcement —
    call .check() to enforce; the autotuner only emits legal ones)."""
    sched = Schedule(name, n_buffers=n_buffers, block_m=block_m,
                     block_n=block_n, block_k=block_k)
    return KernelPolicy(op=op, schedule=sched, swizzle=swizzle,
                        in_dtype=in_dtype, acc_dtype=acc_dtype,
                        epilogue=epilogue, prologue=prologue)


def policy_spec(policy: KernelPolicy) -> dict:
    """JSON-able, bitwise-reconstructible spec of a policy's schedule /
    swizzle / dtype axes (for the pretuned tables of DESIGN.md §15).

    The chain objects are deliberately NOT serialized: a pretuned-table cell
    is looked up under a key that already encodes the chain (its
    ``describe()`` strings), and :func:`policy_from_spec` re-attaches the
    caller's *live* epilogue/prologue objects — they carry callables that
    have no stable JSON form.
    """
    s, sw = policy.schedule, policy.swizzle
    return {
        "op": policy.op,
        "schedule": {"name": s.name, "n_buffers": s.n_buffers,
                     "block_m": s.block_m, "block_n": s.block_n,
                     "block_k": s.block_k,
                     "producer_fraction": s.producer_fraction},
        "swizzle": {"window": sw.window, "chunk": sw.chunk,
                    "n_xcd": sw.n_xcd,
                    "enable_chiplet": sw.enable_chiplet,
                    "enable_window": sw.enable_window},
        "in_dtype": policy.in_dtype,
        "acc_dtype": policy.acc_dtype,
    }


def policy_from_spec(spec: dict, *, epilogue: Optional[object] = None,
                     prologue: Optional[object] = None) -> KernelPolicy:
    """Inverse of :func:`policy_spec`; round-trips bitwise (frozen-dataclass
    equality) when the same chain objects are re-attached."""
    sc = spec["schedule"]
    sched = Schedule(sc["name"], n_buffers=int(sc["n_buffers"]),
                     block_m=int(sc["block_m"]), block_n=int(sc["block_n"]),
                     block_k=int(sc["block_k"]),
                     producer_fraction=float(sc.get("producer_fraction", 0.0)))
    sw = spec["swizzle"]
    swizzle = SwizzleConfig(window=int(sw["window"]), chunk=int(sw["chunk"]),
                            n_xcd=int(sw["n_xcd"]),
                            enable_chiplet=bool(sw["enable_chiplet"]),
                            enable_window=bool(sw["enable_window"]))
    return KernelPolicy(op=spec["op"], schedule=sched, swizzle=swizzle,
                        in_dtype=spec["in_dtype"],
                        acc_dtype=spec.get("acc_dtype", "float32"),
                        epilogue=epilogue, prologue=prologue)


def legacy_policy(op: str, *, warn_what: str = "", **blocks) -> KernelPolicy:
    """Deprecation shim: construct an explicit policy from the pre-policy
    loose-int keyword arguments (block_m/block_n/block_k/block_q/block_kv/
    block_rows/block_s + swizzle). Emits a DeprecationWarning so call sites
    migrate to passing a KernelPolicy."""
    warnings.warn(
        f"{warn_what or op}: raw block-size keywords are deprecated; pass "
        "policy=KernelPolicy(...) (or let repro.core.autotune select one)",
        DeprecationWarning, stacklevel=3)
    swizzle = blocks.pop("swizzle", None) or ROW_MAJOR
    if op == "gemm":
        bm, bn, bk = blocks["block_m"], blocks["block_n"], blocks["block_k"]
    elif op in ("attention_fwd", "attention_bwd"):
        bm, bn, bk = blocks["block_q"], blocks["block_kv"], blocks["head_dim"]
    elif op == "fused_norm":
        bm, bn, bk = blocks["block_rows"], 0, blocks["d"]
    elif op == "rope":
        bm, bn, bk = blocks["block_s"], 0, blocks["d"]
    else:
        raise ValueError(f"unknown op kind {op!r}")
    return make_policy(op, block_m=bm, block_n=bn, block_k=bk,
                       swizzle=swizzle, name="legacy",
                       in_dtype=blocks.get("in_dtype", "bfloat16"))


def legacy_attention_blocks(block_q, block_kv, sq: int, skv: int,
                            d: int) -> Optional[dict]:
    """The attention deprecation-shim clamp, shared by flash fwd/bwd and the
    public attention op: None when no legacy block keywords were passed,
    else the clamped block dict for :func:`resolve_policy`'s legacy path."""
    if block_q is None and block_kv is None:
        return None
    return dict(block_q=min(block_q or 128, sq),
                block_kv=min(block_kv or 128, skv), head_dim=d)


def resolve_policy(op: str, shape, dtype="bfloat16", *, causal: bool = False,
                   legacy_blocks: Optional[dict] = None,
                   warn_what: str = "") -> KernelPolicy:
    """Steps 2-3 of the DESIGN.md §5 resolution order, shared by every
    kernel entry point: explicit legacy block keywords build a shim policy
    (with a DeprecationWarning); otherwise the autotuner selects one,
    memoized per (op, shape-bucket, dtype).

    ``legacy_blocks`` is None when the caller received no legacy keywords;
    otherwise it holds the op-specific block kwargs already clamped to the
    problem (the clamp is the only per-kernel part of the old duplicated
    resolution blocks).
    """
    if legacy_blocks is not None:
        return legacy_policy(op, warn_what=warn_what, **legacy_blocks)
    from . import autotune  # function-level: autotune imports this module

    return autotune.select_policy(op, shape, str(dtype), causal=causal)


# Conservative defaults per op kind — used only as the last-resort fallback
# when the autotuner is bypassed (see DESIGN.md §5 resolution order).
DEFAULT_GEMM = KernelPolicy("gemm", PINGPONG)
DEFAULT_ATTENTION_FWD = make_policy("attention_fwd", block_m=128, block_n=128,
                                    block_k=128, name="default_attn")
DEFAULT_ATTENTION_BWD = make_policy("attention_bwd", block_m=128, block_n=128,
                                    block_k=128, name="default_attn_bwd")
DEFAULT_ATTENTION_DECODE = make_policy("attention_decode", block_m=8,
                                       block_n=128, block_k=128,
                                       name="default_attn_decode")
DEFAULT_FUSED_NORM = make_policy("fused_norm", block_m=256, block_k=1024,
                                 name="default_norm")
DEFAULT_ROPE = make_policy("rope", block_m=256, block_k=128,
                           name="default_rope")
