"""Kernel schedules (paper §3.3), TPU-adapted.

HipKittens identifies two schedules that reach peak on AMD — 8-WAVE PING-PONG
(two waves/SIMD alternating compute↔memory over *large* tiles) and 4-WAVE
INTERLEAVE (one wave/SIMD, fine-grained interleave over *small* tiles) — and
shows NVIDIA-style wave specialization (producer/consumer) loses because
producer waves consume statically-partitioned registers without computing.

On TPU a kernel runs on one compute core and overlap is *temporal*: the Pallas
grid pipeline multi-buffers operand blocks so iteration k's MXU work overlaps
iteration k+1's DMA. The three schedules map to pipeline/tile presets:

  PINGPONG         2 buffers/operand, large tiles   (default; ≈8-wave)
  INTERLEAVE       3 buffers/operand, small tiles   (deep pipeline; ≈4-wave)
  WAVE_SPECIALIZED 2 buffers + extra staging buffers that model the producer
                   VMEM tax — exists to *reproduce the paper's negative
                   result* (Tab. 2) in the analytic model: reserved staging
                   shrinks the feasible output tile and with it arithmetic
                   intensity.
"""
from __future__ import annotations

import dataclasses

from . import tiles


@dataclasses.dataclass(frozen=True)
class Schedule:
    name: str
    n_buffers: int                 # pipeline depth per operand
    block_m: int
    block_n: int
    block_k: int
    producer_fraction: float = 0.0  # VMEM fraction reserved for non-computing staging

    def vmem_budget(self) -> int:
        return int(tiles.VMEM_BYTES * (1.0 - self.producer_fraction))

    def operand_blocks(self, dtype_bytes: int = 2):
        return [((self.block_m, self.block_k), "bfloat16" if dtype_bytes == 2 else "float32"),
                ((self.block_k, self.block_n), "bfloat16" if dtype_bytes == 2 else "float32")]


# NOTE (TPU vs AMD): the v5e ridge point is 197e12/819e9 ≈ 240 FLOP/B and — in
# contrast to MI355X — there is no multi-MB cache raising effective bandwidth,
# so the paper's "maximize the output tile" principle is *more* extreme here:
# a 256x256 output tile (AI=128) is memory-bound; 512x512 (AI=256) is the
# smallest compute-bound square tile. PINGPONG therefore defaults to 512x512.
PINGPONG = Schedule("pingpong", n_buffers=2, block_m=512, block_n=512, block_k=512)
INTERLEAVE = Schedule("interleave", n_buffers=3, block_m=256, block_n=256, block_k=512)
WAVE_SPECIALIZED = Schedule("wave_specialized", n_buffers=2, block_m=256,
                            block_n=512, block_k=512, producer_fraction=0.33)

_SCHEDULES = {s.name: s for s in (PINGPONG, INTERLEAVE, WAVE_SPECIALIZED)}


def get_schedule(name: str) -> Schedule:
    if name not in _SCHEDULES:
        raise KeyError(f"unknown schedule {name!r}; have {sorted(_SCHEDULES)}")
    return _SCHEDULES[name]


def all_schedules():
    return list(_SCHEDULES.values())
