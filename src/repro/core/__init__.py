"""Core tile-programming primitives (the paper's contribution, TPU-adapted).

* :mod:`repro.core.tiles` — tile types + native-tiling legality + VMEM budget
* :mod:`repro.core.grid_swizzle` — Algorithm 1 (chiplet/cache-aware grid order)
* :mod:`repro.core.cache_model` — two-level cache simulator (Tab. 4 / Eq. 1)
* :mod:`repro.core.schedule` — PINGPONG / INTERLEAVE / WAVE_SPECIALIZED presets
* :mod:`repro.core.perf_model` — v5e roofline constants + analytic models
* :mod:`repro.core.policy` — KernelPolicy: schedule × swizzle × dtypes × legality
* :mod:`repro.core.autotune` — analytic policy autotuner + in-process cache
"""
from .tiles import TileSpec, native_tiling, is_aligned, block_spec  # noqa: F401
from .grid_swizzle import SwizzleConfig, ROW_MAJOR  # noqa: F401
from .schedule import Schedule, PINGPONG, INTERLEAVE, WAVE_SPECIALIZED, get_schedule  # noqa: F401
from .perf_model import V5E, ChipSpec, roofline, RooflineTerms  # noqa: F401
from .policy import KernelPolicy, make_policy  # noqa: F401
from .autotune import (OpSignature, candidate_policies, score_policy,  # noqa: F401
                       select_policy, policy_cache_stats, clear_policy_cache,
                       policies_for_model)
