"""Two-level cache simulator for grid schedules (paper Table 4 / Eq. 1).

The paper evaluates grid schedules by their L2 and LLC hit rates and combines
them into an effective bandwidth:

    BW = L2_bw * L2_hit% + LLC_bw * LLC_hit%            (Eq. 1, extended with
                                                         the HBM miss term)

We reproduce that evaluation with an explicit simulator: blocks are dispatched
round-robin across ``n_clusters`` (XCDs), each cluster owns a private LRU L2,
all clusters share an LRU LLC. A GEMM block (i, j) requests the A-row panel
tiles (i, k) and B-column panel tiles (k, j) for all k. The simulator reports
hit rates, Eq.-1 effective bandwidth, and a modeled kernel time — which is how
``benchmarks/bench_grid_swizzle.py`` scores SwizzleConfigs, mirroring Tab. 4.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from .grid_swizzle import SwizzleConfig, schedule_order


@dataclasses.dataclass(frozen=True)
class CacheHW:
    """Hardware model. Defaults follow the paper's MI355X description; the
    ``tpu_v5e`` constructor models a TPU pod slice where 'clusters' are chips,
    'L2' is per-chip CMEM/VMEM-resident reuse and 'LLC' is the neighbors'
    co-scheduled working set reachable before an HBM refetch."""

    n_clusters: int = 8
    executors_per_cluster: int = 32
    l2_bytes: int = 4 * 2**20
    llc_bytes: int = 256 * 2**20
    l2_bw: float = 52e12        # aggregate L2 bandwidth, B/s (≈3x LLC per paper)
    llc_bw: float = 17e12
    hbm_bw: float = 8e12
    peak_flops: float = 2.5e15  # BF16 matrix peak (MI355X)

    @staticmethod
    def tpu_v5e(n_chips: int = 16) -> "CacheHW":
        return CacheHW(n_clusters=n_chips, executors_per_cluster=1,
                       l2_bytes=100 * 2**20, llc_bytes=n_chips * 100 * 2**20,
                       l2_bw=n_chips * 4e12, llc_bw=n_chips * 0.4e12,
                       hbm_bw=n_chips * 819e9)


class _LRU:
    __slots__ = ("cap", "used", "store")

    def __init__(self, cap_bytes: int):
        self.cap = cap_bytes
        self.used = 0
        self.store: OrderedDict = OrderedDict()

    def access(self, key, nbytes: int) -> bool:
        """Touch ``key``; returns True on hit. Inserts (with eviction) on miss."""
        if key in self.store:
            self.store.move_to_end(key)
            return True
        while self.used + nbytes > self.cap and self.store:
            _, old = self.store.popitem(last=False)
            self.used -= old
        if nbytes <= self.cap:
            self.store[key] = nbytes
            self.used += nbytes
        return False


@dataclasses.dataclass
class SimResult:
    l2_hit: float
    llc_hit: float
    effective_bw: float
    total_bytes_requested: int
    hbm_bytes: int
    modeled_time_s: float
    modeled_tflops: float


def simulate_gemm_schedule(cfg: SwizzleConfig, *, m: int, n: int, k: int,
                           block_m: int, block_n: int, block_k: int,
                           dtype_bytes: int = 2,
                           hw: CacheHW = CacheHW()) -> SimResult:
    """Run the block schedule through the cache hierarchy (paper Tab. 4)."""
    num_rows, num_cols = m // block_m, n // block_n
    nk = max(1, k // block_k)
    order = schedule_order(cfg, num_rows, num_cols)

    a_tile = block_m * block_k * dtype_bytes
    b_tile = block_k * block_n * dtype_bytes

    l2s = [_LRU(hw.l2_bytes) for _ in range(hw.n_clusters)]
    llc = _LRU(hw.llc_bytes)

    n_exec = hw.n_clusters * hw.executors_per_cluster
    l2_hits = llc_hits = requests = 0
    hbm_bytes = 0
    total_bytes = 0

    nblocks = len(order)
    for start in range(0, nblocks, n_exec):
        wave = order[start:start + n_exec]
        # Executors in a wave run concurrently and advance their k-loops in
        # rough lockstep, so tile requests interleave k-step-by-k-step (this
        # is what makes same-row/col blocks on one cluster share panels).
        for kk in range(nk):
            # hardware dispatches round-robin across clusters (paper §3.4)
            for slot, (bi, bj) in enumerate(wave):
                cluster = slot % hw.n_clusters
                for key, nbytes in ((("A", int(bi), kk), a_tile),
                                    (("B", kk, int(bj)), b_tile)):
                    requests += 1
                    total_bytes += nbytes
                    if l2s[cluster].access(key, nbytes):
                        l2_hits += 1
                        continue
                    if llc.access(key, nbytes):
                        llc_hits += 1
                        continue
                    hbm_bytes += nbytes

    l2_rate = l2_hits / requests
    llc_rate = llc_hits / requests
    miss_rate = 1.0 - l2_rate - llc_rate
    eff_bw = hw.l2_bw * l2_rate + hw.llc_bw * llc_rate + hw.hbm_bw * miss_rate
    flops = 2.0 * m * n * k
    time_s = max(total_bytes / eff_bw, flops / hw.peak_flops)
    return SimResult(l2_rate, llc_rate, eff_bw, total_bytes, hbm_bytes,
                     time_s, flops / time_s / 1e12)


def sweep_schedules(m, n, k, block_m, block_n, block_k,
                    windows=(1, 4, 5, 7, 8), chunks=(8, 25, 64, 216),
                    hw: CacheHW = CacheHW()):
    """Sweep (W, C) like the paper's Tab. 4 and return scored configs."""
    results = []
    base = simulate_gemm_schedule(
        SwizzleConfig(enable_chiplet=False, enable_window=False),
        m=m, n=n, k=k, block_m=block_m, block_n=block_n, block_k=block_k, hw=hw)
    results.append(("row-major", base))
    for w in windows:
        for c in chunks:
            cfg = SwizzleConfig(window=w, chunk=c, n_xcd=hw.n_clusters)
            r = simulate_gemm_schedule(cfg, m=m, n=n, k=k, block_m=block_m,
                                       block_n=block_n, block_k=block_k, hw=hw)
            results.append((f"XCD(W{w}/C{c})", r))
    return results
