"""Measurement-grounded calibration of the analytic autotuner (DESIGN.md §15).

"Bringing Auto-tuning to HIP" (PAPERS.md) shows the measured optimum on AMD
routinely diverges from modeled rankings; KernelBench makes the same case for
grounding kernel claims in measurement. This module is the repo's empirical
layer over :mod:`repro.core.autotune`:

  1. **Measure** — :func:`calibrate` times ``candidate_policies(sig)`` per
     (op, shape-bucket, dtype, chain) cell. On real hardware the measurement
     is wall-clock (``measure_fn``); locally/CI it is the interpret-path
     proxy: a :class:`CalibrationRig` prices each candidate's proxy counters
     (MXU flops, vector ops, DMA bytes, grid steps — the geometry facts a
     hardware counter would report, extracted by :func:`policy_features`)
     with rig constants deliberately different from the analytic V5E
     defaults, while ``execute=True`` additionally runs each cell's winner
     once in interpret mode under ``obs.capture()`` so the journal carries
     real launches.
  2. **Fit** — :func:`fit_chip` recovers the :class:`~repro.core.perf_model.
     ChipSpec` coefficients (MXU/vector throughput, HBM bandwidth, per-step
     overhead) by least squares over the measured sweep, plus the decode
     ramp constant by 1-D search; deterministic under a fixed seed.
  3. **Persist** — the returned report IS a pretuned policy table
     (versioned JSON keyed shape-bucket×dtype×chain) that
     ``autotune.install_pretuned`` / ``load_pretuned`` consult ahead of the
     analytic ranking. ``tools/calibrate.py`` writes it;
     ``configs/pretuned/`` ships one per arch.
  4. **Gate** — :func:`check_drift` asserts the analytic and measured
     rankings agree (top-1 within tolerance, Spearman rank correlation per
     op family) so the model stays honest as kernels evolve;
     ``tools/drift_check.py`` wires it into CI.
"""
from __future__ import annotations

import dataclasses
import json
import math
import zlib
from typing import Callable, Iterable, Optional

import numpy as np

from repro import obs

from . import autotune
from . import perf_model as pm
from .autotune import OpSignature
from .policy import KernelPolicy, policy_spec

SCHEMA_VERSION = autotune.PRETUNED_SCHEMA_VERSION

_DTYPE_BYTES = autotune._DTYPE_BYTES


# ---------------------------------------------------------------------------
# Proxy counters: the geometry facts of one launch, model-independent.
# ---------------------------------------------------------------------------


def policy_features(sig: OpSignature, policy: KernelPolicy) -> dict:
    """Proxy counters for one (sig, policy) launch — what a hardware counter
    would report, derived purely from geometry (no chip constants):

      mxu_flops   bf16-equivalent MXU work, alignment-derated (so fp8/fp32
                  and ragged tiles cost what the systolic array charges)
      vector_ops  elementwise-unit work (softmax / fused-norm recompute)
      dma_bytes   HBM→VMEM traffic under the policy's traversal order
      grid_steps  Pallas grid steps (each pays the fixed pipeline cost)

    Decode cells also report ``kv_bytes``/``other_bytes`` split out, because
    the split-KV stream rides the saturation ramp while the combine traffic
    does not.
    """
    db = _DTYPE_BYTES.get(sig.dtype, 2)
    rel = pm.V5E.peak_flops(db) / pm.V5E.peak_flops_bf16  # dtype speed ratio

    if sig.op in ("gemm", "gemm_bwd"):
        m, n, k = sig.shape
        eff = pm.mxu_efficiency(policy.block_m, policy.block_n,
                                policy.block_k)
        n_acc = 2 if (policy.epilogue is not None
                      and getattr(policy.epilogue, "gate", False)) else 1
        flops = n_acc * 2.0 * m * n * k / (max(eff, 1e-9) * rel)
        vector = 0.0
        pro = policy.prologue
        if pro is not None and not getattr(pro, "is_identity", True):
            ops = 3.0 if getattr(pro, "precomputed_stats", False) else 8.0
            if sig.op == "gemm_bwd" and sig.variant == "da":
                vector = m * n * ops
            else:
                vector = (n // policy.block_n) * m * k * ops
        if sig.op == "gemm_bwd":
            traffic = autotune.gemm_bwd_traffic_bytes(policy, m, n, k, db,
                                                      sig.variant)
        else:
            traffic = autotune.gemm_traffic_bytes(policy, m, n, k, db)
        steps = (m // policy.block_m) * (n // policy.block_n)
        return dict(mxu_flops=flops, vector_ops=vector, dma_bytes=traffic,
                    grid_steps=steps)

    if sig.op in ("attention_fwd", "attention_bwd"):
        b, h, sq, skv, d = sig.shape
        kv_frac = 0.5 if sig.causal else 1.0
        flops = 4.0 * b * h * sq * skv * d * kv_frac / rel
        vector = 5.0 * b * h * sq * skv * kv_frac
        nq = sq // policy.block_q
        traffic = int(b * h * (nq * kv_frac * 2 * skv * d + 2 * sq * d) * db)
        if sig.op == "attention_bwd":
            flops *= 2.5
            traffic *= 2
        if policy.epilogue is not None:
            traffic += policy.epilogue.extra_read_bytes(h)
        steps = b * h * nq * (skv // policy.block_kv)
        return dict(mxu_flops=flops, vector_ops=vector, dma_bytes=traffic,
                    grid_steps=steps)

    if sig.op == "attention_decode":
        b, hkv, g, skv, d = sig.shape
        n_splits = max(1, skv // policy.block_kv)
        steps = b * hkv * n_splits
        kv_bytes = 2 * b * hkv * skv * d * db
        partial = b * hkv * n_splits * (g * d + 2 * g) * 4
        qo = 2 * b * hkv * g * d * db
        other = 2 * partial + qo
        return dict(mxu_flops=0.0, vector_ops=0.0,
                    dma_bytes=kv_bytes + other, grid_steps=steps,
                    kv_bytes=kv_bytes, other_bytes=other)

    if sig.op == "fused_norm":
        rows, d = sig.shape
        return dict(mxu_flops=0.0, vector_ops=0.0,
                    dma_bytes=4 * rows * d * db,
                    grid_steps=rows // policy.block_rows)

    if sig.op == "rope":
        b, h, s, d = sig.shape
        return dict(mxu_flops=0.0, vector_ops=0.0,
                    dma_bytes=b * h * s * d * (2 * db + 8),
                    grid_steps=b * h * (s // policy.block_rows))

    raise AssertionError(sig.op)


# ---------------------------------------------------------------------------
# The interpret-path measurement proxy.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CalibrationRig:
    """Deterministic stand-in hardware for the interpret path.

    Prices :func:`policy_features` with its own constants — deliberately
    *different* from the analytic V5E defaults (a slightly slower, more
    overhead-prone chip) so the calibration pipeline has real coefficients
    to recover and the drift gate compares two genuinely distinct models.
    ``jitter`` adds a seeded relative perturbation per (cell, candidate) —
    zero by default so shipped tables are reproducible bit-for-bit;
    non-zero values stay deterministic under a fixed ``seed`` (the noise is
    keyed by content hash, not by RNG call order).

    On real hardware none of this runs: pass ``measure_fn`` to
    :func:`calibrate` and candidates are wall-clock timed instead.
    """

    mxu_flops: float = 0.85 * 197e12
    vector_flops: float = 0.85 * 197e12 / 20.0
    hbm_bw: float = 0.9 * 819e9
    step_overhead_s: float = 1.3e-6
    decode_saturation_steps: int = 10
    jitter: float = 0.0
    seed: int = 0

    def time(self, sig: OpSignature, policy: KernelPolicy) -> float:
        f = policy_features(sig, policy)
        if sig.op == "attention_decode":
            util = min(1.0, f["grid_steps"] / self.decode_saturation_steps)
            t = (f["kv_bytes"] / (self.hbm_bw * util)
                 + f["other_bytes"] / self.hbm_bw
                 + f["grid_steps"] * self.step_overhead_s)
        else:
            compute = (f["mxu_flops"] / self.mxu_flops
                       + f["vector_ops"] / self.vector_flops)
            t = (max(compute, f["dma_bytes"] / self.hbm_bw)
                 + f["grid_steps"] * self.step_overhead_s)
        if self.jitter:
            key = (f"{self.seed}|{autotune.pretuned_cell_key(sig)}|"
                   f"{policy.block_m}x{policy.block_n}x{policy.block_k}"
                   f"b{policy.n_buffers}")
            u = (zlib.crc32(key.encode()) % 10000) / 10000.0 * 2.0 - 1.0
            t *= 1.0 + self.jitter * u
        return t

    def describe(self) -> dict:
        return {k: getattr(self, k) for k in
                ("mxu_flops", "vector_flops", "hbm_bw", "step_overhead_s",
                 "decode_saturation_steps", "jitter", "seed")}


def _execute_cell(sig: OpSignature, policy: KernelPolicy) -> int:
    """Run one launch of (sig, policy) in interpret mode under the active
    obs capture, so calibration journals REAL launches, not just modeled
    numbers. Returns the kernel-launch count observed. Function-level kernel
    imports keep repro.core free of a kernels dependency at import time."""
    import jax.numpy as jnp

    def zeros(shape, dtype=None):
        return jnp.zeros(shape, dtype or sig.dtype)

    with obs.capture() as rec:
        if sig.op == "gemm":
            m, n, k = sig.shape
            from repro.kernels.gemm.ops import gemm
            gemm(zeros((m, k)), zeros((k, n)), policy=policy
                 ).block_until_ready()
        elif sig.op == "attention_fwd":
            from repro.kernels.attention.ops import attention
            b, h, sq, skv, d = sig.shape
            attention(zeros((b, h, sq, d)), zeros((b, h, skv, d)),
                      zeros((b, h, skv, d)), causal=sig.causal,
                      policy=policy).block_until_ready()
        elif sig.op == "attention_decode":
            from repro.kernels.attention.ops import attention_decode
            b, hkv, g, skv, d = sig.shape
            attention_decode(zeros((b, hkv * g, 1, d)),
                             zeros((b, hkv, skv, d)),
                             zeros((b, hkv, skv, d)),
                             jnp.full((b,), skv, jnp.int32),
                             policy=policy).block_until_ready()
        elif sig.op == "rope":
            from repro.kernels.rope.ops import rope
            from repro.kernels.rope.ref import rope_tables
            b, h, s, d = sig.shape
            sin, cos = rope_tables(jnp.arange(s), d)
            rope(zeros((b, h, s, d)), sin, cos,
                 policy=policy).block_until_ready()
        else:
            return 0  # fused_norm / bwd launches: proxy-only cells
    n = sum(rec.launch_counts().values())
    obs.incr("calibrate.executed_launches", n)
    return n


# ---------------------------------------------------------------------------
# The calibration sweep.
# ---------------------------------------------------------------------------


def default_sweep(smoke: bool = False) -> list:
    """The bench-aligned cell set: one OpSignature per (op, shape, chain)
    cell the drift gate covers. ``smoke`` keeps the CI-sized subset."""
    from repro.kernels.gemm.epilogue import Epilogue

    cells = [
        OpSignature("gemm", (512, 512, 512)),
        OpSignature("gemm", (1024, 1024, 1024)),
        OpSignature("gemm", (1024, 2048, 1024),
                    epilogue=Epilogue(activation="silu", gate=True)),
        OpSignature("gemm", (1024, 1024, 2048),
                    epilogue=Epilogue(residual=True, scale=True)),
        OpSignature("attention_fwd", (1, 4, 512, 512, 64), causal=True),
        OpSignature("attention_decode", (4, 2, 4, 1024, 64)),
        OpSignature("fused_norm", (2048, 1024), dtype="float32"),
        OpSignature("rope", (1, 4, 512, 64), dtype="float32"),
    ]
    if not smoke:
        cells += [
            OpSignature("gemm", (2048, 2048, 1024)),
            OpSignature("gemm", (4096, 4096, 2048)),
            OpSignature("attention_fwd", (1, 4, 1024, 1024, 128),
                        causal=True),
            OpSignature("attention_fwd", (2, 8, 512, 512, 64), causal=False),
            OpSignature("attention_decode", (8, 4, 4, 2048, 128)),
            OpSignature("fused_norm", (4096, 2048), dtype="float32"),
            OpSignature("rope", (2, 8, 1024, 128), dtype="float32"),
        ]
    return cells


def _shard_cells():
    """Sharded fusion cells (DESIGN.md §16) — built lazily so core only
    touches repro.distributed when a calibration actually runs."""
    from repro.distributed.sharding import ShardSpec
    ep = ShardSpec(mesh=(("model", 4),), partition=(("expert", "model"),),
                   collective="all_to_all")
    tp = ShardSpec(mesh=(("model", 4),), partition=(("ffn", "model"),),
                   collective="all_reduce")
    ring = ShardSpec(mesh=(("model", 4),), partition=(("rows", "model"),),
                     collective="all_gather")
    return [
        ("mlp", (4096, 2048, 8192, 1), dict(residual=False, shard=ep)),
        ("mlp", (4096, 2048, 2048, 1), dict(residual=False, shard=tp)),
        ("gemm_collective", (4096, 4096, 4096), dict(shard=ring)),
    ]


_FUSION_CELLS = [
    # (kind, shape, kwargs) — the chain-plan decisions worth pinning
    ("mlp", (4096, 2048, 8192, 1), dict(prenorm="rmsnorm")),
    ("mlp", (4096, 2048, 8192, 1), dict(prenorm="rmsnorm", backward=True)),
    ("qkv_rope", (4096, 2048, 16, 4, 128), dict(prenorm="rmsnorm")),
    ("attention", (1, 16, 4, 1024, 1024, 128), dict(causal=True)),
]

def _cell_is_executable(sig: OpSignature) -> bool:
    """Cells cheap enough to run in CPU interpret mode for launch
    journaling when ``execute=True`` (per-op work caps, not one element
    count — a 256^3 gemm and a 4k-seq attention cost very differently)."""
    if sig.op == "gemm":
        if sig.epilogue is not None or sig.prologue is not None:
            return False  # chain operands (b2/scale/...) need model tensors
        m, n, k = sig.shape
        return m * n * k <= 2 ** 25
    if sig.op == "attention_fwd":
        b, h, sq, skv, _ = sig.shape
        return b * h * sq * skv <= 2 ** 22
    if sig.op == "attention_decode":
        b, hkv, _, skv, d = sig.shape
        return b * hkv * skv * d <= 2 ** 22
    if sig.op == "rope":
        return math.prod(sig.shape) <= 2 ** 21
    return False


# ---------------------------------------------------------------------------
# Coefficient fitting.
# ---------------------------------------------------------------------------


def fit_chip(samples: list, decode_samples: list, *,
             arch: str = "cpu") -> tuple:
    """Least-squares fit of the ChipSpec coefficients from measurements.

    ``samples``: (features, time_s) pairs of non-decode cells. The linear
    model t ≈ F/peak + V/vec + B/bw + S*step is fit by ``numpy.lstsq`` over
    the whole sweep; each recovered coefficient falls back to the analytic
    default when the sweep doesn't constrain it (column identically zero or
    a non-physical negative estimate). ``decode_samples``: (features,
    time_s) of decode cells; the saturation ramp is recovered by 1-D search
    (the ramp enters through min(1, steps/ramp) — not linear, so lstsq
    can't see it). Deterministic: pure numpy on sorted inputs.

    Returns (chip_coefficients_dict, fit_info_dict).
    """
    defaults = dict(peak_flops_bf16=pm.V5E.peak_flops_bf16,
                    vector_flops=pm.V5E.peak_flops_bf16 / 16,
                    hbm_bw=pm.V5E.hbm_bw,
                    step_overhead_s=1e-6,
                    decode_saturation_steps=pm.DECODE_SATURATION_STEPS)
    info: dict = {"n_samples": len(samples),
                  "n_decode_samples": len(decode_samples)}
    out = dict(defaults)
    if samples:
        a = np.array([[f["mxu_flops"], f["vector_ops"], f["dma_bytes"],
                       f["grid_steps"]] for f, _ in samples])
        t = np.array([v for _, v in samples])
        # column scaling keeps lstsq well-conditioned across ~1e12 ranges
        scale = np.where(np.abs(a).max(axis=0) > 0, np.abs(a).max(axis=0), 1)
        coef, residual, *_ = np.linalg.lstsq(a / scale, t, rcond=None)
        coef = coef / scale
        info["lstsq_residual"] = float(residual[0]) if len(residual) else 0.0
        names = ("peak_flops_bf16", "vector_flops", "hbm_bw",
                 "step_overhead_s")
        for i, name in enumerate(names):
            c = float(coef[i])
            constrained = bool(np.abs(a[:, i]).max() > 0)
            if not constrained or c <= 0:
                info[f"{name}_fallback"] = True
                continue
            out[name] = c if name == "step_overhead_s" else 1.0 / c
    if decode_samples:
        best = (math.inf, defaults["decode_saturation_steps"])
        for ramp in range(1, 33):
            sse = 0.0
            for f, v in decode_samples:
                util = min(1.0, f["grid_steps"] / ramp)
                pred = (f["kv_bytes"] / (out["hbm_bw"] * util)
                        + f["other_bytes"] / out["hbm_bw"]
                        + f["grid_steps"] * out["step_overhead_s"])
                sse += (pred - v) ** 2
            if sse < best[0]:
                best = (sse, ramp)
        out["decode_saturation_steps"] = best[1]
        info["decode_ramp_sse"] = best[0]
    out["name"] = f"{arch}_calibrated"
    return out, info


# ---------------------------------------------------------------------------
# The calibration run.
# ---------------------------------------------------------------------------


def _default_arch() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover
        return "cpu"


def calibrate(cells: Optional[Iterable[OpSignature]] = None, *,
              rig: Optional[CalibrationRig] = None,
              measure_fn: Optional[Callable] = None,
              execute: bool = False, smoke: bool = False,
              top_k: int = 12, seed: int = 0,
              arch: Optional[str] = None) -> dict:
    """Run the measurement sweep and return the pretuned-table report.

    Per cell: enumerate ``candidate_policies``, keep the ``top_k`` by
    analytic rank (the analytic winner is always candidate 0, so agreement
    is measured where it matters), measure each — ``measure_fn(sig,
    policy) -> seconds`` on real hardware, else the :class:`CalibrationRig`
    proxy — and pin the measured winner. Fusion-plan cells are scored once
    (the plan choice is byte-model-driven and chip-independent) and pinned
    verbatim. Coefficients are fit over the full sweep. The returned dict
    is both the drift-check report and the installable pretuned table.
    """
    arch = arch or _default_arch()
    rig = rig or CalibrationRig(seed=seed)
    measure = measure_fn or rig.time
    cells = list(cells) if cells is not None else default_sweep(smoke=smoke)

    report: dict = {"schema_version": SCHEMA_VERSION, "arch": arch,
                    "seed": seed, "rig": rig.describe(),
                    "cells": {}, "fusion": {}}
    samples: list = []
    decode_samples: list = []
    for sig in sorted(cells, key=lambda s: autotune.pretuned_cell_key(s)):
        cands = autotune.candidate_policies(sig)
        if not cands:
            continue
        scored = sorted(
            ((autotune.score_policy(sig, p, pm.V5E), p) for p in cands),
            key=lambda sp: sp[0].rank_key(sp[1]))[:top_k]
        rows = []
        for score, pol in scored:
            t = float(measure(sig, pol))
            feats = policy_features(sig, pol)
            rows.append({"blocks": [pol.block_m, pol.block_n, pol.block_k],
                         "n_buffers": pol.n_buffers,
                         "schedule": pol.schedule.name,
                         "spec": policy_spec(pol),
                         "measured_time_s": t,
                         "analytic_time_s": score.time_s,
                         "dma_bytes": score.dma_bytes})
            if sig.op == "attention_decode":
                decode_samples.append((feats, t))
            else:
                samples.append((feats, t))
        win_i = min(range(len(rows)),
                    key=lambda i: (rows[i]["measured_time_s"],
                                   rows[i]["analytic_time_s"], i))
        winner = scored[win_i][1]
        key = autotune.pretuned_cell_key(sig)
        cell = {"sig": sig_to_json(sig),
                "policy": rows[win_i]["spec"],
                "measured_time_s": rows[win_i]["measured_time_s"],
                "analytic_time_s": rows[win_i]["analytic_time_s"],
                "analytic_best_time_s": rows[0]["analytic_time_s"],
                "candidates": [{k2: v for k2, v in r.items() if k2 != "spec"}
                               for r in rows]}
        if execute and _cell_is_executable(sig):
            cell["executed_launches"] = _execute_cell(sig, winner)
        report["cells"][key] = cell
        obs.incr("calibrate.cells")

    for kind, shape, kw in _FUSION_CELLS + _shard_cells():
        tokens = 1 << max(0, (shape[0] - 1).bit_length())
        plan = autotune.select_fusion(kind, shape, "bfloat16",
                                      chip=pm.V5E, **kw)
        fkey = autotune.pretuned_fusion_key(
            kind, (tokens,) + tuple(shape[1:]), "bfloat16",
            residual=kw.get("residual", True),
            prenorm=kw.get("prenorm", "none"),
            backward=kw.get("backward", False),
            causal=kw.get("causal", False),
            softcap=kw.get("softcap", False), sink=kw.get("sink", False),
            shard=kw.get("shard"))
        report["fusion"][fkey] = {
            "kind": kind, "shape": list(shape),
            "kwargs": {k2: (autotune._shard_str(v) if k2 == "shard" else v)
                       for k2, v in kw.items()},
            "plan": {k2: v for k2, v in plan.items()
                     if k2 not in ("fused", "unfused")}}

    chip, fit_info = fit_chip(sorted(samples, key=lambda s: s[1]),
                              sorted(decode_samples, key=lambda s: s[1]),
                              arch=arch)
    report["chip"] = chip
    report["fit"] = fit_info
    return report


def sig_to_json(sig: OpSignature) -> dict:
    return {"op": sig.op, "shape": list(sig.shape), "dtype": sig.dtype,
            "causal": sig.causal,
            "epilogue": autotune._chain_str(sig.epilogue),
            "prologue": autotune._chain_str(sig.prologue),
            "variant": sig.variant,
            "shard": autotune._shard_str(sig.shard)}


def save_report(report: dict, path) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")


# ---------------------------------------------------------------------------
# The drift gate.
# ---------------------------------------------------------------------------


def spearman(xs, ys) -> float:
    """Spearman rank correlation with average-rank tie handling."""
    def ranks(v):
        v = np.asarray(v, dtype=float)
        order = np.argsort(v, kind="stable")
        r = np.empty(len(v))
        r[order] = np.arange(len(v), dtype=float)
        # average tied ranks
        for val in np.unique(v):
            mask = v == val
            r[mask] = r[mask].mean()
        return r

    rx, ry = ranks(xs), ranks(ys)
    sx, sy = rx.std(), ry.std()
    if sx == 0 or sy == 0:
        return 1.0  # all-tied rankings can't disagree
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))


def check_drift(report: dict, *, top1_tol: float = 0.05,
                min_spearman: float = 0.8) -> dict:
    """Does the analytic ranking agree with the measured one?

    Per cell: the measured winner's *analytic* time must be within
    ``top1_tol`` of the analytic best (a tolerant top-1 so modeled
    near-ties can't flap the gate). Per op family: the mean per-cell
    Spearman rank correlation over measured candidates must reach
    ``min_spearman``. Pure JSON math — re-runs on any saved report.

    Returns {ok, n_cells, families: {op: {cells, top1_agreement,
    mean_spearman}}, violations: [str, ...]}.
    """
    fams: dict = {}
    violations = []
    for key, cell in sorted(report.get("cells", {}).items()):
        op = cell["sig"]["op"]
        f = fams.setdefault(op, {"cells": 0, "top1_ok": 0, "rhos": []})
        f["cells"] += 1
        cands = cell["candidates"]
        analytic = [c["analytic_time_s"] for c in cands]
        measured = [c["measured_time_s"] for c in cands]
        best_analytic = min(analytic)
        win_i = min(range(len(cands)),
                    key=lambda i: (measured[i], analytic[i], i))
        if analytic[win_i] <= (1.0 + top1_tol) * best_analytic:
            f["top1_ok"] += 1
        else:
            violations.append(
                f"{key}: measured winner blocks="
                f"{cands[win_i]['blocks']} has analytic time "
                f"{analytic[win_i]:.3e}s vs best {best_analytic:.3e}s "
                f"(> {1 + top1_tol:.2f}x)")
        if len(cands) >= 3:
            f["rhos"].append(spearman(measured, analytic))
    families = {}
    for op, f in sorted(fams.items()):
        agree = f["top1_ok"] / f["cells"]
        rho = (sum(f["rhos"]) / len(f["rhos"])) if f["rhos"] else 1.0
        families[op] = {"cells": f["cells"], "top1_agreement": agree,
                        "mean_spearman": rho}
        if agree < 1.0:
            pass  # the per-cell violation above already names the cell
        if rho < min_spearman:
            violations.append(
                f"family {op}: mean Spearman {rho:.3f} < {min_spearman}")
    return {"ok": not violations, "n_cells": sum(f["cells"]
                                                 for f in fams.values()),
            "top1_tol": top1_tol, "min_spearman": min_spearman,
            "families": families, "violations": violations}
