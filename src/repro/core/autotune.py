"""Analytic policy autotuner (paper §3.3-3.4; Tab. 2-4 models as the cost fn).

Lurati et al. ("Bringing Auto-tuning to HIP", 2024) show that most of the
AMD-vs-baseline gap lives in tuning-parameter search; HipKittens' answer is a
small, structured search space (schedule × tile × traversal). This module is
that search, run against the repo's *analytic* models instead of hardware:

  1. :func:`candidate_policies` enumerates every VMEM-legal
     :class:`~repro.core.policy.KernelPolicy` whose blocks tile the problem
     shape (divisibility + native alignment via the Schedule blocks);
  2. :func:`score_policy` ranks a candidate with the existing models —
     ``perf_model.gemm_step_model`` / ``attention_step_model`` for pipeline
     time, ``grid_swizzle.dma_bytes`` for the Pallas-revisit HBM traffic of
     its traversal order (and optionally ``cache_model.simulate_gemm_schedule``
     for the multi-executor hierarchy, see :func:`refine_with_cache_model`);
  3. :func:`select_policy` memoizes the winner in an in-process cache keyed by
     (kernel kind, shape-bucket, dtype) so model tracing re-resolves for free.

Deterministic by construction: candidates are scored with pure functions and
ties break on (modeled time, modeled DMA bytes, policy key).

See DESIGN.md §5 for where this sits in the policy resolution order.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

from repro import obs

from . import perf_model as pm
from . import tiles
from .grid_swizzle import ROW_MAJOR, SwizzleConfig, dma_bytes
from .policy import KernelPolicy, OP_KINDS, make_policy, policy_from_spec
from .schedule import Schedule

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1,
                "float8_e4m3fn": 1, "float8_e5m2": 1}

# The per-grid-step fixed cost and the vector-unit throughput both live on
# ChipSpec now (calibratable, DESIGN.md §15): chip.step_overhead_s models the
# pipeline bubble / bookkeeping of a Pallas grid step (only its *relative*
# effect matters: it breaks ties toward fewer, larger blocks for memory-bound
# 1-D ops); chip.vector_throughput() prices softmax/norm vector work.


@dataclasses.dataclass(frozen=True)
class OpSignature:
    """What the autotuner needs to know about one kernel launch.

    ``shape`` per op kind:
      gemm             (m, n, k)
      attention_fwd    (batch, heads, seq_q, seq_kv, head_dim)
      attention_bwd    (batch, heads, seq_q, seq_kv, head_dim)
      attention_decode (batch, kv_heads, group, kv_len, head_dim)
      fused_norm       (rows, d)
      rope             (batch, heads, seq, head_dim)

    ``epilogue`` is the fused store chain the launch will run, carried
    opaquely. For gemm/gemm_bwd it is a
    :class:`repro.kernels.gemm.epilogue.Epilogue`: its extra operands
    change both the legal candidate set (VMEM, whole-head block_n for
    rope) and the scored traffic. For the attention ops it is an
    :class:`repro.kernels.attention.epilogue.AttnEpilogue` (softcap /
    attention-sink stages inside the online-softmax loop and store):
    stateless on the candidate set beyond the tiny sink-operand VMEM
    charge, but its streamed sink row adds to the scored traffic and it
    rides the returned policy into the kernels.
    ``prologue`` (gemm/gemm_bwd only) is the fused A-operand chain
    (:class:`repro.kernels.gemm.prologue.Prologue`)
    — a recompute-path norm prologue pins block_k to the full feature dim
    and charges the per-A-tile norm recompute to the compute term.

    ``variant`` (gemm_bwd only) names which bwd launch of the fused
    backward (DESIGN.md §11) this is: ``'da'`` (shape (M, K, N) — out dA,
    contraction over N) or ``'db'`` (shape (K, N, M) — out dB[, dB2],
    contraction over M). The chains pin different dims per variant: a norm
    prologue pins dA's out-column block to full K (its row reductions need
    whole feature rows) and — on the recompute stats path — dB's out-row
    block to full K (the streamed A tile spans whole rows, the fwd rule);
    a rope epilogue pins the dim its g tiles rotate along to whole heads
    (dA: the contraction block; dB: the out-column block).

    ``shard`` (DESIGN.md §16) is the launch's
    :class:`repro.distributed.sharding.ShardSpec` — mesh axes × operand
    partition × collective — carried opaquely like the chains. It joins
    the bucket so a sharded launch never shares a memo cell with its
    single-device twin (the candidate set is the same — per-rank local
    shapes are what's scored — but the plan audit and pretuned tables key
    on it).
    """

    op: str
    shape: tuple
    dtype: str = "bfloat16"
    causal: bool = False
    epilogue: Optional[object] = None
    prologue: Optional[object] = None
    variant: str = ""
    shard: Optional[object] = None

    def __post_init__(self):
        if self.op not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.op!r}")
        if self.op == "gemm_bwd" and self.variant not in ("da", "db"):
            raise ValueError(f"gemm_bwd needs variant 'da' or 'db', "
                             f"got {self.variant!r}")
        if self.variant and self.op != "gemm_bwd":
            raise ValueError("variant is only meaningful for gemm_bwd")

    def bucket(self) -> tuple:
        """Policy-cache key. Tile-constrained dims stay exact (a block must
        divide them); pure batch-like dims round up to the next power of two
        so e.g. batch 48 and 64 share one compiled bucket."""
        def pow2(x: int) -> int:
            return 1 << max(0, (x - 1).bit_length())

        if self.op in ("attention_fwd", "attention_bwd"):
            b, h, sq, skv, d = self.shape
            shape = (pow2(b), pow2(h), sq, skv, d)
        elif self.op == "attention_decode":
            # kv_len stays exact (the split size must divide it); batch and
            # kv_heads are batch-like; group is tiny and kept exact (it is
            # the q-tile row count).
            b, hkv, g, skv, d = self.shape
            shape = (pow2(b), pow2(hkv), g, skv, d)
        elif self.op == "rope":
            b, h, s, d = self.shape
            shape = (pow2(b), pow2(h), s, d)
        else:
            shape = tuple(self.shape)
        return (self.op, shape, self.dtype, self.causal, self.epilogue,
                self.prologue, self.variant, self.shard)


@dataclasses.dataclass(frozen=True)
class PolicyScore:
    time_s: float        # modeled wall time of the whole op (lower is better)
    dma_bytes: int       # modeled HBM→VMEM traffic under the traversal order
    detail: tuple = ()   # (key, value) pairs for reports

    def rank_key(self, policy: KernelPolicy) -> tuple:
        return (self.time_s, self.dma_bytes, repr(policy.cache_key()))


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def _block_candidates(dim: int, align: int, cap: int) -> list:
    """Aligned divisors of ``dim`` up to ``cap``; always non-empty.

    When no aligned divisor exists (dim itself unaligned), falls back to the
    whole dim / largest divisor — the kernels accept those because a block
    covering an unaligned problem dim pads exactly once (the same padding
    the pre-policy raw BlockSpecs produced); see tiles.block_spec callers.
    """
    cands = [b for b in range(align, min(dim, cap) + 1, align) if dim % b == 0]
    if dim <= cap and dim not in cands:
        cands.append(dim)  # the whole dim always tiles itself
    if not cands:
        cands = [max(b for b in range(1, cap + 1) if dim % b == 0)]
    return sorted(set(cands))


def _sublane(dtype: str) -> int:
    return tiles.native_tiling(
        dtype if dtype in _DTYPE_BYTES else "bfloat16")[0]


def _swizzle_candidates(num_rows: int, num_cols: int) -> list:
    """Traversal orders worth scoring for a 2-D block grid: row-major plus
    Algorithm-1 windows (chiplet step off — single-core Pallas use)."""
    cands = [ROW_MAJOR]
    seen = set()
    for w in (2, 4, 8, num_rows):
        if 1 < w <= num_rows and w not in seen:
            seen.add(w)
            cands.append(SwizzleConfig(window=w, enable_chiplet=False))
    return cands


def _head_multiple_candidates(dim: int, hd: int, base: list) -> list:
    """Restrict block candidates to head_dim multiples (rope's whole-head
    rule), unioning head_dim-aligned divisors for non-128-aligned heads.
    Lane-aligned multiples are preferred when any exist (a 64-wide tile on
    an aligned problem dim would trip tiles.block_spec's strict gate)."""
    cands = sorted(b for b in
                   set(base) | set(_block_candidates(dim, hd, 512))
                   if b % hd == 0)
    aligned = [b for b in cands if b % tiles.LANE == 0]
    return aligned or cands


def candidate_policies(sig: OpSignature,
                       swizzle: Optional[SwizzleConfig] = None) -> list:
    """Every legal candidate for ``sig``: blocks tile the shape AND the
    pipelined working set fits VMEM (Tab. 2's feasibility rule).

    ``swizzle`` restricts the traversal-order axis of the search to one
    requested SwizzleConfig (the legacy ``gemm(swizzle=...)`` shim and the
    bwd launches, which pin the fwd policy's traversal, use this) — block
    and pipeline-depth candidates are still fully enumerated.
    """
    dtype = "bfloat16" if sig.dtype not in _DTYPE_BYTES else sig.dtype
    out = []

    def swizzles(rows, cols):
        return [swizzle] if swizzle is not None else \
            _swizzle_candidates(rows, cols)

    if sig.op in ("gemm", "gemm_bwd"):
        m, n, k = sig.shape
        ep = sig.epilogue
        pro = sig.prologue
        bm_cands = _block_candidates(m, 128, 512)
        bn_cands = _block_candidates(n, 128, 512)
        bk_cands = _block_candidates(k, 128, 512)
        has_rope = ep is not None and getattr(ep, "rope", False)
        has_pro = pro is not None and not getattr(pro, "is_identity", True)
        if sig.op == "gemm":
            if has_rope:
                # rope rotates whole heads per tile: block_n must be a
                # head_dim multiple (head_dim-aligned divisors cover
                # non-128-aligned heads)
                bn_cands = _head_multiple_candidates(n, ep.head_dim, bn_cands)
            if pro is not None and getattr(pro, "needs_full_k", False):
                # recompute-path norm prologue: row stats come from the A
                # tile itself, so the tile must span the full feature dim
                bk_cands = [k]
        elif sig.variant == "da":
            if has_rope:  # g tiles rotate along the contraction (N) dim
                bk_cands = _head_multiple_candidates(k, ep.head_dim, bk_cands)
            if has_pro:   # norm-transpose row reductions span full K
                bn_cands = [n]
        else:  # 'db'
            if has_rope:  # g tiles rotate along the output-column (N) dim
                bn_cands = _head_multiple_candidates(n, ep.head_dim, bn_cands)
            if pro is not None and getattr(pro, "needs_full_k", False):
                bm_cands = [m]  # streamed A tiles span whole feature rows
        for bm in bm_cands:
            for bn in bn_cands:
                for bk in bk_cands:
                    for nbuf in (2, 3):
                        sched = Schedule(f"auto_g{nbuf}", nbuf, bm, bn, bk)
                        rows, cols = m // bm, n // bn
                        for sw in swizzles(rows, cols):
                            pol = KernelPolicy(sig.op, sched, sw,
                                               in_dtype=dtype, epilogue=ep,
                                               prologue=pro)
                            if pol.is_legal():
                                out.append(pol)

    elif sig.op in ("attention_fwd", "attention_bwd"):
        b, h, sq, skv, d = sig.shape
        for bq in _block_candidates(sq, 128, 512):
            for bkv in _block_candidates(skv, 128, 512):
                sched = Schedule("auto_a", 2, bq, bkv, d)
                pol = KernelPolicy(sig.op, sched, ROW_MAJOR, in_dtype=dtype,
                                   epilogue=sig.epilogue)
                if pol.is_legal():
                    out.append(pol)

    elif sig.op == "attention_decode":
        b, hkv, g, skv, d = sig.shape
        # block_n is the KV-split size: one split per grid step. The q tile
        # holds the packed GQA group (block_m = group; tiny, Pallas pads it).
        for bkv in _block_candidates(skv, _sublane(dtype), 2048):
            pol = make_policy("attention_decode", block_m=g, block_n=bkv,
                              block_k=d, in_dtype=dtype, name="auto_d",
                              epilogue=sig.epilogue)
            if pol.is_legal():
                out.append(pol)

    elif sig.op == "fused_norm":
        rows, d = sig.shape
        for br in _block_candidates(rows, _sublane(dtype), 1024):
            pol = make_policy("fused_norm", block_m=br, block_k=d,
                              in_dtype=dtype, name="auto_n")
            if pol.is_legal():
                out.append(pol)

    elif sig.op == "rope":
        b, h, s, d = sig.shape
        for bs in _block_candidates(s, _sublane(dtype), 1024):
            pol = make_policy("rope", block_m=bs, block_k=d,
                              in_dtype=dtype, name="auto_r")
            if pol.is_legal():
                out.append(pol)

    return out


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------

def gemm_traffic_bytes(policy: KernelPolicy, m: int, n: int, k: int,
                       dtype_bytes: int) -> int:
    """Modeled HBM→VMEM bytes of the full GEMM under the policy's traversal
    (full-K panels, Pallas consecutive-revisit rule — grid_swizzle.dma_bytes).

    An attached epilogue adds its streamed operands: the gate's B2 panel
    follows B's revisit pattern exactly (doubled B traffic), the rest
    (bias/residual/tables) stream once with the output tiles. An attached
    prologue adds its gamma/beta rows and fast-path stats columns — the
    *eliminated* normed-activation round trip is chain-model territory
    (perf_model), not this per-launch count.
    """
    rows, cols = m // policy.block_m, n // policy.block_n
    a_panel = policy.block_m * k * dtype_bytes
    b_panel = k * policy.block_n * dtype_bytes
    ep = policy.epilogue
    if ep is not None and getattr(ep, "gate", False):
        b_panel *= 2
    traffic = dma_bytes(policy.swizzle, rows, cols, a_panel, b_panel)
    if ep is not None:
        traffic += ep.extra_read_bytes(m, n, dtype_bytes)
    pro = policy.prologue
    if pro is not None:
        traffic += pro.extra_read_bytes(m, k, dtype_bytes)
    return traffic


def gemm_bwd_traffic_bytes(policy: KernelPolicy, m: int, n: int, k: int,
                           dtype_bytes: int, variant: str) -> int:
    """Modeled HBM→VMEM bytes of one fused-backward launch (DESIGN.md §11).

    The launch is a GEMM of its own (m, n, k) shape under the policy's
    traversal, with the chain's extra streams on top: the saved
    preactivations ride the cotangent panel (the g-side operand — the A
    side for dA, the B side for dB) in the MXU input dtype; the dual-GEMM
    gate doubles the *weight* panel for dA (B and B2 both stream) and costs
    dB nothing extra on reads (dB2 shares the same A and g streams); a norm
    prologue adds the raw-A reads for the tile-wise norm transpose (dA: one
    (M, K) pass with the output tiles; dB: the A panel IS the primal
    operand) plus the gamma/beta/stats rows.
    """
    rows, cols = m // policy.block_m, n // policy.block_n
    a_panel = policy.block_m * k * dtype_bytes
    b_panel = k * policy.block_n * dtype_bytes
    ep = policy.epilogue
    pro = policy.prologue
    n_saved = getattr(ep, "saved_accumulators", 0) if ep is not None else 0
    # scale chains save fp32 preacts (Epilogue.preact_keeps_f32)
    p_bytes = 4 if (ep is not None and getattr(ep, "preact_keeps_f32",
                                               False)) else dtype_bytes
    extra = 0
    if variant == "da":
        a_panel += policy.block_m * k * p_bytes * n_saved      # preacts
        if ep is not None and getattr(ep, "gate", False):
            b_panel *= 2                                       # B and B2
        if pro is not None and not getattr(pro, "is_identity", True):
            extra += m * n * dtype_bytes   # raw A, once per output tile
            extra += pro.extra_read_bytes(m, n, dtype_bytes)
    else:  # 'db'
        b_panel += k * policy.block_n * p_bytes * n_saved      # preacts
        if pro is not None and not getattr(pro, "is_identity", True):
            extra += pro.extra_read_bytes(k, m, dtype_bytes)
    traffic = dma_bytes(policy.swizzle, rows, cols, a_panel, b_panel) + extra
    if ep is not None:
        # bias/scale/table streams are read by the transpose like the fwd
        # store read them — over the *forward* (M, N) dims, which the
        # launch shape encodes per variant: da is (M, K, N), db is
        # (K, N, M). (dresidual is the identity — no stream.)
        fwd_m, fwd_n = (m, k) if variant == "da" else (k, n)
        streams = ep.extra_read_bytes(fwd_m, fwd_n, dtype_bytes)
        if getattr(ep, "residual", False):
            streams -= fwd_m * fwd_n * dtype_bytes
        traffic += streams
    return traffic


def score_policy(sig: OpSignature, policy: KernelPolicy,
                 chip: pm.ChipSpec = pm.V5E) -> PolicyScore:
    dtype_bytes = _DTYPE_BYTES.get(sig.dtype, 2)

    if sig.op in ("gemm", "gemm_bwd"):
        m, n, k = sig.shape
        step = pm.gemm_step_model(policy.schedule, k_total=k,
                                  dtype_bytes=dtype_bytes, chip=chip)
        if not step["feasible"]:
            return PolicyScore(math.inf, 2**62)
        n_blocks = (m // policy.block_m) * (n // policy.block_n)
        tflops = step["modeled_tflops"]
        n_acc = 2 if (policy.epilogue is not None
                      and getattr(policy.epilogue, "gate", False)) else 1
        compute_s = (n_acc * 2.0 * m * n * k / (tflops * 1e12)
                     if tflops else math.inf)
        pro = policy.prologue
        if pro is not None and not getattr(pro, "is_identity", True):
            # per-A-tile norm work: each A panel is re-processed once per
            # output-column block it is revisited for — vector-unit work
            # bought against the eliminated HBM round trip. The recompute
            # path re-derives row stats (~8 ops/element); the
            # precomputed-stats fast path only applies the affine transform
            # (~3 ops/element, stats streamed). The bwd launches pay the
            # same per-tile rate: dB renorms its A stream once per
            # output-column visit like the fwd; dA runs the norm transpose
            # exactly once per full-K store tile — M*K elements total, no
            # revisit factor (its out-column block is pinned to K).
            ops = 3.0 if getattr(pro, "precomputed_stats", False) else 8.0
            if sig.op == "gemm_bwd" and sig.variant == "da":
                norm_elems = m * n          # the (M, K) store tiles, once
            else:
                norm_elems = (n // policy.block_n) * m * k
            compute_s += norm_elems * ops / chip.vector_throughput()
        if sig.op == "gemm_bwd":
            traffic = gemm_bwd_traffic_bytes(policy, m, n, k, dtype_bytes,
                                             sig.variant)
        else:
            traffic = gemm_traffic_bytes(policy, m, n, k, dtype_bytes)
        memory_s = traffic / chip.hbm_bw
        time_s = max(compute_s, memory_s) + n_blocks * chip.step_overhead_s
        return PolicyScore(time_s, traffic,
                           (("bound", step["bound"]),
                            ("ai", round(step["arithmetic_intensity"], 1))))

    if sig.op in ("attention_fwd", "attention_bwd"):
        b, h, sq, skv, d = sig.shape
        step = pm.attention_step_model(
            block_q=policy.block_q, block_kv=policy.block_kv, head_dim=d,
            seq_len=skv, causal=sig.causal, dtype_bytes=dtype_bytes, chip=chip)
        nq = sq // policy.block_q
        useful = 4.0 * b * h * sq * skv * d * (0.5 if sig.causal else 1.0)
        tflops = step["modeled_tflops"]
        time_s = useful / (tflops * 1e12) if tflops else math.inf
        # K/V are re-streamed once per q block; q/o stream once.
        kv_frac = (0.5 if sig.causal else 1.0)
        traffic = int(b * h * (nq * kv_frac * 2 * skv * d
                               + 2 * sq * d) * dtype_bytes)
        if sig.op == "attention_bwd":
            time_s *= 2.5   # dq + dkv passes re-read everything
            traffic *= 2
        if policy.epilogue is not None:
            traffic += policy.epilogue.extra_read_bytes(h)
        time_s += b * h * nq * (skv // policy.block_kv) * chip.step_overhead_s
        return PolicyScore(time_s, traffic, (("bound", step["bound"]),))

    if sig.op == "attention_decode":
        b, hkv, g, skv, d = sig.shape
        step = pm.decode_step_model(
            batch=b, kv_heads=hkv, group=g, kv_len=skv, head_dim=d,
            block_kv=policy.block_kv, dtype_bytes=dtype_bytes, chip=chip)
        sink_bytes = (policy.epilogue.extra_read_bytes(hkv * g)
                      if policy.epilogue is not None else 0)
        return PolicyScore(step["time_s"],
                           step["kv_bytes"] + step["partial_bytes"]
                           + sink_bytes,
                           (("bound", step["bound"]),
                            ("n_splits", step["n_splits"]),
                            ("utilization", round(step["utilization"], 2))))

    if sig.op == "fused_norm":
        rows, d = sig.shape
        traffic = 4 * rows * d * dtype_bytes
        steps = rows // policy.block_rows
        return PolicyScore(traffic / chip.hbm_bw
                           + steps * chip.step_overhead_s, traffic)

    if sig.op == "rope":
        b, h, s, d = sig.shape
        traffic = b * h * s * d * (2 * dtype_bytes + 8)  # x/out + f32 tables
        steps = b * h * (s // policy.block_rows)
        return PolicyScore(traffic / chip.hbm_bw
                           + steps * chip.step_overhead_s, traffic)

    raise AssertionError(sig.op)


def refine_with_cache_model(sig: OpSignature, policies: Iterable[KernelPolicy],
                            hw=None) -> list:
    """Re-rank GEMM finalists with the two-level cache simulator (Tab. 4).

    Slow (explicit LRU sim) — used by the schedule benchmarks and available
    as ``select_policy(..., cache_sim=True)``; the memoized fast path ranks
    analytically only.
    """
    from .cache_model import CacheHW, simulate_gemm_schedule
    hw = hw if hw is not None else CacheHW.tpu_v5e()
    m, n, k = sig.shape
    scored = []
    for pol in policies:
        r = simulate_gemm_schedule(pol.swizzle, m=m, n=n, k=k,
                                   block_m=pol.block_m, block_n=pol.block_n,
                                   block_k=pol.block_k, hw=hw)
        scored.append((r.modeled_time_s, repr(pol.cache_key()), pol, r))
    scored.sort(key=lambda t: t[:2])
    return [(pol, r) for _, _, pol, r in scored]


# ---------------------------------------------------------------------------
# Pretuned policy tables (DESIGN.md §15): measurement-grounded winners from
# repro.core.calibrate, persisted as versioned JSON and consulted AHEAD of
# the analytic ranking. The table also carries a fitted ChipSpec, which
# becomes the default chip for every subsequent analytic score — so even
# cells the table doesn't pin are ranked with measured coefficients.
# ---------------------------------------------------------------------------

PRETUNED_SCHEMA_VERSION = 1

# Module-global like the memo caches: one pretuned table per process. ``gen``
# is the calibration-table generation counter — it is part of every memo key
# below, so installing/refreshing/clearing a table invalidates all cached
# winners in-process (the PR 9 staleness fix) without flushing audits by hand.
_PRETUNED: dict = {"table": None, "chip": None, "gen": 0}


def pretuned_generation() -> int:
    return _PRETUNED["gen"]


def active_pretuned() -> Optional[dict]:
    """The installed pretuned table, or None."""
    return _PRETUNED["table"]


def active_chip() -> pm.ChipSpec:
    """The chip every ``chip=None`` ranking resolves against: the installed
    table's fitted ChipSpec when present, else the analytic V5E defaults."""
    chip = _PRETUNED["chip"]
    return chip if chip is not None else pm.V5E


def chip_from_dict(d: dict) -> pm.ChipSpec:
    """Rebuild a ChipSpec from a pretuned table's coefficient dict (unknown
    keys ignored — forward-compatible with fitted fields we don't have)."""
    fields = {f.name: f for f in dataclasses.fields(pm.ChipSpec)}
    kw = {}
    for k, v in d.items():
        if k not in fields:
            continue
        if fields[k].type in ("int", int):
            v = int(round(v))
        kw[k] = v
    return dataclasses.replace(pm.V5E, **kw)


def _chain_str(chain) -> str:
    """Stable string form of an epilogue/prologue chain for cell keys.
    Chains expose deterministic ``describe()`` short strings; None is the
    identity."""
    if chain is None:
        return "none"
    d = chain.describe()
    return d if isinstance(d, str) else str(d)


def _shard_str(shard) -> str:
    """Stable string form of a ShardSpec for cell keys / plan audits
    (duck-typed: core never imports repro.distributed)."""
    if shard is None:
        return "none"
    describe = getattr(shard, "describe", None)
    return describe() if callable(describe) else str(shard)


def pretuned_cell_key(sig: OpSignature) -> str:
    """The table key of one policy cell: shape-BUCKET × dtype × chain, as a
    stable string (buckets, not raw shapes, so a table cell covers the same
    launches the in-process memo would)."""
    op, shape, dtype, causal, ep, pro, variant, shard = sig.bucket()
    parts = [op, "x".join(str(x) for x in shape), dtype,
             "causal" if causal else "full",
             f"ep={_chain_str(ep)}", f"pro={_chain_str(pro)}"]
    if variant:
        parts.append(f"var={variant}")
    if shard is not None:
        parts.append(f"shard={_shard_str(shard)}")
    return "|".join(parts)


def pretuned_fusion_key(kind: str, bucket_shape: tuple, dtype: str, *,
                        residual: bool, prenorm: str, backward: bool,
                        causal: bool, softcap: bool, sink: bool,
                        shard=None) -> str:
    """The table key of one fusion-plan cell (mirrors select_fusion's memo).
    Unsharded cells keep the historical key so shipped tables stay valid;
    a ShardSpec appends its stable token."""
    parts = [kind, "x".join(str(x) for x in bucket_shape), dtype,
             f"res={int(residual)}", f"pre={prenorm}",
             f"bwd={int(backward)}", f"causal={int(causal)}",
             f"cap={int(softcap)}", f"sink={int(sink)}"]
    if shard is not None:
        parts.append(f"shard={_shard_str(shard)}")
    return "|".join(parts)


def install_pretuned(table: dict, *, arch: Optional[str] = None) -> bool:
    """Validate and install a pretuned table; True iff installed.

    A schema-version or arch mismatch REJECTS the table (counter-logged,
    previous state untouched) and every selection falls back to the analytic
    ranking — a table fitted on other hardware must never pin winners here.
    ``arch`` overrides the expected platform (defaults to the active JAX
    backend).
    """
    if int(table.get("schema_version", -1)) != PRETUNED_SCHEMA_VERSION:
        obs.incr("autotune.pretuned_rejected_schema")
        return False
    expect = arch
    if expect is None:
        try:
            import jax
            expect = jax.default_backend()
        except Exception:  # pragma: no cover - jax is a hard dep in practice
            expect = None
    if expect is not None and table.get("arch") != expect:
        obs.incr("autotune.pretuned_rejected_arch")
        return False
    chip_d = table.get("chip")
    _PRETUNED.update(table=table,
                     chip=chip_from_dict(chip_d) if chip_d else None)
    _PRETUNED["gen"] += 1
    obs.incr("autotune.pretuned_installed")
    return True


def load_pretuned(path, *, arch: Optional[str] = None) -> bool:
    """Load a pretuned table from a JSON file and install it."""
    import json
    with open(path) as f:
        table = json.load(f)
    return install_pretuned(table, arch=arch)


def use_pretuned(table_or_path, *, arch: Optional[str] = None) -> bool:
    """Install a pretuned table given either a report dict or a JSON path —
    the single entry point serve/train expose as ``pretuned=``."""
    if isinstance(table_or_path, dict):
        return install_pretuned(table_or_path, arch=arch)
    return load_pretuned(table_or_path, arch=arch)


def clear_pretuned() -> None:
    """Drop the installed table (and its fitted chip); bumps the generation
    so memoized pretuned winners can't survive."""
    if _PRETUNED["table"] is not None or _PRETUNED["chip"] is not None:
        _PRETUNED.update(table=None, chip=None)
        _PRETUNED["gen"] += 1


def _sig_fits(sig: OpSignature, pol: KernelPolicy) -> bool:
    """A pinned policy must still tile THIS launch's exact shape and fit
    VMEM — guards hand-edited tables and bucket-rounding edge cases."""
    if sig.op in ("gemm", "gemm_bwd"):
        m, n, k = sig.shape
        ok = pol.fits(m, n, k)
    elif sig.op in ("attention_fwd", "attention_bwd"):
        _, _, sq, skv, d = sig.shape
        ok = pol.fits(sq, skv) and pol.block_k == d
    elif sig.op == "attention_decode":
        _, _, g, skv, d = sig.shape
        ok = pol.block_m == g and skv % pol.block_n == 0 and pol.block_k == d
    elif sig.op == "fused_norm":
        rows, d = sig.shape
        ok = rows % pol.block_rows == 0 and pol.block_k == d
    else:  # rope
        _, _, s, d = sig.shape
        ok = s % pol.block_rows == 0 and pol.block_k == d
    return ok and pol.is_legal()


# ---------------------------------------------------------------------------
# Memoized selection
# ---------------------------------------------------------------------------

_POLICY_CACHE: dict = {}
_CACHE_STATS = {"hits": 0, "misses": 0}
# Audit records live beside the memo caches so a cache *hit* can still
# replay the original decision into the telemetry journal (cached=True) —
# the decision is identical, the rescoring cost is zero (DESIGN.md §13).
_POLICY_AUDIT: dict = {}
_PLAN_AUDIT: dict = {}


def select_policy(op: str, shape, dtype="bfloat16", *, causal: bool = False,
                  epilogue=None, prologue=None, variant: str = "",
                  shard=None,
                  swizzle: Optional[SwizzleConfig] = None,
                  cache_sim: bool = False,
                  chip: Optional[pm.ChipSpec] = None) -> KernelPolicy:
    """The tuned policy for an op signature; memoized per shape-bucket.

    ``epilogue``/``prologue`` (gemm/gemm_bwd only) make the candidate set
    and the traffic model chain-aware; the returned policy carries them.
    ``variant`` ('da'|'db', gemm_bwd only) names the fused-backward launch.
    ``shard`` (a :class:`~repro.distributed.sharding.ShardSpec`, DESIGN.md
    §16) marks the launch as one rank of a sharded op: the shape passed in
    is the per-rank LOCAL shape (which is what the candidate set and the
    traffic model should score), and the spec joins the memo key + audit so
    a sharded launch never aliases its single-device twin's cell.
    ``swizzle`` pins the traversal order while the block/pipeline axes are
    still searched (the legacy ``gemm(swizzle=...)`` shim and the bwd
    launches, which inherit the fwd traversal, resolve through this).

    ``chip=None`` resolves against :func:`active_chip` — the calibrated
    ChipSpec when a pretuned table is installed. An installed table is also
    consulted for a pinned WINNER first (measurement-grounded, DESIGN.md
    §15); analytic ranking is the fallback on any cell miss, and pinning is
    bypassed entirely when the caller constrains the search (``swizzle=`` /
    ``cache_sim=True``) since table winners were measured unconstrained.

    Raises ValueError if no candidate is legal — which a recompute-path
    norm prologue *can* hit (its full-K A tile may not fit VMEM for huge
    feature dims): callers fall back to the standalone-norm plan then.
    """
    if chip is None:
        chip = active_chip()
    sig = OpSignature(op, tuple(int(x) for x in shape), str(dtype),
                      causal=causal, epilogue=epilogue, prologue=prologue,
                      variant=variant, shard=shard)
    key = sig.bucket() + (swizzle, bool(cache_sim), chip.name,
                          _PRETUNED["gen"])
    hit = _POLICY_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["hits"] += 1
        if obs.enabled():
            audit = _POLICY_AUDIT.get(key)
            if audit is not None:
                obs.plan_decision("policy", op, sig.shape, sig.dtype,
                                  audit["chosen"], audit["candidates"],
                                  cached=True)
        return hit
    _CACHE_STATS["misses"] += 1

    table = _PRETUNED["table"]
    if table is not None and swizzle is None and not cache_sim:
        cell = (table.get("cells") or {}).get(pretuned_cell_key(sig))
        if cell is None:
            obs.incr("autotune.pretuned_cell_miss")
        else:
            pinned = policy_from_spec(cell["policy"], epilogue=epilogue,
                                      prologue=prologue)
            if _sig_fits(sig, pinned):
                obs.incr("autotune.pretuned_hit")
                _POLICY_CACHE[key] = pinned
                audit = {"chosen": dict(pinned.describe(), pretuned=True),
                         "candidates": [
                             {"policy": pinned.schedule.name,
                              "blocks": [pinned.block_m, pinned.block_n,
                                         pinned.block_k],
                              "time_s": cell.get("measured_time_s"),
                              "dma_bytes": None, "chosen": True,
                              "pretuned": True}]}
                _POLICY_AUDIT[key] = audit
                obs.plan_decision("policy", op, sig.shape, sig.dtype,
                                  audit["chosen"], audit["candidates"])
                return pinned
            obs.incr("autotune.pretuned_illegal")

    cands = candidate_policies(sig, swizzle=swizzle)
    if not cands:
        raise ValueError(f"no legal policy for {sig}")
    scored = sorted(cands,
                    key=lambda p: score_policy(sig, p, chip).rank_key(p))
    best = scored[0]
    if cache_sim and sig.op == "gemm":
        finalists = scored[: min(8, len(scored))]
        best = refine_with_cache_model(sig, finalists)[0][0]
    _POLICY_CACHE[key] = best
    # audit: the winner + the top losing candidates with their modeled
    # time/bytes (bounded — a full candidate set can be hundreds deep)
    cand_audit = []
    for p in scored[:8]:
        s = score_policy(sig, p, chip)
        cand_audit.append({"policy": p.schedule.name,
                           "blocks": [p.block_m, p.block_n, p.block_k],
                           "time_s": s.time_s, "dma_bytes": s.dma_bytes,
                           "chosen": p is best})
    audit = {"chosen": best.describe(),
             "candidates": cand_audit}
    _POLICY_AUDIT[key] = audit
    obs.plan_decision("policy", op, sig.shape, sig.dtype,
                      audit["chosen"], audit["candidates"])
    return best


def policy_cache_stats() -> dict:
    return dict(_CACHE_STATS, size=len(_POLICY_CACHE))


def clear_policy_cache() -> None:
    _POLICY_CACHE.clear()
    _PLAN_CACHE.clear()
    _BWD_ROUTE_CACHE.clear()
    _POLICY_AUDIT.clear()
    _PLAN_AUDIT.clear()
    _CACHE_STATS.update(hits=0, misses=0)


# ---------------------------------------------------------------------------
# Backward routing (DESIGN.md §15): fused kernel bwd vs the oracle VJP
# ---------------------------------------------------------------------------

_BWD_ROUTE_CACHE: dict = {}


def select_bwd_mode(m: int, n: int, k: int, *, dtype: str = "bfloat16",
                    epilogue=None, prologue=None,
                    chip: Optional[pm.ChipSpec] = None) -> str:
    """Route ``gemm_fused(bwd_mode='auto')`` per shape bucket: 'kernel'
    (the fused chain-transpose launches) or 'reference' (the jnp-oracle
    recompute VJP).

    The decision comes from :func:`perf_model.gemm_bwd_route_model` — a
    roofline comparison of the two paths plus a peak-memory residency
    penalty on the kernel path's saved preactivations. Train-shaped cells
    (k ≳ 1024) keep the kernel path; degenerate cells (tiny contraction
    dim, so saved preacts dominate the traffic) route to the oracle.
    Memoized per (pow2-bucketed m, n, k, dtype, chain); the decision is
    journaled as a ``bwd_route`` plan decision so tests audit it without
    monkeypatching.
    """
    if chip is None:
        chip = active_chip()
    m, n, k = int(m), int(n), int(k)
    m_bucket = 1 << max(0, (m - 1).bit_length())  # batch-like dim
    key = (m_bucket, n, k, str(dtype), _chain_str(epilogue),
           _chain_str(prologue), chip.name, _PRETUNED["gen"])
    hit = _BWD_ROUTE_CACHE.get(key)
    if hit is not None:
        if obs.enabled():
            obs.plan_decision("bwd_route", "gemm_bwd", (m, n, k),
                              str(dtype), {"mode": hit, "cached": True},
                              cached=True)
        return hit
    db = _DTYPE_BYTES.get(str(dtype), 2)
    n_saved = 0
    preact_bytes = db
    gated = bool(getattr(epilogue, "gate", False))
    if epilogue is not None and getattr(epilogue, "needs_saved_preact",
                                        False):
        n_saved = int(getattr(epilogue, "saved_accumulators", 1))
        if getattr(epilogue, "preact_keeps_f32", False):
            preact_bytes = 4
    prenorm = bool(prologue is not None
                   and not getattr(prologue, "is_identity", True))
    route = pm.gemm_bwd_route_model(m=m_bucket, n=n, k=k, dtype_bytes=db,
                                    n_saved=n_saved,
                                    preact_bytes=preact_bytes,
                                    gated=gated, prenorm=prenorm, chip=chip)
    mode = route["route"]
    _BWD_ROUTE_CACHE[key] = mode
    obs.plan_decision(
        "bwd_route", "gemm_bwd", (m, n, k), str(dtype),
        {"mode": mode, "kernel_score": route["kernel_score"],
         "reference_score": route["reference_score"],
         "peak_save_bytes": route["peak_save_bytes"]},
        [{"mode": "kernel", "time_s": route["kernel_time_s"],
          "score": route["kernel_score"], "chosen": mode == "kernel"},
         {"mode": "reference", "time_s": route["reference_time_s"],
          "score": route["reference_score"],
          "chosen": mode == "reference"}])
    return mode


# ---------------------------------------------------------------------------
# Fusion-plan selection (DESIGN.md §9): fused vs unfused, from dma_bytes only
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict = {}


def select_fusion(kind: str, shape, dtype="bfloat16", *,
                  residual: bool = True, prenorm: str = "none",
                  backward: bool = False,
                  causal: bool = False, softcap: bool = False,
                  sink: bool = False, shard=None,
                  chip: Optional[pm.ChipSpec] = None) -> dict:
    """Pick the fused or unfused execution plan for a model-layer chain.

    ``chip=None`` resolves against :func:`active_chip` (the calibrated
    ChipSpec when a pretuned table is installed), and an installed table
    pins the fused/unfused DECISION for cells it carries (the byte models
    still fill in the returned plan dict) — see docs/autotuning.md.

    The decision is made *purely* by comparing the two plans' modeled HBM
    traffic (``perf_model.mlp_chain_model`` / ``qkv_rope_chain_model`` /
    ``attention_chain_model``) — no hard-coded preference: a chain that
    stops saving bytes (tiny token counts, residual-free expert FFNs near
    the crossover) loses the selection. Memoized per shape-bucket (the
    token/batch dim rounds to the next power of two).

    ``kind``/``shape``:
      'mlp'       (tokens, d_model, d_ff, gated); ``residual`` says whether
                  the chain ends in a residual add (False for MoE experts)
      'qkv_rope'  (tokens, d_model, num_heads, num_kv_heads, head_dim)
      'qkv'       same shape as 'qkv_rope' but rope-free (BERT/Whisper/
                  enc-dec blocks): the fused side is the packed QK/V GEMM
                  pair with the pre-norm folded in; without a prenorm the
                  plans tie on bytes and 'unfused' wins (the rope-free
                  fusion pays only via the folded norm)
      'attention' (batch, heads, kv_heads, seq_q, seq_kv, head_dim); the
                  fused side is the flash kernel (online softmax, O(1)
                  score memory), the unfused side materializes the
                  (seq_q, seq_kv) score matrix per pass.  ``causal`` /
                  ``softcap`` / ``sink`` describe the epilogue chain the
                  launch runs (softcap adds unfused passes; the sink row
                  is a per-head scalar stream on both sides)

    ``prenorm`` ('rmsnorm' | 'layernorm') prepends the pre-norm of the
    transformer block to both plans: the fused plan folds it into the first
    GEMM's A-tile prologue (DESIGN.md §10), the unfused plan runs the
    standalone norm pass in front of the eager chain.

    ``shard`` (a :class:`~repro.distributed.sharding.ShardSpec`, DESIGN.md
    §16) makes the decision sharding-aware: the spec joins the memo /
    pretuned keys, the chain's collective rides both plans as an
    interconnect term priced from the ICI roofline and folded into
    ``dma_bytes`` in HBM-equivalent units (the ranking stays bytes-only),
    and the returned plan carries ``collective_bytes`` / ``collective_s`` /
    ``overlap_fraction`` for the chosen side. ``shape`` stays the per-rank
    LOCAL chain shape. The extra kind ``'gemm_collective'`` (shape
    (m, n, k), full logical GEMM; requires a shard with an all_gather or
    reduce_scatter collective) scores the ring-overlapped collective GEMM
    against the gather-then-GEMM baseline
    (``perf_model.collective_gemm_model``).

    ``backward=True`` scores the chain's *training backward* instead
    (DESIGN.md §11): the fused side is the kernel-side chain transpose
    (saved-preact streams + two fused bwd GEMM launches per fwd GEMM, norm
    transposed tile-wise; for attention, the saved-(out, lse) flash
    backward), the unfused side is the oracle-recompute VJP (autodiff of
    the unfused jnp chain with full fwd re-materialization).

    Returns {plan: 'fused'|'unfused', fused_bytes, unfused_bytes,
    traffic_reduction, fused: <model dict>, unfused: <model dict>}.
    """
    if chip is None:
        chip = active_chip()
    dtype = str(dtype)
    shape = tuple(int(x) for x in shape)
    tokens = 1 << max(0, (shape[0] - 1).bit_length())  # pow2 bucket
    key = (kind, (tokens,) + shape[1:], dtype, bool(residual), prenorm,
           bool(backward), bool(causal), bool(softcap), bool(sink),
           shard, chip.name, _PRETUNED["gen"])
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        if obs.enabled():
            audit = _PLAN_AUDIT.get(key)
            if audit is not None:
                obs.plan_decision("fusion", kind, shape, dtype,
                                  audit["chosen"], audit["candidates"],
                                  cached=True)
        return hit
    pinned_plan = None
    table = _PRETUNED["table"]
    if table is not None:
        fkey = pretuned_fusion_key(kind, (tokens,) + shape[1:], dtype,
                                   residual=bool(residual), prenorm=prenorm,
                                   backward=bool(backward),
                                   causal=bool(causal),
                                   softcap=bool(softcap), sink=bool(sink),
                                   shard=shard)
        cell = (table.get("fusion") or {}).get(fkey)
        if cell is None:
            obs.incr("autotune.pretuned_fusion_miss")
        elif cell.get("plan", {}).get("plan") in ("fused", "unfused"):
            pinned_plan = cell["plan"]["plan"]
            obs.incr("autotune.pretuned_fusion_hit")
    db = _DTYPE_BYTES.get(dtype, 2)
    if kind == "mlp":
        _, d, f, gated = shape
        model = pm.mlp_chain_bwd_model if backward else pm.mlp_chain_model
        variants = [model(tokens=tokens, d_model=d, d_ff=f,
                          dtype_bytes=db, gated=bool(gated),
                          residual=residual, prenorm=prenorm,
                          fused=fused, chip=chip)
                    for fused in (True, False)]
    elif kind in ("qkv_rope", "qkv"):
        _, d, h, hkv, hd = shape
        model = (pm.qkv_rope_chain_bwd_model if backward
                 else pm.qkv_rope_chain_model)
        variants = [model(tokens=tokens, d_model=d,
                          num_heads=h, num_kv_heads=hkv,
                          head_dim=hd, dtype_bytes=db,
                          prenorm=prenorm, rope=(kind == "qkv_rope"),
                          fused=fused, chip=chip)
                    for fused in (True, False)]
    elif kind == "attention":
        _, h, hkv, sq, skv, hd = shape
        model = (pm.attention_chain_bwd_model if backward
                 else pm.attention_chain_model)
        variants = [model(batch=tokens, heads=h, kv_heads=hkv,
                          seq_q=sq, seq_kv=skv, head_dim=hd,
                          causal=causal, softcap=softcap, sink=sink,
                          dtype_bytes=db, fused=fused, chip=chip)
                    for fused in (True, False)]
    elif kind == "gemm_collective":
        if shard is None or getattr(shard, "collective", "none") not in \
                ("all_gather", "reduce_scatter"):
            raise ValueError(
                "gemm_collective needs a ShardSpec with an all_gather or "
                f"reduce_scatter collective, got shard={shard!r}")
        _, n, k = shape
        variants = [pm.collective_gemm_model(
                        m=tokens, n=n, k=k, n_shards=shard.n_shards,
                        dtype_bytes=db, variant=shard.collective,
                        fused=fused, chip=chip)
                    for fused in (True, False)]
    else:
        raise ValueError(f"unknown fusion kind {kind!r}")
    if (shard is not None and kind != "gemm_collective"
            and getattr(shard, "collective", "none") != "none"):
        # the §16 interconnect term: the chain's collective rides BOTH
        # plans (the wire bytes are plan-invariant for a given sharding —
        # the plans differ on HBM traffic), priced from the ICI roofline
        # and folded into dma_bytes in HBM-equivalent units so the
        # decision below stays bytes-only. all_to_all chains (expert
        # dispatch) pay the wire twice: out and back.
        act_bytes = tokens * shape[1] * db
        if shard.collective == "all_to_all":
            act_bytes *= 2
        variants = [pm.collective_chain_model(
                        v, collective=shard.collective, nbytes=act_bytes,
                        n_shards=shard.n_shards, chip=chip)
                    for v in variants]
    fused, unfused = variants
    plan = dict(
        plan=("fused" if fused["dma_bytes"] < unfused["dma_bytes"]
              else "unfused"),
        fused_bytes=fused["dma_bytes"], unfused_bytes=unfused["dma_bytes"],
        traffic_reduction=unfused["dma_bytes"] / max(1, fused["dma_bytes"]),
        fused=fused, unfused=unfused)
    if pinned_plan is not None:
        # the measured table pins the decision; the byte models above still
        # fill in the plan dict every caller reads
        plan["plan"] = pinned_plan
        plan["pretuned"] = True
    if shard is not None:
        chosen = fused if plan["plan"] == "fused" else unfused
        plan.update(shard=_shard_str(shard),
                    collective_bytes=chosen.get("collective_bytes", 0),
                    collective_s=chosen.get("collective_s", 0.0),
                    overlap_fraction=chosen.get("overlap_fraction", 0.0))
    _PLAN_CACHE[key] = plan
    audit = {"chosen": {"plan": plan["plan"],
                        "traffic_reduction": plan["traffic_reduction"],
                        "prenorm": prenorm, "backward": bool(backward),
                        **({"shard": plan["shard"],
                            "overlap_fraction": plan["overlap_fraction"]}
                           if shard is not None else {}),
                        **({"pretuned": True} if pinned_plan else {})},
             "candidates": [
                 {"plan": "fused", "dma_bytes": plan["fused_bytes"],
                  "chosen": plan["plan"] == "fused"},
                 {"plan": "unfused", "dma_bytes": plan["unfused_bytes"],
                  "chosen": plan["plan"] == "unfused"}]}
    _PLAN_AUDIT[key] = audit
    obs.plan_decision("fusion", kind, shape, dtype,
                      audit["chosen"], audit["candidates"])
    return plan


# ---------------------------------------------------------------------------
# Model-level resolution (used by models/api, dryrun, serve, trainer)
# ---------------------------------------------------------------------------

def policies_for_model(cfg, *, batch: int, seq_len: int,
                       dtype: Optional[str] = None,
                       decode_len: Optional[int] = None,
                       shard=None) -> dict:
    """Resolve the kernel policies a model built from ``cfg`` will use for a
    (batch, seq_len) bucket. Returns {op_kind: KernelPolicy}; attention-free
    architectures get only the 1-D policies.

    ``decode_len`` is the KV-cache slot count of the decode step (an engine
    passes its max_len); the split-KV decode policy resolves against it.
    Windowed layers keep a smaller ring cache and re-resolve their exact
    shape through the same memoized autotuner at trace time.

    ``shard`` (ShardSpec) additionally warms + journals the SHARDED fusion
    plans this bucket will execute (the per-rank MoE expert chain and the
    prenorm-MLP chain with the interconnect term), so a training run's
    plan audit shows the sharded decisions at pin time rather than deep in
    the first traced step."""
    dtype = dtype or getattr(cfg, "compute_dtype", "bfloat16")
    h = getattr(cfg, "num_heads", 0)
    d = getattr(cfg, "head_dim", 0) or 0
    dm = getattr(cfg, "d_model", 0)
    out = {}
    kinds = set(getattr(cfg, "block_pattern", ("attn",)))
    has_attn = bool(kinds & {"attn", "local", "moe"}) or \
        getattr(cfg, "family", "lm") in ("encdec", "vlm")
    if has_attn and h and d:
        attn_shape = (batch, h, seq_len, seq_len, d)
        out["attention_fwd"] = select_policy("attention_fwd", attn_shape,
                                             dtype, causal=True)
        out["attention_bwd"] = select_policy("attention_bwd", attn_shape,
                                             dtype, causal=True)
        hkv = getattr(cfg, "num_kv_heads", h) or h
        out["attention_decode"] = select_policy(
            "attention_decode",
            (batch, hkv, h // hkv, decode_len or seq_len, d), dtype)
        if getattr(cfg, "rope_style", "none") != "none":
            out["rope"] = select_policy("rope", (batch, h, seq_len, d), dtype)
    if dm:
        out["fused_norm"] = select_policy("fused_norm",
                                          (batch * seq_len, dm), dtype)
    d_ff = getattr(cfg, "d_ff", 0) or 0
    if dm and d_ff:
        # The fused-MLP megakernel GEMMs (DESIGN.md §9-§10): the dual-output
        # gated up-projection (with the pre-norm folded into its A prologue
        # when the chain model picks that plan) and the residual-fused
        # down-projection. (Function-level import; epilogue/prologue depend
        # only on jax, so this does not create a core -> kernels cycle.)
        from repro.kernels.gemm.epilogue import Epilogue
        from repro.kernels.gemm.prologue import norm_prologue
        gated = getattr(cfg, "mlp_act", "swiglu") in ("swiglu", "geglu")
        act = "gelu" if getattr(cfg, "mlp_act", "") in ("geglu", "gelu") \
            else "silu"
        tokens = batch * seq_len
        up_ep = (Epilogue(activation=act, gate=True) if gated
                 else Epilogue(activation=act))
        norm_kind = getattr(cfg, "norm", "rmsnorm")
        up_pro = None
        if select_fusion("mlp", (tokens, dm, d_ff, gated), dtype,
                         prenorm=norm_kind)["plan"] == "fused":
            up_pro = norm_prologue(norm_kind, beta=(norm_kind == "layernorm"))
        try:
            out["gemm_mlp_up"] = select_policy("gemm", (tokens, d_ff, dm),
                                               dtype, epilogue=up_ep,
                                               prologue=up_pro)
        except ValueError:
            # full-K A tile doesn't fit VMEM: the model layer falls back to
            # the standalone-norm plan, so report that policy here too
            out["gemm_mlp_up"] = select_policy("gemm", (tokens, d_ff, dm),
                                               dtype, epilogue=up_ep)
        out["gemm_mlp_down"] = select_policy(
            "gemm", (tokens, dm, d_ff), dtype,
            epilogue=Epilogue(residual=True, scale=True))
        if shard is not None:
            # the sharded plans this bucket executes (DESIGN.md §16): the
            # residual-free per-rank expert chain for MoE configs, the
            # plain prenorm chain otherwise — journaled at pin time
            ns = max(1, shard.n_shards)
            if getattr(cfg, "moe", None) is not None:
                loc_f = d_ff if shard.collective == "all_to_all" \
                    else max(1, d_ff // ns)
                select_fusion("mlp", (tokens, dm, loc_f, gated), dtype,
                              residual=False, shard=shard)
            else:
                select_fusion("mlp", (tokens, dm, d_ff, gated), dtype,
                              prenorm=norm_kind, shard=shard)
    return out


def describe_policies(policies: dict) -> dict:
    """JSON-able {op: describe()} for dryrun/report cells."""
    return {op: pol.describe() for op, pol in sorted(policies.items())}
