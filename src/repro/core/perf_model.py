"""TPU v5e roofline constants and analytic kernel pipeline model.

Used three ways:
  * the dry-run roofline terms in EXPERIMENTS.md §Roofline;
  * the Tab. 2/3 reproduction (`benchmarks/bench_schedules.py`) — modeled
    TFLOP/s as a function of output tile, pipeline depth and producer VMEM tax;
  * kernel-level napkin math during the §Perf hillclimb.
"""
from __future__ import annotations

import dataclasses
import math

from . import tiles
from .schedule import Schedule


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Roofline constants — every coefficient the calibration subsystem can
    re-fit from measurement lives here (DESIGN.md §15): MXU and vector
    throughput, HBM bandwidth, the per-grid-step overhead, and the decode
    pipeline's saturation ramp. ``repro.core.calibrate.fit_chip`` produces a
    replacement ChipSpec by least squares over a measured sweep;
    ``autotune.active_chip()`` swaps it in for every subsequent ranking."""

    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # B/s
    ici_bw_per_link: float = 50e9        # B/s per ICI link (about; 2D torus)
    ici_links: int = 4                   # links per chip on a 2D torus
    vmem_bytes: int = tiles.VMEM_BYTES
    mxu_dim: int = 128
    # --- calibratable coefficients (defaults reproduce the analytic model
    # exactly; a fitted chip overrides them) ---
    vector_flops: float = 0.0            # 0 -> peak_flops_bf16 / 16
    step_overhead_s: float = 1e-6        # fixed cost per Pallas grid step
    decode_saturation_steps: int = 8     # split-KV pipeline ramp constant

    def peak_flops(self, dtype_bytes: int = 2) -> float:
        # v5e matrix unit: int8 is 2x bf16; fp32 via passes ≈ 1/4.
        if dtype_bytes == 1:
            return 2 * self.peak_flops_bf16
        if dtype_bytes == 4:
            return self.peak_flops_bf16 / 4
        return self.peak_flops_bf16

    def vector_throughput(self) -> float:
        """Elementwise-unit FLOP/s (softmax/norm vector work)."""
        return self.vector_flops or self.peak_flops_bf16 / 16


V5E = ChipSpec()


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        # optimistic full-overlap model: the dominant term is the step time
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self) -> float:
        """compute_s / step_time — how close to compute-bound we are."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0


def roofline(flops: float, hbm_bytes: float, collective_bytes: float,
             *, n_chips: int, chip: ChipSpec = V5E,
             dtype_bytes: int = 2) -> RooflineTerms:
    """The three §Roofline terms, in seconds (totals are fleet-wide)."""
    compute = flops / (n_chips * chip.peak_flops(dtype_bytes))
    memory = hbm_bytes / (n_chips * chip.hbm_bw)
    coll = collective_bytes / (n_chips * chip.ici_bw_per_link * chip.ici_links)
    return RooflineTerms(compute, memory, coll)


# ---------------------------------------------------------------------------
# Analytic GEMM pipeline model (paper Tab. 2 reproduction).
# ---------------------------------------------------------------------------

def mxu_efficiency(dim_m: int, dim_n: int, dim_k: int, mxu: int = 128) -> float:
    """Fraction of systolic-array cycles doing useful work for a tile matmul."""
    eff = 1.0
    for d in (dim_m, dim_n, dim_k):
        eff *= d / (math.ceil(d / mxu) * mxu)
    return eff


def gemm_step_model(schedule: Schedule, *, k_total: int, dtype_bytes: int = 2,
                    chip: ChipSpec = V5E) -> dict:
    """Model one grid step of the blocked GEMM under ``schedule``.

    Compute time: bm*bn*bk MACs on the MXU at efficiency from alignment.
    Memory time: (A+B block) DMA at HBM bandwidth.
    Pipeline: steady-state step time = max(compute, memory) (PINGPONG double
    buffering); deeper pipelines amortize the prologue but raise VMEM use.
    """
    bm, bn, bk = schedule.block_m, schedule.block_n, schedule.block_k
    flops = 2.0 * bm * bn * bk
    eff = mxu_efficiency(bm, bn, bk, chip.mxu_dim)
    compute_s = flops / (chip.peak_flops(dtype_bytes) * eff)
    dma_bytes = (bm * bk + bk * bn) * dtype_bytes
    memory_s = dma_bytes / chip.hbm_bw

    acc_bytes = bm * bn * 4  # fp32 accumulator scratch (pinned, see DESIGN §2)
    vmem = tiles.pipeline_vmem_bytes(
        [((bm, bk), "bfloat16"), ((bk, bn), "bfloat16")],
        n_buffers=schedule.n_buffers, scratch_bytes=acc_bytes)
    feasible = vmem <= schedule.vmem_budget()

    n_steps = max(1, k_total // bk)
    steady = max(compute_s, memory_s)
    prologue = memory_s  # first block load not overlapped
    total = prologue + n_steps * steady
    tflops = (2.0 * bm * bn * k_total) / total / 1e12
    return dict(schedule=schedule.name, block=(bm, bn, bk), feasible=feasible,
                vmem_bytes=vmem, compute_s=compute_s, memory_s=memory_s,
                arithmetic_intensity=flops / dma_bytes,
                modeled_tflops=tflops if feasible else 0.0,
                bound="compute" if compute_s >= memory_s else "memory")


def best_output_tile(vmem_budget: int, n_buffers: int, block_k: int,
                     dtype_bytes: int = 2) -> tuple[int, int]:
    """Largest square-ish MXU-aligned output tile whose pipeline fits VMEM.

    Reproduces the paper's Tab. 2 argument: VMEM (register) budget bounds the
    output tile, which bounds arithmetic intensity.
    """
    best = (128, 128)
    for bm in (128, 192, 256, 384, 512):
        for bn in (128, 192, 256, 384, 512):
            acc = bm * bn * 4
            vmem = tiles.pipeline_vmem_bytes(
                [((bm, block_k), "bfloat16"), ((block_k, bn), "bfloat16")],
                n_buffers=n_buffers, scratch_bytes=acc)
            if vmem <= vmem_budget and bm * bn > best[0] * best[1]:
                best = (bm, bn)
    return best


# ---------------------------------------------------------------------------
# Split-KV flash-decode model (bandwidth-dominated; paper Fig. 9 regime).
# ---------------------------------------------------------------------------

# Grid steps needed before the Pallas pipeline hides the HBM latency of the
# next K/V block behind the current (tiny) compute step. Below this the
# prologue/epilogue bubbles dominate — the reason split-KV exists: when
# batch*kv_heads is small, splitting the KV axis manufactures grid
# parallelism so the DMA engine stays busy. These module constants are the
# uncalibrated defaults; a fitted ChipSpec overrides both per-chip.
DECODE_SATURATION_STEPS = 8
# Per-grid-step fixed cost (s): pipeline bookkeeping per Pallas step. Matches
# the autotuner's step-overhead scale.
DECODE_STEP_OVERHEAD_S = 1e-6


def decode_step_model(*, batch: int, kv_heads: int, group: int,
                      kv_len: int, head_dim: int, block_kv: int,
                      dtype_bytes: int = 2, chip: ChipSpec = V5E) -> dict:
    """Model one split-KV flash-decode launch (q_len=1, GQA group packed).

    Unlike the GEMM/attention models this one is bandwidth-, not FLOP-,
    dominated: each of the ``batch * kv_heads * n_splits`` grid cells streams
    one (block_kv, head_dim) K and V block exactly once, does O(group *
    block_kv * head_dim) MACs (negligible: group <= 16), and writes a
    (group, head_dim) partial + (group,) m/l stats that a jnp log-sum-exp
    combine reduces. Split count trades per-step overhead against pipeline
    fill: too few steps and the DMA queue never saturates HBM.
    """
    n_splits = max(1, kv_len // block_kv)
    n_steps = batch * kv_heads * n_splits
    kv_bytes = 2 * batch * kv_heads * kv_len * head_dim * dtype_bytes
    # q/o traffic + the per-split partials the combine step re-reads
    partial_bytes = batch * kv_heads * n_splits * (group * head_dim + 2 * group) * 4
    qo_bytes = 2 * batch * kv_heads * group * head_dim * dtype_bytes
    util = min(1.0, n_steps / chip.decode_saturation_steps)
    stream_s = kv_bytes / (chip.hbm_bw * util)
    combine_s = 2 * partial_bytes / chip.hbm_bw  # written then re-read
    total = (stream_s + qo_bytes / chip.hbm_bw + combine_s
             + n_steps * chip.step_overhead_s)
    flops = 4.0 * batch * kv_heads * group * kv_len * head_dim
    return dict(block_kv=block_kv, n_splits=n_splits, n_steps=n_steps,
                kv_bytes=kv_bytes, partial_bytes=partial_bytes,
                utilization=util, time_s=total,
                achieved_bw=kv_bytes / total if total else 0.0,
                modeled_tflops=flops / total / 1e12 if total else 0.0,
                bound="memory")


# ---------------------------------------------------------------------------
# Fused GEMM epilogue chain models (DESIGN.md §9; paper Fig. 9 regime).
#
# These model the HBM traffic of "GEMM + a short elementwise chain" both as
# the fused megakernel (the chain runs in the store, so intermediates never
# round-trip HBM) and as the unfused eager sequence (every op re-reads and
# re-writes the full activation). Weights are counted once per GEMM pass —
# the panel-revisit refinement lives in autotune.gemm_traffic_bytes; here the
# *difference* between plans is pure activation traffic, which revisits
# don't change. The autotuner's select_fusion picks a plan from dma_bytes
# alone, so any chain that stops saving bytes falls back to unfused.
# ---------------------------------------------------------------------------


def _chain_dict(dma_bytes: float, flops: float, fused: bool,
                dtype_bytes: int, chip: ChipSpec) -> dict:
    compute_s = flops / chip.peak_flops(dtype_bytes)
    memory_s = dma_bytes / chip.hbm_bw
    return dict(dma_bytes=int(dma_bytes), flops=flops, fused=fused,
                compute_s=compute_s, memory_s=memory_s,
                time_s=max(compute_s, memory_s),
                bound="compute" if compute_s >= memory_s else "memory")


def _prenorm_vec_bytes(d: int, prenorm: str, dtype_bytes: int) -> int:
    """gamma (+ beta for layernorm) row-vector bytes of a pre-norm."""
    if prenorm == "none":
        return 0
    return d * dtype_bytes * (2 if prenorm == "layernorm" else 1)


def mlp_chain_model(*, tokens: int, d_model: int, d_ff: int,
                    dtype_bytes: int = 2, gated: bool = True,
                    residual: bool = True, prenorm: str = "none",
                    fused: bool = True, chip: ChipSpec = V5E) -> dict:
    """The transformer MLP hot chain: [pre-norm +] up-projection(s) +
    activation [+ SwiGLU gating] + down-projection [+ scaled residual add].

    fused (two launches):
      dual-output up GEMM   reads x once + both up weights, writes h once;
                            with ``prenorm`` the norm runs as its A-tile
                            prologue (plus the gamma/beta rows) — the
                            normed activation never exists in HBM. The
                            per-A-tile recompute is block-dependent vector
                            work charged by autotune.score_policy; here it
                            appears as one logical norm pass of FLOPs.
      down GEMM             reads h + w_out [+ the residual], writes out
    unfused (eager chain):
      [pre-norm             reads x, writes norm(x)]
      each up GEMM          re-reads norm(x), writes its own (T, F) output
      gating/activation     re-reads the intermediates, writes h
      down GEMM             reads h + w_out, writes out
      [residual add         re-reads out and x, writes out]

    ``residual=False`` models residual-free chains (the MoE expert FFN) —
    neither plan is charged the add.
    """
    t, d, f = tokens, d_model, d_ff
    act_td = t * d * dtype_bytes
    act_tf = t * f * dtype_bytes
    w_up = d * f * dtype_bytes
    w_down = f * d * dtype_bytes
    n_up = 2 if gated else 1
    norm_vec = _prenorm_vec_bytes(d, prenorm, dtype_bytes)
    if fused:
        up = act_td + n_up * w_up + act_tf + norm_vec
        down = act_tf + w_down + act_td + (act_td if residual else 0)
        total = up + down
    else:
        norm_pass = (2 * act_td + norm_vec) if prenorm != "none" else 0
        up = n_up * (act_td + w_up + act_tf)
        glu = (3 if gated else 2) * act_tf  # read h_gate[, h_in], write h
        down = act_tf + w_down + act_td
        resid = 3 * act_td if residual else 0  # read out, read x, write out
        total = norm_pass + up + glu + down + resid
    flops = 2.0 * t * f * d * (n_up + 1)
    if prenorm != "none":
        flops += 8.0 * t * d  # one norm pass (~8 vector ops/element)
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


def qkv_rope_chain_model(*, tokens: int, d_model: int, num_heads: int,
                         num_kv_heads: int, head_dim: int,
                         dtype_bytes: int = 2, prenorm: str = "none",
                         rope: bool = True,
                         fused: bool = True, chip: ChipSpec = V5E) -> dict:
    """The attention [pre-norm +] QKV-projection [→ RoPE] chain.

    fused (two launches): one GEMM over the pre-packed ``wqk`` weight
    produces rope(norm(x)@[wq|wk]) with the rotation applied to the
    resident output tiles, a second produces v — x is read twice, q/k never
    round-trip HBM for the rotation, and with ``prenorm`` each GEMM folds
    the norm into its A-tile prologue (the normed activation never exists
    in HBM; both launches stream the gamma/beta rows). ``[wq|wk]`` is
    packed at param-build time, so no in-graph concat is charged — the
    fused plan wins at every token count (it strictly removes passes).
    unfused: [standalone norm +] three projection GEMMs (norm(x) read each
    time) + a rope pass that re-reads and re-writes q and k.

    ``rope=False`` is the rope-free QKV chain (BERT/Whisper/enc-dec blocks,
    and 'partial'-rope blocks whose rotation runs on the split heads): no
    tables stream and no rope pass exists, and the honest unfused baseline
    is the *packed* two-GEMM eager path (x read twice, not three times) —
    so without a folded pre-norm fused and unfused tie and the plan stays
    unfused; the rope-free fusion's entire win IS the norm fold.
    """
    t = tokens
    nq = num_heads * head_dim
    nkv = num_kv_heads * head_dim
    x_read = t * d_model * dtype_bytes
    w = d_model * (nq + 2 * nkv) * dtype_bytes
    qkv_write = t * (nq + 2 * nkv) * dtype_bytes
    tables = (2 * t * head_dim * 4) if rope else 0  # f32 sin/cos, dup halves
    norm_vec = _prenorm_vec_bytes(d_model, prenorm, dtype_bytes)
    if fused:
        total = 2 * x_read + w + qkv_write + tables + 2 * norm_vec
    else:
        norm_pass = (2 * x_read + norm_vec) if prenorm != "none" else 0
        rope_rw = 2 * t * (nq + nkv) * dtype_bytes if rope else 0
        n_reads = 3 if rope else 2
        total = norm_pass + n_reads * x_read + w + qkv_write + tables + rope_rw
    flops = 2.0 * t * d_model * (nq + 2 * nkv)
    if prenorm != "none":
        # fused: both launches re-norm their A tiles; unfused: one pass
        flops += 8.0 * tokens * d_model * (2 if fused else 1)
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


def mlp_chain_bwd_model(*, tokens: int, d_model: int, d_ff: int,
                        dtype_bytes: int = 2, gated: bool = True,
                        residual: bool = True, prenorm: str = "none",
                        fused: bool = True, chip: ChipSpec = V5E) -> dict:
    """Backward of the MLP hot chain (DESIGN.md §11), fused vs oracle.

    fused (the kernel-side chain transpose):
      saves       the fwd launches write the raw accumulators the transpose
                  needs, in the MXU input dtype: the up-GEMM preact(s)
                  (T, F) and — for the scaled-residual down store — the
                  down preact (T, D)
      down bwd    dH launch reads g + preact + w_out, writes dh; dW launch
                  reads h + g + preact, writes w_out. dresidual is the
                  identity (no pass); dscale is DCE'd (residual_scale is a
                  constant in the model layers)
      up bwd      dX launch reads g_h + the preacts + both up weights
                  (+ raw x and the gamma/beta rows when the pre-norm is
                  folded: the norm transpose runs tile-wise in the store),
                  writes dx; dW launch reads x (renormed tile-wise — the
                  normed activation is never re-materialized) + g_h + the
                  preacts, writes both up weights
    unfused (the oracle-recompute VJP — autodiff of the unfused jnp chain):
      the whole unfused fwd chain re-materializes (remat), then every op's
      transpose re-reads its saved operands: the scaled-residual pass, the
      down GEMM's two bwd GEMMs, the 5-pass GLU transpose, both up GEMMs'
      bwd pairs (dxn accumulated across them), and the standalone norm bwd.
    """
    t, d, f = tokens, d_model, d_ff
    act_td = t * d * dtype_bytes
    act_tf = t * f * dtype_bytes
    w_up = d * f * dtype_bytes
    w_down = f * d * dtype_bytes
    n_up = 2 if gated else 1
    norm_vec = _prenorm_vec_bytes(d, prenorm, dtype_bytes)
    # saved preactivations round through the MXU input dtype (fp32 launches
    # save exactly; bf16 pays the same rounding the operands already did);
    # the scale-carrying down store keeps fp32 (its dscale reduction
    # consumes the operand's full precision)
    preact_tf = t * f * dtype_bytes
    preact_td = t * d * 4
    if fused:
        saves = n_up * preact_tf + (preact_td if residual else 0)
        down_pre = preact_td if residual else 0
        down_dh = act_td + down_pre + w_down + act_tf
        down_dw = act_tf + act_td + down_pre + w_down
        up_dx = act_tf + n_up * preact_tf + n_up * w_up + act_td
        up_dw = act_td + act_tf + n_up * preact_tf + n_up * w_up
        if prenorm != "none":
            up_dx += act_td + norm_vec   # raw x for the norm transpose
            up_dw += norm_vec            # gamma rows for the tile renorm
        total = saves + down_dh + down_dw + up_dx + up_dw
    else:
        recompute = mlp_chain_model(
            tokens=t, d_model=d, d_ff=f, dtype_bytes=dtype_bytes,
            gated=gated, residual=residual, prenorm=prenorm, fused=False,
            chip=chip)["dma_bytes"]
        resid_b = 2 * act_td if residual else 0   # dm = scale*g pass
        down_b = (act_td + w_down + act_tf) + (act_tf + act_td + w_down)
        glu_b = (5 if gated else 3) * act_tf
        up_b = n_up * (act_tf + w_up + act_td) \
            + n_up * (act_td + act_tf + w_up)
        norm_b = (3 * act_td + norm_vec) if prenorm != "none" else 0
        total = recompute + resid_b + down_b + glu_b + up_b + norm_b
    flops = 2 * 2.0 * t * f * d * (n_up + 1)   # dA + dB per fwd GEMM
    if not fused:
        flops *= 1.5                            # + the fwd recompute
    if prenorm != "none":
        flops += 8.0 * t * d
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


def qkv_rope_chain_bwd_model(*, tokens: int, d_model: int, num_heads: int,
                             num_kv_heads: int, head_dim: int,
                             dtype_bytes: int = 2, prenorm: str = "none",
                             rope: bool = True,
                             fused: bool = True,
                             chip: ChipSpec = V5E) -> dict:
    """Backward of the QKV-projection [→ RoPE] chain (DESIGN.md §11).

    fused: the rope epilogue is linear, so no preactivation is saved — the
    rotation adjoint runs on the g tiles as they stream into both bwd
    launches of the qk GEMM (tables re-streamed), the v GEMM transposes
    plainly, and with a folded pre-norm both dW launches renorm their A
    stream tile-wise while the dX launch runs the norm transpose in its
    store. unfused: the oracle-recompute VJP re-materializes the whole
    unfused fwd chain, then pays the rope transpose pass and each GEMM's
    materialized bwd pair plus the standalone norm bwd. ``rope=False``
    drops the tables and the rope transpose pass on both sides (see the
    fwd model for why the rope-free unfused baseline is the packed
    two-GEMM path).
    """
    t = tokens
    nq = num_heads * head_dim
    nkv = num_kv_heads * head_dim
    nqk = nq + nkv
    x_b = t * d_model * dtype_bytes
    gqk_b = t * nqk * dtype_bytes
    gv_b = t * nkv * dtype_bytes
    wqk_b = d_model * nqk * dtype_bytes
    wv_b = d_model * nkv * dtype_bytes
    tables = (2 * t * head_dim * 4) if rope else 0
    norm_vec = _prenorm_vec_bytes(d_model, prenorm, dtype_bytes)
    if fused:
        qk_dx = gqk_b + tables + wqk_b + x_b
        qk_dw = x_b + gqk_b + tables + wqk_b
        v_dx = gv_b + wv_b + x_b
        v_dw = x_b + gv_b + wv_b
        if prenorm != "none":
            qk_dx += x_b + norm_vec
            qk_dw += norm_vec
            v_dw += norm_vec
        dx_add = 3 * x_b   # dx_qk + dx_v summed in one jnp pass
        total = qk_dx + qk_dw + v_dx + v_dw + dx_add
    else:
        recompute = qkv_rope_chain_model(
            tokens=t, d_model=d_model, num_heads=num_heads,
            num_kv_heads=num_kv_heads, head_dim=head_dim,
            dtype_bytes=dtype_bytes, prenorm=prenorm, rope=rope,
            fused=False, chip=chip)["dma_bytes"]
        rope_b = (2 * t * (nq + nkv) * dtype_bytes + tables) if rope else 0
        gemm_b = (gqk_b + wqk_b + x_b) + (x_b + gqk_b + wqk_b) \
            + (gv_b + wv_b + x_b) + (x_b + gv_b + wv_b)
        norm_b = (3 * x_b + norm_vec) if prenorm != "none" else 0
        dx_add = 3 * x_b
        total = recompute + rope_b + gemm_b + norm_b + dx_add
    flops = 2 * 2.0 * t * d_model * (nq + 2 * nkv)
    if not fused:
        flops *= 1.5
    if prenorm != "none":
        flops += 8.0 * t * d_model
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


def gemm_epilogue_model(*, m: int, n: int, k: int, dtype_bytes: int = 2,
                        bias: bool = False, activation: bool = False,
                        gate: bool = False, residual: bool = False,
                        fused: bool = True, chip: ChipSpec = V5E) -> dict:
    """One GEMM + its epilogue chain, fused vs the eager per-op sequence
    (the bench_gemm epilogue-sweep column)."""
    a_b = m * k * dtype_bytes
    w = k * n * dtype_bytes
    out = m * n * dtype_bytes
    n_mm = 2 if gate else 1
    if fused:
        total = a_b + n_mm * w + out
        if bias:
            total += n * dtype_bytes
        if residual:
            total += out
    else:
        total = n_mm * (a_b + w + out)      # each GEMM writes its own C
        if gate:
            total += 3 * out                # act(C1)*C2: read both, write h
        elif activation:
            total += 2 * out
        if bias:
            total += 2 * out + n * dtype_bytes
        if residual:
            total += 3 * out
    flops = n_mm * 2.0 * m * n * k
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


# ---------------------------------------------------------------------------
# Memory-bound elementwise kernels (paper Fig. 9) — activation-pass counts
# shared by bench_memory_bound (no more hand-computed byte constants there).
# ---------------------------------------------------------------------------


def dropout_residual_ln_traffic(rows: int, d: int, *, dtype_bytes: int = 4,
                                fused: bool = True) -> int:
    """Fused: read x + residual, write normed + new-residual (the keep mask
    is generated in-kernel). Unfused eager chain: dropout (read x, write
    xd) + residual add (read xd, read residual, write r2) + layernorm
    (read r2, write out) = 7 activation passes."""
    return (4 if fused else 7) * rows * d * dtype_bytes


def rope_traffic(batch: int, heads: int, seq: int, head_dim: int, *,
                 dtype_bytes: int = 4, fused: bool = True) -> int:
    """Fused rotary kernel: read x, write out, stream the f32 tables once
    per sequence block. Unfused eager: slice/negate/concat materializes the
    rotated half (read x, write rot), then two table multiplies and an add
    over full tensors (read x + rot, write out) = 5 passes."""
    x_bytes = batch * heads * seq * head_dim * dtype_bytes
    tables = 2 * seq * head_dim * 4
    return (2 if fused else 5) * x_bytes + tables

def attention_step_model(*, block_q: int, block_kv: int, head_dim: int,
                         seq_len: int, causal: bool, dtype_bytes: int = 2,
                         chip: ChipSpec = V5E) -> dict:
    kv_steps = seq_len // block_kv
    if causal:
        kv_steps = (kv_steps + 1) / 2  # average over query blocks
    flops_per_kv = 2 * block_q * block_kv * head_dim * 2  # qk^T and pv
    vector_ops = block_q * block_kv * 5                   # softmax vector work
    compute_s = (flops_per_kv / chip.peak_flops(dtype_bytes)
                 + vector_ops / chip.vector_throughput())
    dma = (block_kv * head_dim * 2) * dtype_bytes          # K and V blocks
    memory_s = dma / chip.hbm_bw
    steady = max(compute_s, memory_s)
    total = memory_s + kv_steps * steady
    useful_flops = 2 * block_q * seq_len * head_dim * 2 * (0.5 if causal else 1.0)
    return dict(block=(block_q, block_kv), compute_s=compute_s,
                memory_s=memory_s, modeled_tflops=useful_flops / total / 1e12,
                bound="compute" if compute_s >= memory_s else "memory")


# ---------------------------------------------------------------------------
# Attention chain models (DESIGN.md §12): the flash kernel + its epilogue
# stages vs the eager XLA chain that materializes the (Sq, Skv) score
# matrix. These are whole-chain traffic models (every tensor streamed once;
# the per-launch KV-revisit refinement lives in autotune.score_policy), so
# select_fusion can put an attention sublayer on the same dma_bytes scale
# as the mlp/qkv_rope plans and score a whole transformer block. The ratio
# unfused/fused ≈ 4·S/d — which is exactly why the paper's d=64 cells are
# the headline: halving d doubles the relative cost of score-matrix traffic.
# ---------------------------------------------------------------------------


def attention_chain_model(*, batch: int, heads: int, kv_heads: int,
                          seq_q: int, seq_kv: int, head_dim: int,
                          causal: bool = True, softcap: bool = False,
                          sink: bool = False, dtype_bytes: int = 2,
                          fused: bool = True, chip: ChipSpec = V5E) -> dict:
    """Flash attention + epilogue stages (softcap/sink) vs the eager chain.

    fused: q and out stream once per query head, k and v once per kv head,
    plus the (B, H, Sq) f32 lse residual write and — with a sink — one f32
    scalar per head; the tanh cap is free (vector work on resident tiles).
    unfused (the eager einsum baseline `attention_ref` models): the same
    operand streams plus the f32 score matrix round-tripping HBM — write s,
    read+write for mask+softmax, read for p@v = 4 passes (causal halves the
    live score area), and a softcap adds its own read+write pass. The sink
    column rides the softmax pass either way.
    """
    b, h, hkv = batch, heads, kv_heads
    kv_frac = 0.5 if causal else 1.0
    qo = 2 * b * h * seq_q * head_dim * dtype_bytes
    kv = 2 * b * hkv * seq_kv * head_dim * dtype_bytes
    lse = b * h * seq_q * 4
    sink_b = h * 4 if sink else 0
    flops = 4.0 * b * h * seq_q * seq_kv * head_dim * kv_frac
    if fused:
        total = qo + kv + lse + sink_b
    else:
        smat = b * h * seq_q * seq_kv * kv_frac * 4   # one f32 score pass
        passes = 6 if softcap else 4
        total = qo + kv + passes * smat + sink_b
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


def attention_chain_bwd_model(*, batch: int, heads: int, kv_heads: int,
                              seq_q: int, seq_kv: int, head_dim: int,
                              causal: bool = True, softcap: bool = False,
                              sink: bool = False, dtype_bytes: int = 2,
                              fused: bool = True,
                              chip: ChipSpec = V5E) -> dict:
    """Backward under the attention saved-preact convention: the fwd saves
    (out, lse) and nothing else — softcap recomputes the raw logits from
    the streamed q/k tiles, the sink mass is already inside lse (dsink is a
    jnp reduction over (lse, delta)).

    fused: the delta preprocess (read do + out, write delta) + the dq pass
    (stream q/k/v/do + lse/delta, write dq) + the dkv pass (same streams,
    write dk/dv per *query* head — the paper's GQA-bwd strategy) + the
    jnp group reduction (read per-head dk/dv, write per-kv-head) when
    GQA. unfused: the eager chain's recompute (score matrix and p
    re-materialize) plus its transpose — p read for dv, dp and ds written
    and read back, ds's two GEMM reads ≈ 6 score-matrix passes (8 with
    softcap's extra tanh/grad pass pair) on top of re-streamed operands
    and the dq/dk/dv writes.
    """
    b, h, hkv = batch, heads, kv_heads
    kv_frac = 0.5 if causal else 1.0
    db = dtype_bytes
    q_b = b * h * seq_q * head_dim * db
    kv_b = 2 * b * hkv * seq_kv * head_dim * db
    vec = b * h * seq_q * 4                      # lse or delta, each
    sink_b = h * 4 if sink else 0
    flops = 2.5 * 4.0 * b * h * seq_q * seq_kv * head_dim * kv_frac
    if fused:
        delta_pass = 2 * q_b + vec               # read do + out, write delta
        dq_pass = 2 * q_b + kv_b + 2 * vec + q_b
        dkv_pass = 2 * q_b + kv_b + 2 * vec + 2 * b * h * seq_kv * head_dim * db
        reduce = (2 * b * h * seq_kv * head_dim * db + kv_b) if h != hkv else 0
        dsink_pass = 2 * vec if sink else 0      # re-read lse + delta in jnp
        total = delta_pass + dq_pass + dkv_pass + reduce + dsink_pass + sink_b
    else:
        recompute = attention_chain_model(
            batch=b, heads=h, kv_heads=hkv, seq_q=seq_q, seq_kv=seq_kv,
            head_dim=head_dim, causal=causal, softcap=softcap, sink=sink,
            dtype_bytes=db, fused=False, chip=chip)["dma_bytes"]
        smat = b * h * seq_q * seq_kv * kv_frac * 4
        passes = 8 if softcap else 6
        operands = 2 * q_b + kv_b                # q, do, k, v re-streamed
        writes = q_b + kv_b                      # dq + dk/dv (per kv head)
        total = recompute + passes * smat + operands + writes
        flops *= 1.5                             # the fwd recompute
    return _chain_dict(total, flops, fused, dtype_bytes, chip)


# ---------------------------------------------------------------------------
# Backward-mode routing model (DESIGN.md §15; unblocks PR 5's deferred
# bwd-plan-aware routing). Scores gemm_fused's two VJP strategies on a
# common scale so `bwd_mode="auto"` can pick per shape:
#   kernel     the kernel-side fused chain transpose — lower bwd traffic,
#              but the fwd must SAVE the raw preactivations, which charges a
#              peak-memory residency term (those tensors sit in HBM from fwd
#              until bwd; on a training step that residency is what OOMs
#              first, so it is priced, not just counted).
#   reference  the oracle-recompute VJP (remat): ~1.5x the FLOPs (the fwd
#              chain re-materializes) and eager per-op traffic, but nothing
#              saved — zero residency.
# Degenerate shapes (tiny K, huge M·N) make the kernel plan's saved-preact
# traffic + residency dominate its GEMM savings; there the oracle wins.
# ---------------------------------------------------------------------------

# Seconds charged per byte-of-residency/hbm_bw: how much one byte parked in
# HBM between fwd and bwd "costs" relative to streaming it once. 4x ≈ the
# activation-lifetime/step-time ratio of the pipelined trainer — enough to
# flip degenerate cells without disturbing train-shaped ones (k >= ~1024
# stays on the kernel path at V5E ratios).
PEAK_RESIDENCY_FACTOR = 4.0


def gemm_bwd_route_model(*, m: int, n: int, k: int, dtype_bytes: int = 2,
                         n_saved: int = 0, preact_bytes: int = 2,
                         gated: bool = False, prenorm: bool = False,
                         chip: ChipSpec = V5E) -> dict:
    """Score the fused-kernel vs oracle-recompute VJP for one gemm_fused
    call of shape (m, k) @ (k, n) with ``n_saved`` saved preactivation
    accumulators of ``preact_bytes``/element.

    Returns both strategies' roofline times plus the residency-priced
    ``score`` each; ``route`` is the argmin. The byte models mirror
    mlp_chain_bwd_model's counting at single-GEMM granularity.
    """
    a_b = m * k * dtype_bytes
    g_b = m * n * dtype_bytes
    w_b = k * n * dtype_bytes * (2 if gated else 1)
    save_b = n_saved * m * n * preact_bytes
    # kernel plan: fwd writes the saves; dA reads g + weights + saves, writes
    # dA; dB reads A + g + saves, writes dB (dual-output when gated). A
    # folded prenorm re-reads raw A in the dA launch for the norm transpose.
    da_b = g_b + w_b + save_b + a_b
    db_b = a_b + g_b + save_b + w_b
    kernel_bytes = save_b + da_b + db_b + (a_b if prenorm else 0)
    kernel_flops = (2 if gated else 1) * 4.0 * m * n * k
    # oracle plan: remat the eager fwd chain, then each op's materialized
    # transpose — per-op reads/writes of the (m, n) intermediates dominate.
    n_up = 2 if gated else 1
    recompute_b = a_b + w_b + (n_up + 2) * g_b
    bwd_gemms_b = (g_b + w_b + a_b) + (a_b + g_b + w_b)
    chain_b = 3 * n_up * g_b          # per-stage transpose passes
    ref_bytes = recompute_b + bwd_gemms_b + chain_b
    ref_flops = 1.5 * kernel_flops    # the bwd pairs + the fwd recompute
    pf = chip.peak_flops(dtype_bytes)
    kernel_t = max(kernel_flops / pf, kernel_bytes / chip.hbm_bw)
    ref_t = max(ref_flops / pf, ref_bytes / chip.hbm_bw)
    residency_s = PEAK_RESIDENCY_FACTOR * save_b / chip.hbm_bw
    kernel_score = kernel_t + residency_s
    return dict(kernel_bytes=int(kernel_bytes), reference_bytes=int(ref_bytes),
                kernel_flops=kernel_flops, reference_flops=ref_flops,
                kernel_time_s=kernel_t, reference_time_s=ref_t,
                peak_save_bytes=int(save_b), residency_s=residency_s,
                kernel_score=kernel_score, reference_score=ref_t,
                route="kernel" if kernel_score <= ref_t else "reference")


# ---------------------------------------------------------------------------
# Serving-path models (DESIGN.md §14): prefill traffic under prefix caching
# and the speculative verify round. These put modeled-v5e numbers behind the
# serve benchmark's derived columns, the same way decode_step_model backs
# the decode sweep.
# ---------------------------------------------------------------------------


def serve_prefill_model(*, tokens: int, total_tokens: int, d_model: int,
                        n_layers: int, num_heads: int, kv_heads: int,
                        head_dim: int, d_ff: int, dtype_bytes: int = 2,
                        chip: ChipSpec = V5E) -> dict:
    """Model one prompt prefill that computes ``tokens`` new positions of a
    ``total_tokens``-long prompt.

    ``tokens == total_tokens`` is the cold path; ``tokens < total_tokens``
    is the prefix-cached suffix path (the cached prefix contributes KV
    stream to the suffix's attention but no QKV/MLP compute and no KV
    writes). Weights stream once per launch regardless of token count, so
    short suffixes are weight-bound — exactly why prefix caching pays: the
    per-token GEMM work (``flops``) is what the hit removes.
    """
    w_attn = (d_model * (num_heads + 2 * kv_heads) * head_dim
              + num_heads * head_dim * d_model)
    w_mlp = 3 * d_model * d_ff                   # gate/up/down
    weight_bytes = n_layers * (w_attn + w_mlp) * dtype_bytes
    # activations round-trip per computed token; new KV is written once,
    # and the suffix's attention re-streams the cached prefix KV
    act_bytes = n_layers * tokens * (6 * d_model
                                     + 2 * kv_heads * head_dim) * dtype_bytes
    prefix_kv_bytes = (n_layers * 2 * kv_heads * head_dim
                       * (total_tokens - tokens) * dtype_bytes)
    gemm_flops = n_layers * 2.0 * tokens * (w_attn + w_mlp)
    # causal attention over the full (cached + computed) context; the mean
    # visible prefix of the computed span is total - tokens/2
    attn_flops = (n_layers * 4.0 * num_heads * head_dim * tokens
                  * (total_tokens - tokens / 2.0))
    flops = gemm_flops + attn_flops
    dma_bytes = weight_bytes + act_bytes + prefix_kv_bytes
    compute_s = flops / chip.peak_flops(dtype_bytes)
    memory_s = dma_bytes / chip.hbm_bw
    return dict(tokens=tokens, total_tokens=total_tokens, flops=flops,
                gemm_flops=gemm_flops, dma_bytes=int(dma_bytes),
                weight_bytes=int(weight_bytes), compute_s=compute_s,
                memory_s=memory_s, time_s=max(compute_s, memory_s),
                bound="compute" if compute_s >= memory_s else "memory")


def spec_verify_model(*, batch: int, kv_heads: int, group: int, kv_len: int,
                      head_dim: int, block_kv: int, q_tokens: int,
                      mean_accepted: float, draft_cost_frac: float = 0.15,
                      dtype_bytes: int = 2, chip: ChipSpec = V5E) -> dict:
    """Model one speculative round against serial decode.

    The verify launch streams the KV pool ONCE for ``q_tokens`` query rows
    (they ride in the q tile next to the GQA group), where serial decode
    would stream it ``mean_accepted`` times — that traffic ratio is the
    whole speedup, bounded by the acceptance rate. ``draft_cost_frac`` is
    one draft micro-step's cost relative to a target decode step (a k-times
    smaller draft ≈ 1/k the weight+KV stream).
    """
    verify = decode_step_model(batch=batch, kv_heads=kv_heads,
                               group=group * q_tokens, kv_len=kv_len,
                               head_dim=head_dim, block_kv=block_kv,
                               dtype_bytes=dtype_bytes, chip=chip)
    serial = decode_step_model(batch=batch, kv_heads=kv_heads, group=group,
                               kv_len=kv_len, head_dim=head_dim,
                               block_kv=block_kv, dtype_bytes=dtype_bytes,
                               chip=chip)
    round_s = verify["time_s"] * (1.0 + draft_cost_frac * q_tokens)
    serial_s = mean_accepted * serial["time_s"]
    return dict(q_tokens=q_tokens, mean_accepted=mean_accepted,
                verify_time_s=verify["time_s"], round_time_s=round_s,
                serial_time_s=serial_s,
                speedup_vs_serial=serial_s / round_s if round_s else 0.0,
                kv_stream_ratio=(mean_accepted * serial["kv_bytes"]
                                 / verify["kv_bytes"]
                                 if verify["kv_bytes"] else 0.0))


# ---------------------------------------------------------------------------
# Collective chain models (DESIGN.md §16): the interconnect term.
#
# The fusion subsystem's decisions stay bytes-driven (select_fusion ranks
# plans from modeled dma_bytes alone); these helpers extend that discipline
# across chips. A collective's wire bytes are priced against the ICI
# roofline (chip.ici_bw_per_link * chip.ici_links) and expressed back in
# HBM-time-equivalent bytes, so a sharded plan's score is still "modeled
# bytes" — just bytes on two fabrics. The overlap columns model the paper's
# DMA/MMA async-worker pattern one level up: a ring collective's hops hide
# under the fused panel launches they feed.
# ---------------------------------------------------------------------------


def collective_wire_bytes(kind: str, nbytes: float, n_shards: int) -> float:
    """Per-chip wire bytes of one ring collective over ``n_shards``.

    ``nbytes`` is the full logical buffer (all_gather output / reduce_scatter
    input / all_to_all local send buffer). Ring algorithms move (n-1)/n of
    it per chip; all_reduce = reduce_scatter + all_gather moves it twice.
    """
    if n_shards <= 1 or kind == "none":
        return 0.0
    frac = (n_shards - 1) / n_shards
    if kind == "all_reduce":
        return 2.0 * nbytes * frac
    if kind in ("all_gather", "reduce_scatter", "all_to_all"):
        return nbytes * frac
    raise ValueError(f"unknown collective kind {kind!r}")


def collective_model(kind: str, nbytes: float, *, n_shards: int,
                     chip: ChipSpec = V5E) -> dict:
    """One collective's wire bytes + ICI-roofline time + ring step count."""
    wire = collective_wire_bytes(kind, nbytes, n_shards)
    bw = chip.ici_bw_per_link * chip.ici_links
    return dict(kind=kind, wire_bytes=int(wire), collective_s=wire / bw,
                steps=max(0, n_shards - 1))


def hbm_equivalent_bytes(wire_bytes: float, chip: ChipSpec = V5E) -> float:
    """Wire bytes expressed in HBM-time-equivalent bytes — the unit that
    lets select_fusion keep ranking sharded plans from bytes alone."""
    return wire_bytes * chip.hbm_bw / (chip.ici_bw_per_link * chip.ici_links)


def collective_chain_model(chain: dict, *, collective: str, nbytes: float,
                           n_shards: int, chip: ChipSpec = V5E) -> dict:
    """Attach one collective's interconnect term to a §9-§12 chain dict.

    Returns a new chain dict where ``dma_bytes`` additionally carries the
    wire bytes in HBM-equivalent units (``hbm_dma_bytes`` keeps the pure
    HBM term), ``time_s`` is the overlapped step time, and
    ``overlap_fraction`` is the share of the collective hidden under the
    chain's compute/memory time (0 when there is nothing to hide behind).
    """
    coll = collective_model(collective, nbytes, n_shards=n_shards, chip=chip)
    d = dict(chain)
    cs = coll["collective_s"]
    chain_s = d["time_s"]
    d.update(
        collective=collective,
        collective_bytes=coll["wire_bytes"],
        collective_s=cs,
        serialized_s=chain_s + cs,
        overlapped_s=max(chain_s, cs),
        overlap_fraction=(min(chain_s, cs) / cs) if cs > 0 else 0.0,
        hbm_dma_bytes=d["dma_bytes"],
        dma_bytes=int(d["dma_bytes"]
                      + hbm_equivalent_bytes(coll["wire_bytes"], chip)),
        time_s=max(chain_s, cs))
    return d


def collective_gemm_model(*, m: int, n: int, k: int, n_shards: int,
                          dtype_bytes: int = 2, variant: str = "all_gather",
                          fused: bool = True, chip: ChipSpec = V5E) -> dict:
    """Ring-overlapped collective GEMM vs gather-then-GEMM (DESIGN.md §16).

    (m, n, k) is the FULL logical GEMM. ``variant``:
      all_gather      A is row-sharded; the ring circulates A panels while
                      each previously-arrived panel's GEMM runs.
      reduce_scatter  the contraction dim is sharded; the ring circulates
                      fp32 output-panel accumulators between partial-panel
                      GEMMs.

    fused=True is the ring plan: S panel launches, hop i+1 in flight under
    panel i's compute, and no HBM round-trip for the gathered operand.
    fused=False is the serialized baseline: run the collective, materialize
    its result in HBM (one write + one read of the moved buffer), then one
    big GEMM. The byte difference is what select_fusion ranks on; the
    overlap_fraction column is the ring's hidden-communication share.
    """
    flops = 2.0 * m * n * k
    gemm_bytes = float(m * k + k * n + m * n) * dtype_bytes
    if variant == "all_gather":
        moved = float(m * k) * dtype_bytes
    elif variant == "reduce_scatter":
        moved = float(m * n) * 4            # fp32 accumulator panels
    else:
        raise ValueError(f"unknown collective-GEMM variant {variant!r}")
    coll = collective_model(variant, moved, n_shards=n_shards, chip=chip)
    cs = coll["collective_s"]
    if fused:
        chain = _chain_dict(gemm_bytes, flops, True, dtype_bytes, chip)
        s = max(1, n_shards)
        step_s = chain["time_s"] / s
        hop_s = cs / max(1, s - 1) if s > 1 else 0.0
        overlapped = step_s + (s - 1) * max(step_s, hop_s)
        serialized = chain["time_s"] + cs
        hidden = max(0.0, serialized - overlapped)
        chain.update(collective=variant,
                     collective_bytes=coll["wire_bytes"], collective_s=cs,
                     serialized_s=serialized, overlapped_s=overlapped,
                     overlap_fraction=min(1.0, hidden / cs) if cs > 0 else 0.0,
                     hbm_dma_bytes=chain["dma_bytes"],
                     dma_bytes=int(gemm_bytes
                                   + hbm_equivalent_bytes(coll["wire_bytes"],
                                                          chip)),
                     time_s=overlapped, ring_steps=s)
        return chain
    # gather-then-GEMM: the moved buffer round-trips HBM before the launch
    chain = _chain_dict(gemm_bytes + 2.0 * moved, flops, False, dtype_bytes,
                        chip)
    chain.update(collective=variant, collective_bytes=coll["wire_bytes"],
                 collective_s=cs, serialized_s=chain["time_s"] + cs,
                 overlapped_s=chain["time_s"] + cs, overlap_fraction=0.0,
                 hbm_dma_bytes=chain["dma_bytes"],
                 dma_bytes=int(chain["dma_bytes"]
                               + hbm_equivalent_bytes(coll["wire_bytes"],
                                                      chip)),
                 time_s=chain["time_s"] + cs, ring_steps=1)
    return chain


def partial_softmax_allreduce_model(*, rows: int, head_dim: int,
                                    n_shards: int,
                                    chip: ChipSpec = V5E) -> dict:
    """The sequence-parallel KV term (cache_specs): a decode step over a
    'model'-sharded kv axis lowers to per-shard partial softmax + one tiny
    all-reduce of (m, l, weighted-sum) stats — (head_dim + 2) fp32 per
    (batch, head) row."""
    nbytes = float(rows) * (head_dim + 2) * 4
    return collective_model("all_reduce", nbytes, n_shards=n_shards,
                            chip=chip)
