"""TPU v5e roofline constants and analytic kernel pipeline model.

Used three ways:
  * the dry-run roofline terms in EXPERIMENTS.md §Roofline;
  * the Tab. 2/3 reproduction (`benchmarks/bench_schedules.py`) — modeled
    TFLOP/s as a function of output tile, pipeline depth and producer VMEM tax;
  * kernel-level napkin math during the §Perf hillclimb.
"""
from __future__ import annotations

import dataclasses
import math

from . import tiles
from .schedule import Schedule


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # B/s
    ici_bw_per_link: float = 50e9        # B/s per ICI link (about; 2D torus)
    ici_links: int = 4                   # links per chip on a 2D torus
    vmem_bytes: int = tiles.VMEM_BYTES
    mxu_dim: int = 128

    def peak_flops(self, dtype_bytes: int = 2) -> float:
        # v5e matrix unit: int8 is 2x bf16; fp32 via passes ≈ 1/4.
        if dtype_bytes == 1:
            return 2 * self.peak_flops_bf16
        if dtype_bytes == 4:
            return self.peak_flops_bf16 / 4
        return self.peak_flops_bf16


V5E = ChipSpec()


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        # optimistic full-overlap model: the dominant term is the step time
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self) -> float:
        """compute_s / step_time — how close to compute-bound we are."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0


def roofline(flops: float, hbm_bytes: float, collective_bytes: float,
             *, n_chips: int, chip: ChipSpec = V5E,
             dtype_bytes: int = 2) -> RooflineTerms:
    """The three §Roofline terms, in seconds (totals are fleet-wide)."""
    compute = flops / (n_chips * chip.peak_flops(dtype_bytes))
    memory = hbm_bytes / (n_chips * chip.hbm_bw)
    coll = collective_bytes / (n_chips * chip.ici_bw_per_link * chip.ici_links)
    return RooflineTerms(compute, memory, coll)


# ---------------------------------------------------------------------------
# Analytic GEMM pipeline model (paper Tab. 2 reproduction).
# ---------------------------------------------------------------------------

def mxu_efficiency(dim_m: int, dim_n: int, dim_k: int, mxu: int = 128) -> float:
    """Fraction of systolic-array cycles doing useful work for a tile matmul."""
    eff = 1.0
    for d in (dim_m, dim_n, dim_k):
        eff *= d / (math.ceil(d / mxu) * mxu)
    return eff


def gemm_step_model(schedule: Schedule, *, k_total: int, dtype_bytes: int = 2,
                    chip: ChipSpec = V5E) -> dict:
    """Model one grid step of the blocked GEMM under ``schedule``.

    Compute time: bm*bn*bk MACs on the MXU at efficiency from alignment.
    Memory time: (A+B block) DMA at HBM bandwidth.
    Pipeline: steady-state step time = max(compute, memory) (PINGPONG double
    buffering); deeper pipelines amortize the prologue but raise VMEM use.
    """
    bm, bn, bk = schedule.block_m, schedule.block_n, schedule.block_k
    flops = 2.0 * bm * bn * bk
    eff = mxu_efficiency(bm, bn, bk, chip.mxu_dim)
    compute_s = flops / (chip.peak_flops(dtype_bytes) * eff)
    dma_bytes = (bm * bk + bk * bn) * dtype_bytes
    memory_s = dma_bytes / chip.hbm_bw

    acc_bytes = bm * bn * 4  # fp32 accumulator scratch (pinned, see DESIGN §2)
    vmem = tiles.pipeline_vmem_bytes(
        [((bm, bk), "bfloat16"), ((bk, bn), "bfloat16")],
        n_buffers=schedule.n_buffers, scratch_bytes=acc_bytes)
    feasible = vmem <= schedule.vmem_budget()

    n_steps = max(1, k_total // bk)
    steady = max(compute_s, memory_s)
    prologue = memory_s  # first block load not overlapped
    total = prologue + n_steps * steady
    tflops = (2.0 * bm * bn * k_total) / total / 1e12
    return dict(schedule=schedule.name, block=(bm, bn, bk), feasible=feasible,
                vmem_bytes=vmem, compute_s=compute_s, memory_s=memory_s,
                arithmetic_intensity=flops / dma_bytes,
                modeled_tflops=tflops if feasible else 0.0,
                bound="compute" if compute_s >= memory_s else "memory")


def best_output_tile(vmem_budget: int, n_buffers: int, block_k: int,
                     dtype_bytes: int = 2) -> tuple[int, int]:
    """Largest square-ish MXU-aligned output tile whose pipeline fits VMEM.

    Reproduces the paper's Tab. 2 argument: VMEM (register) budget bounds the
    output tile, which bounds arithmetic intensity.
    """
    best = (128, 128)
    for bm in (128, 192, 256, 384, 512):
        for bn in (128, 192, 256, 384, 512):
            acc = bm * bn * 4
            vmem = tiles.pipeline_vmem_bytes(
                [((bm, block_k), "bfloat16"), ((block_k, bn), "bfloat16")],
                n_buffers=n_buffers, scratch_bytes=acc)
            if vmem <= vmem_budget and bm * bn > best[0] * best[1]:
                best = (bm, bn)
    return best


# ---------------------------------------------------------------------------
# Split-KV flash-decode model (bandwidth-dominated; paper Fig. 9 regime).
# ---------------------------------------------------------------------------

# Grid steps needed before the Pallas pipeline hides the HBM latency of the
# next K/V block behind the current (tiny) compute step. Below this the
# prologue/epilogue bubbles dominate — the reason split-KV exists: when
# batch*kv_heads is small, splitting the KV axis manufactures grid
# parallelism so the DMA engine stays busy.
DECODE_SATURATION_STEPS = 8
# Per-grid-step fixed cost (s): pipeline bookkeeping per Pallas step. Matches
# the autotuner's step-overhead scale.
DECODE_STEP_OVERHEAD_S = 1e-6


def decode_step_model(*, batch: int, kv_heads: int, group: int,
                      kv_len: int, head_dim: int, block_kv: int,
                      dtype_bytes: int = 2, chip: ChipSpec = V5E) -> dict:
    """Model one split-KV flash-decode launch (q_len=1, GQA group packed).

    Unlike the GEMM/attention models this one is bandwidth-, not FLOP-,
    dominated: each of the ``batch * kv_heads * n_splits`` grid cells streams
    one (block_kv, head_dim) K and V block exactly once, does O(group *
    block_kv * head_dim) MACs (negligible: group <= 16), and writes a
    (group, head_dim) partial + (group,) m/l stats that a jnp log-sum-exp
    combine reduces. Split count trades per-step overhead against pipeline
    fill: too few steps and the DMA queue never saturates HBM.
    """
    n_splits = max(1, kv_len // block_kv)
    n_steps = batch * kv_heads * n_splits
    kv_bytes = 2 * batch * kv_heads * kv_len * head_dim * dtype_bytes
    # q/o traffic + the per-split partials the combine step re-reads
    partial_bytes = batch * kv_heads * n_splits * (group * head_dim + 2 * group) * 4
    qo_bytes = 2 * batch * kv_heads * group * head_dim * dtype_bytes
    util = min(1.0, n_steps / DECODE_SATURATION_STEPS)
    stream_s = kv_bytes / (chip.hbm_bw * util)
    combine_s = 2 * partial_bytes / chip.hbm_bw  # written then re-read
    total = (stream_s + qo_bytes / chip.hbm_bw + combine_s
             + n_steps * DECODE_STEP_OVERHEAD_S)
    flops = 4.0 * batch * kv_heads * group * kv_len * head_dim
    return dict(block_kv=block_kv, n_splits=n_splits, n_steps=n_steps,
                kv_bytes=kv_bytes, partial_bytes=partial_bytes,
                utilization=util, time_s=total,
                achieved_bw=kv_bytes / total if total else 0.0,
                modeled_tflops=flops / total / 1e12 if total else 0.0,
                bound="memory")


# ---------------------------------------------------------------------------
# Flash-attention model (per (batch*heads) × q-block grid step).
# ---------------------------------------------------------------------------

def attention_step_model(*, block_q: int, block_kv: int, head_dim: int,
                         seq_len: int, causal: bool, dtype_bytes: int = 2,
                         chip: ChipSpec = V5E) -> dict:
    kv_steps = seq_len // block_kv
    if causal:
        kv_steps = (kv_steps + 1) / 2  # average over query blocks
    flops_per_kv = 2 * block_q * block_kv * head_dim * 2  # qk^T and pv
    vector_ops = block_q * block_kv * 5                   # softmax vector work
    compute_s = (flops_per_kv / chip.peak_flops(dtype_bytes)
                 + vector_ops / (chip.peak_flops_bf16 / 16))
    dma = (block_kv * head_dim * 2) * dtype_bytes          # K and V blocks
    memory_s = dma / chip.hbm_bw
    steady = max(compute_s, memory_s)
    total = memory_s + kv_steps * steady
    useful_flops = 2 * block_q * seq_len * head_dim * 2 * (0.5 if causal else 1.0)
    return dict(block=(block_q, block_kv), compute_s=compute_s,
                memory_s=memory_s, modeled_tflops=useful_flops / total / 1e12,
                bound="compute" if compute_s >= memory_s else "memory")
