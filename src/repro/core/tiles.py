"""Tile abstractions with TPU-native alignment rules (HipKittens C1, TPU-adapted).

HipKittens restricts tile rows/columns to multiples of the AMD matrix-core
shape and derives per-instruction swizzles so that every co-occurring access
pattern is bank-conflict free *by construction at tile-creation time*.

On TPU the analogous hazards are:
  * relayout / padding waste when the last two dims of a VMEM block are not
    multiples of the dtype's native tiling (sublane, lane);
  * MXU underutilization when matmul dims are not multiples of 128;
  * VMEM overflow when the pipeline's working set exceeds the ~128 MiB budget.

``TileSpec`` encodes the legality rules; every Pallas BlockSpec in this repo is
built through :func:`block_spec` so misaligned tiles are rejected at trace time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (single core).
# ---------------------------------------------------------------------------
LANE = 128            # minor-dim vector lane count
MXU = 128             # systolic array dimension (128x128)
VMEM_BYTES = 128 * 1024 * 1024   # per-core VMEM budget we target (v5e: 128MiB)
SMEM_BYTES = 1 * 1024 * 1024

# Native (sublane, lane) tiling per element width. A VMEM block whose last two
# dims are multiples of this incurs no relayout/padding.
_SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}


def native_tiling(dtype) -> tuple[int, int]:
    """Return the native (sublane, lane) tile for ``dtype``."""
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize not in _SUBLANE_BY_ITEMSIZE:
        raise ValueError(f"unsupported dtype for tiles: {dtype}")
    return (_SUBLANE_BY_ITEMSIZE[itemsize], LANE)


def is_aligned(shape: Sequence[int], dtype) -> bool:
    """True if the trailing dims of ``shape`` are native-tile multiples."""
    if len(shape) == 0:
        return False
    sub, lane = native_tiling(dtype)
    if len(shape) == 1:
        return shape[-1] % lane == 0
    return shape[-1] % lane == 0 and shape[-2] % sub == 0


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """A 2-D tile of ``dtype`` living in VMEM.

    Mirrors HK's register/shared tiles: shape is validated against the
    hardware-native tiling, exactly as HK validates against MFMA shapes.
    ``pinned`` requests explicit scratch allocation (the TPU analogue of HK's
    pinned register ranges — see DESIGN.md §2).
    """

    rows: int
    cols: int
    dtype: str = "bfloat16"
    pinned: bool = False

    def __post_init__(self):
        sub, lane = native_tiling(self.dtype)
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError(f"tile dims must be positive, got {self.rows}x{self.cols}")
        if self.rows % sub != 0:
            raise ValueError(
                f"tile rows {self.rows} not a multiple of sublane {sub} for {self.dtype}"
            )
        if self.cols % lane != 0:
            raise ValueError(
                f"tile cols {self.cols} not a multiple of lane {lane} for {self.dtype}"
            )

    @property
    def shape(self) -> tuple[int, int]:
        return (self.rows, self.cols)

    @property
    def nbytes(self) -> int:
        return self.rows * self.cols * jnp.dtype(self.dtype).itemsize

    def mxu_aligned(self) -> bool:
        """True if both dims are MXU-dimension multiples (full systolic use)."""
        return self.rows % MXU == 0 and self.cols % MXU == 0


def assert_tile(shape: Sequence[int], dtype, *, what: str = "block") -> None:
    """Raise if the trailing 2 dims of ``shape`` are not a legal tile."""
    if len(shape) < 2:
        if len(shape) == 1 and shape[0] % LANE == 0:
            return
        raise ValueError(f"{what}: shape {tuple(shape)} too small / misaligned")
    TileSpec(shape[-2], shape[-1], str(jnp.dtype(dtype)))


def block_spec(shape: Sequence[int], index_map: Callable, dtype="bfloat16",
               *, allow_ragged_minor: bool = False) -> pl.BlockSpec:
    """Build a Pallas BlockSpec, enforcing native-tiling legality.

    ``allow_ragged_minor`` permits a final dim that is not a LANE multiple
    (e.g. head_dim=64 tiles), which Pallas pads — we account for the padding
    in vmem_bytes but allow it since head_dim 64 attention is a paper
    workload (Fig. 7). With the flag off the contract is strict: *every*
    non-multiple minor dim is rejected, including the lane/2 case (callers on
    the head-dim-64 path must opt in explicitly).
    """
    shape = tuple(shape)
    if not allow_ragged_minor:
        # Trailing-2 dims must be native-tile multiples; leading dims are free.
        trailing = [d for d in shape if d is not None]
        if len(trailing) >= 2:
            sub, lane = native_tiling(dtype)
            r, c = trailing[-2], trailing[-1]
            if c % lane != 0:
                raise ValueError(f"block minor dim {c} not {lane}-aligned "
                                 f"(pass allow_ragged_minor=True to accept "
                                 f"padded tiles, e.g. head_dim 64)")
            if r % sub != 0:
                raise ValueError(f"block sublane dim {r} not {sub}-aligned")
    return pl.BlockSpec(shape, index_map)


def shape_ragged(rows_dim: int, minor_dim: int, dtype) -> bool:
    """True when the *problem* dims themselves are not native-tile aligned.

    Any tiling of an unaligned problem dim pads, so kernels waive
    :func:`block_spec`'s strict gate for exactly these shapes (reproducing
    the padding the pre-policy raw BlockSpecs accepted) while keeping the
    gate active for aligned problems, where a misaligned *block* is a bug.
    """
    sub, lane = native_tiling(dtype)
    return rows_dim % sub != 0 or minor_dim % lane != 0


def compiler_params(*, dimension_semantics: tuple):
    """pltpu compiler params across jax versions (CompilerParams was named
    TPUCompilerParams before jax 0.5)."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics)


def padded_tile_bytes(shape: Sequence[int], dtype) -> int:
    """Bytes a block occupies in VMEM after padding to native tiling."""
    sub, lane = native_tiling(dtype)
    dims = [d for d in shape if d is not None]
    if not dims:
        return 0
    padded = list(dims)
    padded[-1] = math.ceil(padded[-1] / lane) * lane
    if len(padded) >= 2:
        padded[-2] = math.ceil(padded[-2] / sub) * sub
    return math.prod(padded) * jnp.dtype(dtype).itemsize


def pipeline_vmem_bytes(operand_blocks: Sequence[tuple[Sequence[int], object]],
                        *, n_buffers: int = 2,
                        scratch_bytes: int = 0) -> int:
    """Working-set estimate for a pipelined pallas_call.

    Each operand block is multi-buffered ``n_buffers`` deep (the PINGPONG
    schedule uses 2). This is the TPU analogue of HK's register-budget
    accounting in Tab. 2: schedules that blow the budget are rejected.
    """
    total = scratch_bytes
    for shape, dtype in operand_blocks:
        total += n_buffers * padded_tile_bytes(shape, dtype)
    return total


def check_vmem_budget(operand_blocks, *, n_buffers=2, scratch_bytes=0,
                      budget=VMEM_BYTES, what="kernel") -> int:
    used = pipeline_vmem_bytes(operand_blocks, n_buffers=n_buffers,
                               scratch_bytes=scratch_bytes)
    if used > budget:
        raise ValueError(
            f"{what}: VMEM working set {used/2**20:.1f} MiB exceeds budget "
            f"{budget/2**20:.1f} MiB — shrink tiles or pipeline depth"
        )
    return used
