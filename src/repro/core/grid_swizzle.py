"""HipKittens Algorithm 1 — chiplet/cache-aware grid swizzle — TPU-adapted.

The paper remaps flattened GEMM block IDs in two steps:
  1. *XCD grouping*: chunks of ``C`` consecutive remapped IDs land on the same
     XCD under the hardware's round-robin dispatch (reduces cross-chiplet L2
     traffic).
  2. *Hierarchical windowed traversal*: the flattened ID space is folded into
     vertical windows of height ``W`` so blocks sharing rows of A / columns of
     B execute near each other in time (L2 reuse).

On TPU the same permutation controls two real locality levels (DESIGN.md §2):
  * within a core, the Pallas grid pipeline skips the HBM→VMEM DMA for a block
    whose index is unchanged between consecutive iterations — so traversal
    order directly determines DMA traffic (measured by :func:`dma_bytes`);
  * across the mesh, the analogous assignment problem is handled by
    ``distributed/sharding.py``.

All functions are pure and work on python ints, numpy arrays, and traced JAX
values (used inside Pallas ``index_map``s).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

N_XCD_DEFAULT = 8  # paper's MI355X has 8 XCDs; kept as the default cluster count


def _is_traced(*xs) -> bool:
    return any(not isinstance(x, (int, np.integer, np.ndarray)) for x in xs)


def _backend(*xs):
    if _is_traced(*xs):
        import jax.numpy as jnp
        return jnp
    return np


def chiplet_transform_chunked(xy, blocks, n_xcd, chunk):
    """Step 1 of Algorithm 1 (paper's ``chiplet_transform_chunked``).

    Remaps a flattened block id so that, under round-robin dispatch across
    ``n_xcd`` clusters, chunks of ``chunk`` consecutive *remapped* ids are
    resident on the same cluster. Bijective on [0, blocks).
    """
    xp = _backend(xy)
    blocks_per_cycle = n_xcd * chunk
    limit = (blocks // blocks_per_cycle) * blocks_per_cycle
    xcd = xy % n_xcd
    local = xy // n_xcd
    chunk_idx = local // chunk
    pos = local % chunk
    remapped = chunk_idx * blocks_per_cycle + xcd * chunk + pos
    return xp.where(xy >= limit, xy, remapped)


def windowed_traversal(xy, num_rows, num_cols, window):
    """Step 2 of Algorithm 1: fold flattened ids into vertical windows.

    Returns (row, col). Within a window of ``window`` rows the fast index goes
    *down a column* (so the B column-block is reused by ``window`` consecutive
    blocks); after ``win_h`` rows we move to the next column.
    """
    xp = _backend(xy, num_rows, num_cols)
    tid_per_group = window * num_cols
    group_id = xy // tid_per_group
    first_row = group_id * window
    win_h = xp.minimum(num_rows - first_row, window)
    l = xy % tid_per_group
    row = first_row + (l % win_h)
    col = l // win_h
    return row, col


@dataclasses.dataclass(frozen=True)
class SwizzleConfig:
    """Parameters of Algorithm 1. ``window``/``chunk`` trade L2 vs LLC reuse
    in the paper; here they trade B-block revisit runs vs A working-set span."""

    window: int = 8
    chunk: int = 64
    n_xcd: int = N_XCD_DEFAULT
    enable_chiplet: bool = True   # step 1 on/off (off for single-core Pallas use)
    enable_window: bool = True    # step 2 on/off (off => row-major)

    def remap(self, xy, num_rows, num_cols):
        """Full Algorithm 1: flattened id -> (row, col) block coordinates."""
        blocks = num_rows * num_cols
        if self.enable_chiplet:
            xy = chiplet_transform_chunked(xy, blocks, self.n_xcd, self.chunk)
        if self.enable_window:
            return windowed_traversal(xy, num_rows, num_cols, self.window)
        return xy // num_cols, xy % num_cols


ROW_MAJOR = SwizzleConfig(enable_chiplet=False, enable_window=False)


def schedule_order(cfg: SwizzleConfig, num_rows: int, num_cols: int) -> np.ndarray:
    """(blocks, 2) array of (row, col) in execution order — for simulators."""
    xy = np.arange(num_rows * num_cols)
    r, c = cfg.remap(xy, num_rows, num_cols)
    return np.stack([np.asarray(r), np.asarray(c)], axis=1)


def is_permutation(cfg: SwizzleConfig, num_rows: int, num_cols: int) -> bool:
    """Every output block must be produced exactly once (tested w/ hypothesis)."""
    order = schedule_order(cfg, num_rows, num_cols)
    flat = order[:, 0] * num_cols + order[:, 1]
    return (np.sort(flat) == np.arange(num_rows * num_cols)).all() and \
        (order[:, 0] < num_rows).all() and (order[:, 1] < num_cols).all() and \
        (order >= 0).all()


def dma_bytes(cfg: SwizzleConfig, num_rows: int, num_cols: int,
              a_block_bytes: int, b_block_bytes: int) -> int:
    """HBM→VMEM traffic of a full-K blocked GEMM under Pallas revisit rules.

    The pipeline skips an input DMA iff the block index equals the previous
    iteration's. A blocks are indexed by row, B blocks by col. Note that under
    this *consecutive-only* revisit rule the optimum degenerates to run-length
    maximization on the larger operand (W=1 → row-runs reuse A; W=num_rows →
    column-runs reuse B); the full (W, C) structure of Algorithm 1 pays off at
    the multi-executor cache level, which :mod:`repro.core.cache_model`
    evaluates (see DESIGN.md §2).
    """
    order = schedule_order(cfg, num_rows, num_cols)
    rows, cols = order[:, 0], order[:, 1]
    a_fetches = 1 + int(np.count_nonzero(rows[1:] != rows[:-1]))
    b_fetches = 1 + int(np.count_nonzero(cols[1:] != cols[:-1]))
    return a_fetches * a_block_bytes + b_fetches * b_block_bytes


def best_window(num_rows: int, num_cols: int, a_block_bytes: int,
                b_block_bytes: int, candidates=(1, 2, 4, 8, 16, 32)) -> SwizzleConfig:
    """Pick the window minimizing modeled DMA traffic (autotuning hook)."""
    best = None
    for w in candidates:
        if w > num_rows:
            continue
        cfg = SwizzleConfig(window=w, enable_chiplet=False)
        traffic = dma_bytes(cfg, num_rows, num_cols, a_block_bytes, b_block_bytes)
        if best is None or traffic < best[0]:
            best = (traffic, cfg)
    return best[1] if best else ROW_MAJOR
