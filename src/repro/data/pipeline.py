"""Deterministic, shardable synthetic LM data pipeline.

Properties a production pipeline needs and this one has:
  * *Stateless indexing*: ``batch_at(step)`` is a pure function of
    (seed, step, shard), so resuming from a checkpointed step is exact and
    elastic re-sharding (different data-parallel size on restart) yields the
    same global batch.
  * *Learnable structure*: tokens follow a noisy affine-modular chain
    (next = (a·prev + c) mod V with prob 1-ε, else uniform), so e2e training
    actually reduces loss (used by the paper-parity example).
  * *Document packing*: geometric-length documents packed into fixed
    seq_len windows with a BOS-reset loss mask.
  * *Device placement*: ``global_batch_at`` builds a sharded global array via
    ``jax.make_array_from_callback`` (each host materializes only its shard).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.2         # probability of a uniform-random token
    mean_doc_len: int = 256    # geometric packing
    mult: int = 31             # affine chain multiplier
    add: int = 7


def _rng_for(cfg: DataConfig, step: int, row: int) -> np.random.Generator:
    # Stable per-(step, row) stream — independent of sharding layout.
    return np.random.Generator(np.random.Philox(
        key=cfg.seed, counter=[step, row, 0, 0]))


def _sample_row(cfg: DataConfig, step: int, row: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tokens (S+1,), doc_starts (S+1,) bool) for one packed row."""
    rng = _rng_for(cfg, step, row)
    s = cfg.seq_len + 1
    toks = np.empty(s, np.int32)
    starts = np.zeros(s, bool)
    i = 0
    while i < s:
        doc_len = 1 + rng.geometric(1.0 / cfg.mean_doc_len)
        doc_len = min(doc_len, s - i)
        starts[i] = True
        t = rng.integers(0, cfg.vocab_size)
        for j in range(doc_len):
            toks[i + j] = t
            if rng.random() < cfg.noise:
                t = rng.integers(0, cfg.vocab_size)
            else:
                t = (cfg.mult * t + cfg.add) % cfg.vocab_size
        i += doc_len
    return toks, starts


def batch_rows(cfg: DataConfig, step: int, rows: range) -> dict[str, np.ndarray]:
    pairs = [_sample_row(cfg, step, r) for r in rows]
    toks = np.stack([p[0] for p in pairs])
    starts = np.stack([p[1] for p in pairs])
    inputs = toks[:, :-1]
    targets = toks[:, 1:]
    # no loss where the target starts a new (unrelated) document
    loss_mask = (~starts[:, 1:]).astype(np.float32)
    return {"inputs": inputs, "targets": targets, "loss_mask": loss_mask}


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Full global batch as host arrays (single-host path)."""
    return batch_rows(cfg, step, range(cfg.global_batch))


def global_batch_at(cfg: DataConfig, step: int, mesh,
                    batch_axes=("data",)) -> dict[str, jax.Array]:
    """Sharded global batch: each shard materializes only its rows."""
    out = {}
    sample = batch_rows(cfg, step, range(0, 1))
    for key, proto in sample.items():
        shape = (cfg.global_batch,) + proto.shape[1:]
        sharding = NamedSharding(mesh, P(batch_axes, *([None] * (proto.ndim - 1))))

        def cb(index, key=key):
            rows = index[0]
            start = rows.start or 0
            stop = rows.stop if rows.stop is not None else cfg.global_batch
            return batch_rows(cfg, step, range(start, stop))[key]

        out[key] = jax.make_array_from_callback(shape, sharding, cb)
    return out


class DataIterator:
    """Checkpointable iterator facade: state == the integer step."""

    def __init__(self, cfg: DataConfig, mesh=None, batch_axes=("data",),
                 start_step: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.batch_axes = batch_axes
        self.step = start_step

    def __next__(self):
        if self.mesh is not None:
            b = global_batch_at(self.cfg, self.step, self.mesh, self.batch_axes)
        else:
            b = {k: jnp.asarray(v) for k, v in batch_at(self.cfg, self.step).items()}
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
