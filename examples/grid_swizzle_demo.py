"""Algorithm 1 (chiplet-aware grid swizzle) demo — paper Fig. 5 / Tab. 4.

Prints ASCII visualizations of the block-to-XCD assignment for row-major vs
Algorithm-1 schedules and scores each on the two-level cache simulator.

  PYTHONPATH=src python examples/grid_swizzle_demo.py
"""
import numpy as np

from repro.core.grid_swizzle import SwizzleConfig, ROW_MAJOR, schedule_order
from repro.core.cache_model import simulate_gemm_schedule


def visualize(cfg, num_rows=12, num_cols=12, n_exec=32, n_xcd=8):
    """Show the XCD that computes each output block in the first wave."""
    order = schedule_order(cfg, num_rows, num_cols)
    grid = np.full((num_rows, num_cols), ".", dtype=object)
    for slot, (r, c) in enumerate(order[:n_exec]):
        grid[r, c] = str(slot % n_xcd)
    return "\n".join(" ".join(row) for row in grid)


for name, cfg in (("row-major", ROW_MAJOR),
                  ("Algorithm 1 (W=4, C=4)", SwizzleConfig(window=4, chunk=4)),
                  ("Algorithm 1 (W=8, C=64)", SwizzleConfig(window=8, chunk=64))):
    print(f"\n=== {name} — first 32 blocks by XCD ===")
    print(visualize(cfg))
    r = simulate_gemm_schedule(cfg, m=9216, n=9216, k=9216,
                               block_m=192, block_n=256, block_k=64)
    print(f"cache sim @9216³: L2 {r.l2_hit:.0%}  LLC {r.llc_hit:.0%}  "
          f"eff-BW {r.effective_bw/1e12:.1f} TB/s  "
          f"modeled {r.modeled_tflops:.0f} TFLOP/s")
