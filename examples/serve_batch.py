"""Batched serving demo: prefill + decode with the request queue over a
sliding-window (Mixtral-family) model — exercises the ring-buffer KV cache.

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Engine, Request, RequestQueue

cfg = get_config("mixtral-8x7b", smoke=True)
model = build_model(cfg, mode="reference")
params = model.init(jax.random.PRNGKey(0))

engine = Engine(model, params, max_len=128)
queue = RequestQueue(engine, batch_size=4, buckets=(16, 48))

rng = np.random.default_rng(0)
for uid in range(10):
    plen = int(rng.integers(8, 48))
    queue.submit(Request(uid, rng.integers(0, cfg.vocab_size, plen)
                         .astype(np.int32), max_new_tokens=12))

served = queue.flush(force=True)
print(f"served {served} requests; sample completions:")
for uid in sorted(queue.results)[:5]:
    print(f"  req {uid}: ...{queue.results[uid][-12:]}")
