"""Continuous-batching demo: paged KV cache + split-KV decode over a
sliding-window (Mixtral-family) model — mixed-length prompts join free
batch slots as others retire, sharing one compiled decode step.

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import PagedEngine, Request

cfg = get_config("mixtral-8x7b", smoke=True)
model = build_model(cfg, mode="reference")
params = model.init(jax.random.PRNGKey(0))

engine = PagedEngine(model, params, batch_slots=4, page_size=8,
                     max_pages_per_seq=8)

rng = np.random.default_rng(0)
for uid in range(10):
    plen = int(rng.integers(8, 48))
    engine.submit(Request(uid, rng.integers(0, cfg.vocab_size, plen)
                          .astype(np.int32), max_new_tokens=12))

results = engine.run()
print(f"served {len(results)} requests in {engine.steps} decode steps "
      f"over {engine.batch_slots} slots; sample completions:")
for uid in sorted(results)[:5]:
    print(f"  req {uid}: ...{results[uid][-12:]}")
print("pinned decode/prefill buckets:",
      [k for k in engine.bucket_policies])
