"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.kernels.gemm import gemm, gemm_ref
from repro.kernels.attention import attention
from repro.core.grid_swizzle import SwizzleConfig

# --- 1. Kernels: tile-programmed GEMM with Algorithm-1 grid swizzling -----
a = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.bfloat16)
b = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.bfloat16)
c = gemm(a, b, swizzle=SwizzleConfig(window=2, chunk=4))     # Pallas kernel
c_ref = gemm_ref(a, b)                                       # jnp oracle
print("gemm max err:", float(jnp.abs(c.astype(jnp.float32)
                                     - c_ref.astype(jnp.float32)).max()))

# --- 2. Flash attention (GQA + sliding window), fwd + bwd -----------------
q = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 256, 64))
k = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 256, 64))
v = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 256, 64))
out = attention(q, k, v, causal=True, window=128)            # Pallas kernel
grad = jax.grad(lambda q: attention(q, k, v, causal=True).sum())(q)
print("attention out:", out.shape, "dq:", grad.shape)

# --- 3. Models: any assigned architecture, one API ------------------------
cfg = get_config("mixtral-8x7b", smoke=True)   # reduced same-family config
model = build_model(cfg, mode="reference")
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                            cfg.vocab_size)
logits, aux = model.forward(params, tokens)
print("mixtral logits:", logits.shape, "moe aux loss:", float(aux))

# --- 4. Decode with the ring-buffer KV cache ------------------------------
cache = model.init_cache(2, 64)
cache, lg = model.prefill(params, tokens, cache)
cache, lg = model.decode_step(params, jnp.argmax(lg, -1)[:, None], cache, 32)
print("next-token logits:", lg.shape)
