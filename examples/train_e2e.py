"""End-to-end driver (paper §4 validation, scaled): train the ~100M
llama-family model for a few hundred steps on the synthetic corpus, with
checkpointing, failure injection, and a straggler watchdog — then compare
the Pallas-kernel path against the XLA reference path on held-out loss
(the reproduction of the paper's Llama-pretraining parity check).

  PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--pallas]
"""
import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.data.pipeline import DataConfig, DataIterator, batch_at
from repro.optim import AdamWConfig, wsd_schedule
from repro.train import train_loop, FailureInjector, StragglerWatchdog


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--width", type=int, default=256,
                    help="d_model (default scaled for CPU; 768 = full 100M)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--pallas", action="store_true",
                    help="route attention/rope through the Pallas kernels "
                         "(interpret mode on CPU)")
    args = ap.parse_args()

    cfg = get_config("llama-100m")
    cfg = dataclasses.replace(
        cfg, num_layers=args.layers, d_model=args.width,
        num_heads=max(4, args.width // 64), num_kv_heads=max(2, args.width // 128),
        d_ff=args.width * 3, vocab_size=512)
    mode = "pallas_interpret" if args.pallas else "reference"
    model = build_model(cfg, mode=mode)
    print(f"[e2e] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"kernels={mode}")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, noise=0.1)
    opt = AdamWConfig(schedule=wsd_schedule(1e-2, 20, args.steps))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        res = train_loop(
            model, DataIterator(dcfg), args.steps, opt,
            ckpt_dir=ckpt_dir, ckpt_every=max(10, args.steps // 6),
            failure_injector=FailureInjector((args.steps // 2,)),
            watchdog=StragglerWatchdog(), log_every=25)

    # held-out eval
    heldout = {k: np.asarray(v) for k, v in
               batch_at(dataclasses.replace(dcfg, seed=999), 0).items()}
    loss, _ = model.loss(res.state["params"], heldout)
    print(f"[e2e] first-loss {res.losses[0]:.3f} -> last {res.losses[-1]:.3f}"
          f" | held-out {float(loss):.3f} | restarts {res.restarts}")
    want = min(1.2, args.steps / 250)
    assert res.losses[-1] < res.losses[0] - want, "did not learn"


if __name__ == "__main__":
    main()
