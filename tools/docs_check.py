"""Docs check: execute the README quickstart and verify intra-repo links.

Two checks, both CI-enforced (.github/workflows/ci.yml, docs-check step):

  1. **Quickstart execution** — every ```python fenced block in README.md is
     executed, in order, in one shared namespace (interpret mode on CPU, so
     the blocks must be written to run anywhere the tier-1 tests run). A
     README whose first code sample is broken is worse than no README.
  2. **Link check** — every relative markdown link in README.md, DESIGN.md,
     ROADMAP.md and docs/*.md must point at a file or directory that exists
     in the repo (anchors and external http(s)/mailto links are skipped).

Run from the repo root: ``PYTHONPATH=src python tools/docs_check.py``.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

DOC_FILES = [REPO / "README.md", REPO / "DESIGN.md", REPO / "ROADMAP.md"]
DOC_FILES += sorted((REPO / "docs").glob("*.md"))

_FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$",
                       re.MULTILINE | re.DOTALL)
# [text](target) — excluding images' srcsets and in-page #anchors
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def quickstart_blocks(readme: pathlib.Path) -> list:
    return [m.group(1) for m in _FENCE_RE.finditer(readme.read_text())]


def run_quickstart() -> int:
    blocks = quickstart_blocks(REPO / "README.md")
    if not blocks:
        print("docs-check: README.md has no ```python quickstart block",
              file=sys.stderr)
        return 1
    ns: dict = {}
    for i, block in enumerate(blocks):
        print(f"docs-check: executing README python block {i + 1}/"
              f"{len(blocks)} ({len(block.splitlines())} lines)")
        try:
            exec(compile(block, f"README.md#block{i + 1}", "exec"), ns)
        except Exception as e:  # noqa: BLE001 — report, fail, keep going
            print(f"docs-check: README block {i + 1} FAILED: {e!r}",
                  file=sys.stderr)
            return 1
    print("docs-check: README quickstart OK")
    return 0


def check_links() -> int:
    bad = []
    for doc in DOC_FILES:
        if not doc.exists():
            bad.append((doc.relative_to(REPO), "(file missing)"))
            continue
        for target in _LINK_RE.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (doc.parent / path).exists():
                bad.append((doc.relative_to(REPO), target))
    for doc, target in bad:
        print(f"docs-check: broken intra-repo link in {doc}: {target}",
              file=sys.stderr)
    if not bad:
        print(f"docs-check: links OK across {len(DOC_FILES)} docs")
    return 1 if bad else 0


def main() -> int:
    return check_links() or run_quickstart()


if __name__ == "__main__":
    sys.exit(main())
