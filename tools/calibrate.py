#!/usr/bin/env python
"""Run the calibration sweep and write CALIB_*.json pretuned tables.

Usage:
    PYTHONPATH=src python tools/calibrate.py --out calib/ [--smoke]
        [--execute] [--seed 0] [--arch cpu] [--jitter 0.0] [--top-k 12]

Writes ``CALIB_<arch>.json`` into ``--out`` — a file that is both the
drift-check report (per-candidate measured + analytic times) and an
installable pretuned policy table (``autotune.load_pretuned`` /
``configs.pretuned_table_path``). Run ``tools/drift_check.py <out-dir>``
afterwards to gate the analytic model against the measurements.

On CPU/CI the measurement is the interpret-path proxy rig (see
``repro.core.calibrate``); ``--execute`` additionally runs each small
cell's winner once in interpret mode so the obs journal records real
kernel launches. On real hardware, wire a wall-clock ``measure_fn``
instead of the rig.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="calib", help="output directory")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (BENCH_SMOKE cells only)")
    ap.add_argument("--execute", action="store_true",
                    help="run small cells in interpret mode under obs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default=None,
                    help="arch tag (default: jax.default_backend())")
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="seeded relative measurement noise for the rig")
    ap.add_argument("--top-k", type=int, default=12,
                    help="candidates measured per cell (by analytic rank)")
    args = ap.parse_args(argv)

    from repro import obs
    from repro.core import calibrate as cal

    rig = cal.CalibrationRig(jitter=args.jitter, seed=args.seed)
    with obs.capture() as rec:
        report = cal.calibrate(rig=rig, execute=args.execute,
                               smoke=args.smoke, top_k=args.top_k,
                               seed=args.seed, arch=args.arch)
    report["obs_counters"] = {k: v for k, v in sorted(
        rec.counters.items()) if k.startswith("calibrate.")}

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"CALIB_{report['arch']}.json")
    cal.save_report(report, path)

    drift = cal.check_drift(report)
    print(f"wrote {path}: {len(report['cells'])} cells, "
          f"{len(report['fusion'])} fusion plans, "
          f"fitted chip {report['chip']['name']}")
    print(json.dumps(drift["families"], indent=1, sort_keys=True))
    if not drift["ok"]:
        print("DRIFT VIOLATIONS (gate will fail):", file=sys.stderr)
        for v in drift["violations"]:
            print(f"  {v}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
