"""Trace check: validate the telemetry artifacts the bench runner emits.

Every bench run writes, beside each ``BENCH_<key>.json``:

  * ``TRACE_<key>.json``    — Chrome-trace/Perfetto JSON (``traceEvents``)
  * ``COUNTERS_<key>.json`` — flat counters + launch counts

CI runs this after the bench smoke (.github/workflows/ci.yml, trace-check
step) so a malformed exporter can't silently ship unloadable traces. Three
checks per file set:

  1. **Chrome-trace schema** — top level is an object with a ``traceEvents``
     list; every event carries ``name``/``ph``/``pid``/``ts`` with sane
     types; ``X`` events carry ``dur``; ``C`` events carry a numeric
     ``args.value``. This is the subset both chrome://tracing and Perfetto
     require to load a file.
  2. **Counters schema** — ``counters`` maps str -> number and ``launches``
     maps str -> non-negative int (the stable key contract BENCH json
     consumers rely on).
  3. **Bench embedding** — when the matching ``BENCH_<key>.json`` is
     present, its ``telemetry.launches`` block must agree with the
     counters file's ``launches``.

Run from the repo root: ``python tools/trace_check.py [dir]`` (default:
``$BENCH_OUT`` or cwd). Exits non-zero listing every violation.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys

_PHASES = {"B", "E", "X", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


def check_trace(path: pathlib.Path) -> list[str]:
    errs = []
    try:
        doc = json.loads(path.read_text())
    except Exception as e:  # noqa: BLE001 — a parse failure IS the finding
        return [f"{path.name}: not valid JSON ({e})"]
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return [f"{path.name}: missing top-level traceEvents list"]
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"{path.name}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errs.append(f"{where}: missing/empty name")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errs.append(f"{where}: bad phase {ph!r}")
        if not isinstance(ev.get("pid"), int):
            errs.append(f"{where}: missing integer pid")
        if not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"{where}: missing numeric ts")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"{where}: 'X' event without numeric dur")
        if ph == "C":
            val = (ev.get("args") or {}).get("value")
            if not isinstance(val, (int, float)):
                errs.append(f"{where}: 'C' event without numeric args.value")
    return errs


def check_counters(path: pathlib.Path) -> list[str]:
    errs = []
    try:
        doc = json.loads(path.read_text())
    except Exception as e:  # noqa: BLE001
        return [f"{path.name}: not valid JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path.name}: top level is not an object"]
    counters = doc.get("counters")
    if not isinstance(counters, dict):
        errs.append(f"{path.name}: missing counters object")
    else:
        for k, v in counters.items():
            if not isinstance(k, str) or not isinstance(v, (int, float)):
                errs.append(f"{path.name}: counters[{k!r}] = {v!r} "
                            "is not str -> number")
    launches = doc.get("launches")
    if not isinstance(launches, dict):
        errs.append(f"{path.name}: missing launches object")
    else:
        for k, v in launches.items():
            if (not isinstance(k, str) or not isinstance(v, int)
                    or isinstance(v, bool) or v < 0):
                errs.append(f"{path.name}: launches[{k!r}] = {v!r} "
                            "is not str -> non-negative int")
    return errs


def check_bench_embedding(counters_path: pathlib.Path) -> list[str]:
    key = counters_path.name[len("COUNTERS_"):]
    bench = counters_path.parent / f"BENCH_{key}"
    if not bench.exists():
        return []
    try:
        want = json.loads(counters_path.read_text()).get("launches")
        got = (json.loads(bench.read_text()).get("telemetry") or {}) \
            .get("launches")
    except Exception as e:  # noqa: BLE001
        return [f"{bench.name}: not valid JSON ({e})"]
    if got is None:
        return [f"{bench.name}: no telemetry.launches block"]
    if got != want:
        return [f"{bench.name}: telemetry.launches disagrees with "
                f"{counters_path.name} ({got} != {want})"]
    return []


def main(argv: list[str]) -> int:
    out_dir = pathlib.Path(argv[1] if len(argv) > 1
                           else os.environ.get("BENCH_OUT", "."))
    traces = sorted(out_dir.glob("TRACE_*.json"))
    counters = sorted(out_dir.glob("COUNTERS_*.json"))
    if not traces and not counters:
        print(f"trace-check: no TRACE_*/COUNTERS_* files in {out_dir}",
              file=sys.stderr)
        return 1
    errs: list[str] = []
    for p in traces:
        errs += check_trace(p)
    for p in counters:
        errs += check_counters(p)
        errs += check_bench_embedding(p)
    for e in errs:
        print(f"trace-check: {e}", file=sys.stderr)
    if not errs:
        print(f"trace-check: OK ({len(traces)} traces, "
              f"{len(counters)} counter files in {out_dir})")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
