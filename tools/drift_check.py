#!/usr/bin/env python
"""Drift gate: assert the analytic perf model still matches measurement.

Usage:
    PYTHONPATH=src python tools/drift_check.py <calib-dir-or-json> ...
        [--top1-tol 0.05] [--min-spearman 0.8]

Reads every ``CALIB_*.json`` report produced by ``tools/calibrate.py``
(each candidate carries both ``measured_time_s`` and ``analytic_time_s``,
so this is pure JSON math — no model re-evaluation) and enforces, per
bench-sweep cell and per op family:

  * top-1 agreement — the measured winner's analytic time is within
    ``--top1-tol`` of the analytic best, and
  * rank fidelity — mean Spearman rank correlation between analytic and
    measured candidate rankings is at least ``--min-spearman``.

Exits non-zero listing every violation. CI runs this as a required step
after the calibrate-smoke sweep; a red gate means the analytic model has
drifted from what the kernels actually do — recalibrate or fix the model
(docs/autotuning.md walks through both).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def _collect(paths):
    files = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(sorted(glob.glob(os.path.join(p, "CALIB_*.json"))))
        else:
            files.append(p)
    return files


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+",
                    help="CALIB_*.json files or directories holding them")
    ap.add_argument("--top1-tol", type=float, default=0.05)
    ap.add_argument("--min-spearman", type=float, default=0.8)
    args = ap.parse_args(argv)

    from repro.core.calibrate import check_drift

    files = _collect(args.paths)
    if not files:
        print("drift_check: no CALIB_*.json reports found", file=sys.stderr)
        return 2

    failed = False
    for path in files:
        with open(path) as f:
            report = json.load(f)
        res = check_drift(report, top1_tol=args.top1_tol,
                          min_spearman=args.min_spearman)
        status = "OK" if res["ok"] else "DRIFT"
        print(f"{path}: {status} ({res['n_cells']} cells)")
        for op, fam in sorted(res["families"].items()):
            print(f"  {op:18s} cells={fam['cells']:3d} "
                  f"top1={fam['top1_agreement']:.2f} "
                  f"spearman={fam['mean_spearman']:.3f}")
        for v in res["violations"]:
            print(f"  VIOLATION: {v}", file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
