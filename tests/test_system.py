"""End-to-end behaviour tests for the whole system (paper §4 scaled down):
train a llama-family model on structured data through BOTH kernel paths and
check learning + parity — the reproduction of the paper's "pretraining
matches PyTorch+AITER perplexity" validation.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.data.pipeline import DataConfig, DataIterator
from repro.optim import AdamWConfig, cosine_schedule, wsd_schedule
from repro.train import train_loop


def _tiny_llama():
    cfg = get_config("llama-100m")
    return dataclasses.replace(cfg, num_layers=2, d_model=128, num_heads=4,
                               num_kv_heads=2, d_ff=256, vocab_size=256)


def _train(cfg, mode, steps=30, schedule=cosine_schedule):
    model = build_model(cfg, mode=mode)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8,
                      noise=0.05)
    opt = AdamWConfig(schedule=schedule(1e-2, 5, steps))
    return train_loop(model, DataIterator(dcfg), steps, opt, log_every=0)


def test_training_learns_structure():
    res = _train(_tiny_llama(), "reference", steps=60)
    assert res.losses[-1] < res.losses[0] - 1.0, res.losses[::10]


@pytest.mark.slow
def test_pallas_path_trains_to_parity():
    """Same config, same data: the Pallas-kernel path must track the loss
    curve (paper §4 kernel-stability validation).

    Since the fusion PRs (DESIGN.md §9-§10) most of the kernel path's
    GEMM chain accumulates in f32 where the bf16 reference rounds through
    bf16 between ops, so the two bf16 curves drift apart with optimizer
    steps — each in its own direction around the true trajectory. The
    anchor is therefore the f32-compute reference curve (the ground truth
    both approximate): both paths must track it, and the kernel path may
    not sit meaningfully further from it than the bf16 reference does.
    """
    cfg = _tiny_llama()
    r_ref = _train(cfg, "reference", steps=25)
    r_pk = _train(cfg, "pallas_interpret", steps=25)
    r_truth = _train(dataclasses.replace(cfg, compute_dtype="float32"),
                     "reference", steps=25)
    truth = np.asarray(r_truth.losses)
    ref_err = np.abs(np.asarray(r_ref.losses) - truth).max()
    pk_err = np.abs(np.asarray(r_pk.losses) - truth).max()
    np.testing.assert_allclose(r_pk.losses, truth, atol=0.3)
    np.testing.assert_allclose(r_ref.losses, truth, atol=0.3)
    assert pk_err <= 2.5 * ref_err + 0.05, (pk_err, ref_err)
    # and the kernel path genuinely learns the structured data
    assert r_pk.losses[-1] < r_pk.losses[0] - 0.5, r_pk.losses[::6]


def test_wsd_schedule_trains():
    res = _train(_tiny_llama(), "reference", steps=60, schedule=wsd_schedule)
    assert res.losses[-1] < res.losses[0] - 1.0
