"""Per-arch smoke tests (required: one reduced-config per assigned arch,
forward/train step on CPU, output shapes + no NaNs) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.models.lm import lm_forward

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg, mode="reference")
        params = model.init(jax.random.PRNGKey(0))
        batch = model.make_batch(SMOKE_SHAPE, jax.random.PRNGKey(1))

        logits, aux = model.forward(params, batch)
        expect_s = batch["targets"].shape[1]
        assert logits.shape == (2, expect_s, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

        loss, metrics = model.loss(params, batch)
        assert np.isfinite(float(loss))
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(l, np.float32)).all()
                   for l in leaves), arch
        gnorm = sum(float(jnp.sum(l.astype(jnp.float32) ** 2))
                    for l in leaves) ** 0.5
        assert gnorm > 0

    def test_full_config_param_count(self, arch):
        """Full configs are never instantiated, but N must be sane."""
        cfg = get_config(arch)
        n = cfg.param_count()
        expected = {
            "whisper-base": (5e7, 2e8),
            "minicpm-2b": (2e9, 4e9),
            "chatglm3-6b": (5e9, 8e9),
            "granite-8b": (7e9, 9.5e9),
            "qwen2-72b": (6.5e10, 8.5e10),
            "llama4-maverick-400b-a17b": (3e11, 5e11),
            "mixtral-8x7b": (4e10, 5.5e10),
            "mamba2-130m": (1e8, 2e8),
            "recurrentgemma-2b": (2e9, 3.5e9),
            "internvl2-2b": (1.5e9, 3e9),
        }[arch]
        assert expected[0] <= n <= expected[1], (arch, f"{n:.3e}")


DECODE_ARCHS = ["granite-8b", "qwen2-72b", "mixtral-8x7b", "mamba2-130m",
                "recurrentgemma-2b", "chatglm3-6b", "minicpm-2b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    logits_full, _ = model.forward(params, toks)
    cache = model.init_cache(b, 64)
    cache, lg = model.prefill(params, toks[:, : s - 4], cache)
    errs = [float(jnp.abs(lg - logits_full[:, s - 5]).max())]
    for i in range(s - 4, s):
        cache, lg = model.decode_step(params, toks[:, i:i + 1], cache, i)
        errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
    assert max(errs) < 2e-1, (arch, errs)


def test_whisper_decode_consistency():
    cfg = get_config("whisper-base", smoke=True)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = {
        "encoder_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_seq, cfg.d_model),
            jnp.bfloat16),
        "inputs": jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                                     cfg.vocab_size)}
    logits_full, _ = model.forward(params, batch)
    cache = model.init_cache(b, 64)
    cache, lg = model.prefill(
        params, {**batch, "inputs": batch["inputs"][:, : s - 4]}, cache)
    errs = [float(jnp.abs(lg - logits_full[:, s - 5]).max())]
    for i in range(s - 4, s):
        cache, lg = model.decode_step(params, batch["inputs"][:, i:i + 1],
                                      cache, i)
        errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
    assert max(errs) < 2e-1


def test_sliding_window_ring_cache():
    """Decode past the window with the ring buffer == full-cache attention."""
    cfg = get_config("mixtral-8x7b", smoke=True)   # window 32
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 48                                   # prompt longer than window
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    logits_full, _ = model.forward(params, toks)
    cache = model.init_cache(b, cfg.max_seq_len)   # ring: 32 slots
    assert jax.tree.leaves(cache)[0].shape[3] == 32  # (L, B, Hkv, slots, hd)
    cache, lg = model.prefill(params, toks[:, : s - 4], cache)
    errs = [float(jnp.abs(lg - logits_full[:, s - 5]).max())]
    for i in range(s - 4, s):
        cache, lg = model.decode_step(params, toks[:, i:i + 1], cache, i)
        errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
    assert max(errs) < 2e-1, errs


def test_ssm_chunk_invariance():
    """SSD output must not depend on the chunk size (property of the
    state-space duality)."""
    cfg = get_config("mamba2-130m", smoke=True)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    l1, _ = model.forward(params, toks)
    cfg2 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=8))
    l2, _ = lm_forward(cfg2, params, toks)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=5e-2)


def test_bert_mlm_smoke():
    """Paper §4's second validation model (BERT-110M family): encoder-only
    MLM trains on masked positions only."""
    cfg = dataclasses.replace(get_config("bert-110m"), num_layers=2,
                              d_model=64, num_heads=4, num_kv_heads=4,
                              d_ff=128, vocab_size=256, max_seq_len=64)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    rng = jax.random.PRNGKey(1)
    targets = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    mask = (jax.random.uniform(jax.random.PRNGKey(2), (b, s)) < 0.15)
    inputs = jnp.where(mask, 0, targets)      # 0 = [MASK]
    batch = {"inputs": inputs, "targets": targets,
             "loss_mask": mask.astype(jnp.float32)}
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(g))
    with pytest.raises(NotImplementedError):
        model.init_cache(2, 64)


def test_pallas_vs_reference_model_parity():
    """Paper §4 stability validation (scaled down): the same model computes
    the same loss through the Pallas kernels and the XLA reference path.

    Since the fused-epilogue PR the kernel path no longer shares a bitwise
    graph with the bf16 reference for the MLP/QKV projections (the kernels
    accumulate in f32 where the jnp reference rounds through bf16), so the
    gradient check anchors both paths against an f32-compute ground truth:
    the kernel path's gradient error must be no worse than the bf16
    reference path's (× slack), and both must point the same way.
    """
    cfg = get_config("granite-8b", smoke=True)
    ref_model = build_model(cfg, mode="reference")
    pk_model = build_model(cfg, mode="pallas_interpret")
    truth_model = build_model(
        dataclasses.replace(cfg, compute_dtype="float32"), mode="reference")
    params = ref_model.init(jax.random.PRNGKey(0))
    batch = ref_model.make_batch(ShapeConfig("t", 128, 2, "train"),
                                 jax.random.PRNGKey(1))
    l_ref, _ = ref_model.loss(params, batch)
    l_pk, _ = pk_model.loss(params, batch)
    assert abs(float(l_ref) - float(l_pk)) < 5e-2, (float(l_ref), float(l_pk))
    g_ref = jax.grad(lambda p: ref_model.loss(p, batch)[0])(params)
    g_pk = jax.grad(lambda p: pk_model.loss(p, batch)[0])(params)
    g_truth = jax.grad(lambda p: truth_model.loss(p, batch)[0])(params)
    def cos(a, b):
        return float(np.dot(a.ravel(), b.ravel()) /
                     max(np.linalg.norm(a) * np.linalg.norm(b), 1e-9))

    for (ka, t), (_, r), (_, k) in zip(
            *(sorted(jax.tree_util.tree_flatten_with_path(g)[0], key=str)
              for g in (g_truth, g_ref, g_pk))):
        t = np.asarray(t, np.float32)
        r = np.asarray(r, np.float32)
        k = np.asarray(k, np.float32)
        ref_err = np.abs(r - t).max()
        pk_err = np.abs(k - t).max()
        assert pk_err <= 2.0 * ref_err + 1e-2, \
            (jax.tree_util.keystr(ka), float(pk_err), float(ref_err))
        # the kernel path (f32 accumulators) must align with the f32 truth
        # at least as well as the bf16 reference does, per parameter
        assert cos(k, t) >= cos(r, t) - 0.05, \
            (jax.tree_util.keystr(ka), cos(k, t), cos(r, t))
