"""Telemetry subsystem (DESIGN.md §13): launch journal, spans/counters,
plan audit, exporters — and the zero-overhead contract on the disabled
path.

Coverage per the acceptance bar:
  * disabled path is a no-op: instrumented kernels run with no capture
    active and ``obs.null_allocations()`` stays 0 (the tripwire that
    every recording helper returned before allocating);
  * the journal reproduces DESIGN.md §12 launch counts (3 fwd / 5 bwd for
    the decoder attention sublayer) — asserted in test_attention_fusion;
    here the journal is checked at the single-kernel level (op names,
    policy payloads, modeled dma_bytes, wall-clock timing opt-in);
  * the plan-audit journal records every select_policy/select_fusion
    verdict with losing candidates, and replays memo hits (cached=True);
  * exporters: Chrome-trace JSON parses and passes tools/trace_check.py;
    counters JSON keys are stable;
  * engine/trainer counters surface through capture (admissions,
    preemptions, bucket-LRU, trainer steps).
"""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import autotune
from repro.kernels.gemm import Epilogue, Prologue, gemm, gemm_fused

REPO = pathlib.Path(__file__).resolve().parent.parent


def _rand(key, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * 0.5
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Disabled path: the zero-overhead contract
# ---------------------------------------------------------------------------

class TestDisabledPath:
    def test_recording_api_is_noop_without_capture(self):
        assert not obs.enabled()
        obs.incr("nope")
        obs.gauge("nope", 3.0)
        obs.plan_decision("policy", "gemm", (1, 1, 1), "f32", {})
        with obs.span("nope", k=1):
            pass
        assert not obs.enabled()

    def test_instrumented_kernels_allocate_nothing_when_disabled(self):
        """The acceptance criterion: a full instrumented dispatch with no
        recorder active must build zero event objects."""
        obs.reset_null_allocations()
        a, b = _rand(0, (64, 64)), _rand(1, (64, 64))
        jax.block_until_ready(gemm(a, b, out_dtype=jnp.float32))
        jax.block_until_ready(gemm_fused(
            a, b, b2=_rand(2, (64, 64)),
            epilogue=Epilogue(activation="silu", gate=True),
            out_dtype=jnp.float32))
        assert obs.null_allocations() == 0

    def test_tripwire_fires_on_unguarded_record(self):
        obs.reset_null_allocations()
        obs._record_launch(obs.LaunchEvent(op="rogue"))
        assert obs.null_allocations() == 1
        obs.reset_null_allocations()


# ---------------------------------------------------------------------------
# Launch journal
# ---------------------------------------------------------------------------

class TestLaunchJournal:
    def test_gemm_event_carries_policy_and_modeled_bytes(self):
        a, b = _rand(0, (128, 128)), _rand(1, (128, 128))
        with obs.capture() as cap:
            gemm(a, b, out_dtype=jnp.float32)
        assert cap.count("gemm") == 1
        ev = cap.launches[0]
        assert ev.grid and all(g >= 1 for g in ev.grid)
        assert ev.policy and "schedule" in ev.policy and "blocks" in ev.policy
        assert ev.dma_bytes and ev.dma_bytes > 0
        assert ev.flops == 2 * 128 * 128 * 128
        assert cap.modeled_bytes("gemm") == ev.dma_bytes

    def test_gemm_fused_event_carries_chain(self):
        a, b = _rand(0, (128, 128)), _rand(1, (128, 128))
        with obs.capture() as cap:
            gemm_fused(a, b, b2=_rand(2, (128, 128)),
                       epilogue=Epilogue(activation="silu", gate=True),
                       out_dtype=jnp.float32)
        ev = cap.launches[-1]
        assert ev.op == "gemm_fused"
        assert ev.chain and "silu" in ev.chain

    def test_timing_capture_fills_wall_clock(self):
        a, b = _rand(0, (128, 128)), _rand(1, (128, 128))
        with obs.capture(timing=True) as cap:
            gemm_fused(a, b, out_dtype=jnp.float32)
        ev = next(e for e in cap.launches if e.op == "gemm_fused")
        assert ev.wall_s is not None and ev.wall_s > 0

    def test_fused_norm_and_rope_journal(self):
        from repro.kernels.fused_norm import fused_dropout_residual_layernorm
        from repro.kernels.rope import rope_pallas, rope_tables
        x = _rand(0, (64, 128))
        gamma = jnp.ones((128,))
        beta = jnp.zeros((128,))
        with obs.capture() as cap:
            fused_dropout_residual_layernorm(x, jnp.zeros_like(x), gamma,
                                             beta, 0)
            q = _rand(1, (1, 2, 64, 64))
            sin, cos = rope_tables(jnp.arange(64), 64)
            rope_pallas(q, sin, cos)
        assert cap.count("fused_norm") == 1, cap.launch_counts()
        assert cap.count("rope") == 1, cap.launch_counts()
        assert cap.modeled_bytes() > 0

    def test_nested_captures_fan_out(self):
        a, b = _rand(0, (64, 64)), _rand(1, (64, 64))
        with obs.capture() as outer:
            gemm(a, b, out_dtype=jnp.float32)
            with obs.capture() as inner:
                gemm(a, b, out_dtype=jnp.float32)
        assert inner.count("gemm") == 1
        assert outer.count("gemm") == 2


# ---------------------------------------------------------------------------
# Spans + counters
# ---------------------------------------------------------------------------

class TestSpansCounters:
    def test_span_counter_gauge_basics(self):
        with obs.capture() as cap:
            with obs.span("outer", tag="x"):
                obs.incr("hits")
                obs.incr("hits", 2.0)
                obs.gauge("peak", 3.0)
                obs.gauge("peak", 1.0)   # running max keeps 3
        assert cap.counter("hits") == 3.0
        assert cap.counter("peak") == 3.0
        assert [s.name for s in cap.spans] == ["outer"]
        assert cap.spans[0].meta == {"tag": "x"}
        assert cap.spans[0].dur >= 0

    def test_summary_block_shape(self):
        a, b = _rand(0, (64, 64)), _rand(1, (64, 64))
        with obs.capture() as cap:
            with obs.span("s"):
                gemm(a, b, out_dtype=jnp.float32)
            obs.incr("c")
        s = cap.summary()
        assert s["launches"] == {"gemm": 1}
        assert s["modeled_dma_bytes"]["gemm"] > 0
        assert s["counters"] == {"c": 1.0}
        assert s["spans"] == 1


# ---------------------------------------------------------------------------
# Plan-audit journal
# ---------------------------------------------------------------------------

class TestPlanAudit:
    def test_select_policy_audited_with_candidates(self):
        autotune.clear_policy_cache()
        with obs.capture() as cap:
            autotune.select_policy("gemm", (512, 512, 512), "bfloat16")
        pols = [p for p in cap.plans if p.kind == "policy"]
        assert len(pols) == 1
        dec = pols[0]
        assert dec.op == "gemm" and not dec.cached
        assert dec.candidates and any(c["chosen"] for c in dec.candidates)
        assert all("dma_bytes" in c and "time_s" in c
                   for c in dec.candidates)

    def test_memo_hit_replays_audit_as_cached(self):
        autotune.clear_policy_cache()
        autotune.select_policy("gemm", (512, 512, 512), "bfloat16")  # warm
        with obs.capture() as cap:
            autotune.select_policy("gemm", (512, 512, 512), "bfloat16")
        pols = [p for p in cap.plans if p.kind == "policy"]
        assert len(pols) == 1 and pols[0].cached
        assert pols[0].chosen  # the stored describe() payload replays

    def test_select_fusion_audited(self):
        autotune.clear_policy_cache()
        with obs.capture() as cap:
            plan = autotune.select_fusion("mlp", (4096, 1024, 4096, True))
        fus = [p for p in cap.plans if p.kind == "fusion"]
        assert len(fus) == 1
        dec = fus[0]
        assert dec.chosen["plan"] == plan["plan"]
        assert {c["plan"] for c in dec.candidates} == {"fused", "unfused"}


# ---------------------------------------------------------------------------
# Exporters + tools/trace_check.py
# ---------------------------------------------------------------------------

class TestExporters:
    def _run_captured(self):
        a, b = _rand(0, (128, 128)), _rand(1, (128, 128))
        autotune.clear_policy_cache()
        with obs.capture(timing=True) as cap:
            with obs.span("window", case="test"):
                gemm(a, b, out_dtype=jnp.float32)
                gemm_fused(a, b, out_dtype=jnp.float32)
            obs.incr("tokens", 7)
        return cap

    def test_chrome_trace_schema(self, tmp_path):
        cap = self._run_captured()
        path = obs.export_chrome_trace(cap, tmp_path / "TRACE_t.json")
        doc = json.loads(pathlib.Path(path).read_text())
        evs = doc["traceEvents"]
        assert evs and all(
            isinstance(e["name"], str) and isinstance(e["pid"], int)
            and isinstance(e["ts"], (int, float)) and e["ph"] in "XiC"
            for e in evs)
        assert any(e["ph"] == "X" and e["dur"] >= 0 for e in evs)
        counter_evs = [e for e in evs if e["ph"] == "C"]
        assert any(e["name"] == "tokens" for e in counter_evs)
        assert doc["otherData"]["producer"] == "repro.obs"
        assert isinstance(doc["otherData"]["plan_decisions"], list)

    def test_counters_export_stable_keys(self, tmp_path):
        cap = self._run_captured()
        path = obs.export_counters(cap, tmp_path / "COUNTERS_t.json")
        doc = json.loads(pathlib.Path(path).read_text())
        assert list(doc) == ["counters", "launches"]
        assert doc["counters"]["tokens"] == 7
        assert doc["launches"] == {"gemm": 1, "gemm_fused": 1}

    def test_trace_check_tool_passes_on_real_exports(self, tmp_path):
        cap = self._run_captured()
        obs.export_chrome_trace(cap, tmp_path / "TRACE_t.json")
        obs.export_counters(cap, tmp_path / "COUNTERS_t.json")
        res = subprocess.run(
            [sys.executable, str(REPO / "tools" / "trace_check.py"),
             str(tmp_path)], capture_output=True, text=True)
        assert res.returncode == 0, res.stderr

    def test_trace_check_tool_rejects_malformed(self, tmp_path):
        (tmp_path / "TRACE_bad.json").write_text(
            json.dumps({"traceEvents": [{"ph": "X"}]}))
        res = subprocess.run(
            [sys.executable, str(REPO / "tools" / "trace_check.py"),
             str(tmp_path)], capture_output=True, text=True)
        assert res.returncode == 1
        assert "TRACE_bad.json" in res.stderr

    def test_bench_json_embeds_telemetry(self, tmp_path, monkeypatch):
        """benchmarks.common bracket: begin/end_capture feeds a telemetry
        block + trace/counter exports into write_bench_json."""
        sys.path.insert(0, str(REPO))
        try:
            from benchmarks import common as bcommon
        finally:
            sys.path.pop(0)
        monkeypatch.setenv("BENCH_OUT", str(tmp_path))
        a, b = _rand(0, (64, 64)), _rand(1, (64, 64))
        bcommon.begin_capture()
        gemm(a, b, out_dtype=jnp.float32)
        bcommon.emit("case", 1.0, "tf=2")
        rows = bcommon.end_capture()
        bcommon.write_bench_json("t", rows)
        doc = json.loads((tmp_path / "BENCH_t.json").read_text())
        assert doc["telemetry"]["launches"] == {"gemm": 1}
        assert (tmp_path / "TRACE_t.json").exists()
        assert (tmp_path / "COUNTERS_t.json").exists()
        res = subprocess.run(
            [sys.executable, str(REPO / "tools" / "trace_check.py"),
             str(tmp_path)], capture_output=True, text=True)
        assert res.returncode == 0, res.stderr


# ---------------------------------------------------------------------------
# Engine + trainer integration
# ---------------------------------------------------------------------------

class TestEngineTrainerCounters:
    def test_paged_engine_counters_surface_in_capture(self):
        from repro.configs import get_config
        from repro.models import build_model
        from repro.serve.engine import PagedEngine, Request

        cfg = get_config("granite-8b", smoke=True)
        model = build_model(cfg, mode="reference")
        params = model.init(jax.random.PRNGKey(0))
        eng = PagedEngine(model, params, batch_slots=2, page_size=4,
                          max_pages_per_seq=4, n_pages=9)
        rng = np.random.default_rng(0)
        with obs.capture() as cap:
            for u in range(2):
                eng.submit(Request(u, rng.integers(0, cfg.vocab_size, 4)
                                   .astype(np.int32), 3))
            eng.run()
        assert cap.counter("engine.admissions") == eng.admissions == 2
        assert cap.counter("engine.tokens_generated") \
            == eng.tokens_generated == 6
        assert cap.counter("engine.peak_pages_in_use") \
            == eng.peak_pages_in_use > 0
        assert any(s.name == "engine.run" for s in cap.spans)
        assert any(s.name == "engine.decode_step" for s in cap.spans)
        rep = eng.report()
        assert rep["bucket_lru"]["misses"] >= 1

    def test_trainer_counters_surface_in_capture(self):
        import dataclasses

        from repro.configs import get_config
        from repro.data.pipeline import DataConfig, DataIterator
        from repro.models import build_model
        from repro.optim import AdamWConfig, cosine_schedule
        from repro.train import train_loop

        cfg = get_config("llama-100m")
        cfg = dataclasses.replace(cfg, num_layers=1, d_model=128,
                                  num_heads=4, num_kv_heads=2, d_ff=256,
                                  vocab_size=256, compute_dtype="float32")
        model = build_model(cfg, mode="reference")
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=2)
        opt = AdamWConfig(schedule=cosine_schedule(1e-3, 1, 3))
        with obs.capture() as cap:
            train_loop(model, DataIterator(dcfg), 3, opt, log_every=0)
        assert cap.counter("trainer.steps") == 3
        assert cap.counter("trainer.bucket_pins") == 1
        assert cap.counter("trainer.bucket_pins.2x16") == 1
        steps = [s for s in cap.spans if s.name == "trainer.step"]
        assert len(steps) == 3 and all(s.dur > 0 for s in steps)
