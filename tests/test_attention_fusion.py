"""Attention under the chain-spec protocol (DESIGN.md §12).

Coverage per the acceptance bar:
  * softcap (gemma2 tanh cap) + attention-sink epilogue parity: flash
    fwd/bwd and both decode kernels vs the jnp references, including the
    differentiable dsink path;
  * saved-preact attention backward anchored against an f32 ground truth
    (kernel grads no worse than the bf16 reference path's);
  * the prefill-side fused QKV plan ladder: cached k/v parity vs the
    standalone norm+project+rope path across rope_style x GQA x window,
    and dense-vs-paged fused prefill cache parity;
  * launch counts: a default llama-style decoder attention sublayer is
    exactly 2 fused GEMMs + 1 flash launch forward (no standalone norm,
    no standalone rope), and 1 flash bwd + 4 fused bwd GEMM launches
    backward;
  * select_fusion picks the fused attention plan purely from modeled
    dma_bytes, with >= 1.2x modeled traffic reduction on the paper's
    d=64 and GQA-backward headline cells.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs.base import ModelConfig
from repro.core import autotune
from repro.kernels.attention import (attention, attention_decode,
                                     attention_decode_paged, attention_ref,
                                     decode_ref, AttnEpilogue,
                                     ATTN_EPILOGUE_NONE)
from repro.models import attention as mattn
from repro.models.attention import (attn_defs, project_qkv,
                                    project_qkv_heads, _apply_rope)
from repro.models.common import (apply_prenorm, init_params, norm_defs,
                                 norm_params)


def _rand(key, shape, dtype=jnp.float32, scale=0.5):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale
    return x.astype(dtype)


def _qkv(b=2, h=4, hkv=2, s=256, d=64, dtype=jnp.float32):
    return (_rand(0, (b, h, s, d), dtype), _rand(1, (b, hkv, s, d), dtype),
            _rand(2, (b, hkv, s, d), dtype))


# ---------------------------------------------------------------------------
# Epilogue protocol object
# ---------------------------------------------------------------------------

class TestAttnEpilogue:
    def test_identity_and_describe(self):
        assert ATTN_EPILOGUE_NONE.is_identity
        assert ATTN_EPILOGUE_NONE.describe() == "none"
        ep = AttnEpilogue(softcap=30.0, sink=True)
        assert not ep.is_identity
        assert "softcap" in ep.describe() and "sink" in ep.describe()
        assert ep.operand_names() == ("sinks",)
        assert ep.extra_read_bytes(16) == 64  # one f32 logit per head

    def test_hashable_and_jit_static(self):
        # the epilogue rides jit static_argnames and the autotune bucket
        assert hash(AttnEpilogue(softcap=30.0)) == hash(AttnEpilogue(
            softcap=30.0))
        assert AttnEpilogue() == ATTN_EPILOGUE_NONE

    def test_validation(self):
        with pytest.raises(ValueError):
            AttnEpilogue(softcap=-1.0)

    def test_policy_carries_attention_epilogue(self):
        ep = AttnEpilogue(softcap=30.0, sink=True)
        pol = autotune.select_policy("attention_fwd", (2, 4, 256, 256, 64),
                                     "float32", causal=True, epilogue=ep)
        assert pol.epilogue is ep
        # the sink operand joins the policy's operand blocks as a (1, 1) tile
        base = autotune.select_policy("attention_fwd", (2, 4, 256, 256, 64),
                                      "float32", causal=True)
        blocks = pol.operand_blocks()
        assert len(blocks) == len(base.operand_blocks()) + 1
        assert blocks[-1][0] == (1, 1)


# ---------------------------------------------------------------------------
# Softcap + sink kernel parity (fwd, bwd, decode, paged decode)
# ---------------------------------------------------------------------------

class TestEpilogueParity:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("softcap,sink", [(30.0, False), (None, True),
                                              (20.0, True)])
    def test_fwd_matches_reference(self, causal, softcap, sink):
        q, k, v = _qkv()
        sinks = _rand(3, (4,), scale=1.0) if sink else None
        ref = attention_ref(q, k, v, causal=causal, softcap=softcap,
                            sinks=sinks)
        out = attention(q, k, v, causal=causal, softcap=softcap, sinks=sinks,
                        mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    def test_windowed_softcap_matches_reference(self):
        q, k, v = _qkv()
        ref = attention_ref(q, k, v, causal=True, window=128, softcap=25.0)
        out = attention(q, k, v, causal=True, window=128, softcap=25.0,
                        mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    @pytest.mark.parametrize("softcap,sink", [(25.0, False), (None, True),
                                              (25.0, True)])
    def test_bwd_matches_reference_autodiff(self, softcap, sink):
        """The saved-preact transpose (softcap grad factor recomputed
        in-kernel, dsink from the (out, lse) residuals) vs jax autodiff of
        the jnp reference."""
        q, k, v = _qkv()
        sinks = _rand(3, (4,), scale=1.0) if sink else None
        argnums = (0, 1, 2, 3) if sink else (0, 1, 2)

        def loss(fn):
            return lambda *a: jnp.sum(fn(*a) ** 2)

        gk = jax.grad(loss(lambda q, k, v, *s: attention(
            q, k, v, causal=True, softcap=softcap,
            sinks=s[0] if s else None, mode="pallas_interpret")),
            argnums=argnums)(q, k, v, *((sinks,) if sink else ()))
        gr = jax.grad(loss(lambda q, k, v, *s: attention_ref(
            q, k, v, causal=True, softcap=softcap,
            sinks=s[0] if s else None)),
            argnums=argnums)(q, k, v, *((sinks,) if sink else ()))
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)

    @pytest.mark.parametrize("softcap,sink", [(25.0, False), (None, True),
                                              (25.0, True)])
    def test_decode_matches_reference(self, softcap, sink):
        b, h, hkv, s, d = 2, 4, 2, 128, 64
        q = _rand(0, (b, h, 1, d))
        k, v = _rand(1, (b, hkv, s, d)), _rand(2, (b, hkv, s, d))
        sinks = _rand(3, (h,), scale=1.0) if sink else None
        lengths = jnp.array([s, s - 17], jnp.int32)
        ref = decode_ref(q.reshape(b, hkv, h // hkv, d), k, v, lengths,
                         softcap=softcap, sinks=sinks).reshape(b, h, 1, d)
        out = attention_decode(q, k, v, lengths, softcap=softcap,
                               sinks=sinks, mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    def test_paged_decode_softcap_sink(self):
        from repro.serve import kv_cache as kvc
        b, h, hkv, d, page, mp = 2, 4, 2, 64, 16, 4
        n_pages = 1 + b * mp
        k_pages = _rand(1, (n_pages, hkv, page, d))
        v_pages = _rand(2, (n_pages, hkv, page, d))
        pt = jnp.arange(1, 1 + b * mp, dtype=jnp.int32).reshape(b, mp)
        q = _rand(0, (b, h, 1, d))
        sinks = _rand(3, (h,), scale=1.0)
        lengths = jnp.array([mp * page, mp * page - 9], jnp.int32)
        ref = attention_decode_paged(q, k_pages, v_pages, pt, lengths,
                                     softcap=25.0, sinks=sinks,
                                     mode="reference")
        out = attention_decode_paged(q, k_pages, v_pages, pt, lengths,
                                     softcap=25.0, sinks=sinks,
                                     mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    def test_softcap_actually_changes_output(self):
        # guard against the cap silently not being applied anywhere
        q, k, v = _qkv(s=128)
        a = attention(q, k, v, causal=True, mode="pallas_interpret")
        b = attention(q, k, v, causal=True, softcap=1.0,
                      mode="pallas_interpret")
        assert float(jnp.max(jnp.abs(a - b))) > 1e-4


class TestBwdF32Anchor:
    def test_bf16_grads_anchor_to_f32_truth(self):
        """Paper Fig. 8 family: the bf16 kernel backward must track the f32
        ground truth at least as well as the bf16 jnp reference does."""
        q, k, v = _qkv(b=1, h=4, hkv=1, s=256, d=64, dtype=jnp.bfloat16)
        qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))

        def loss(fn):
            return lambda q, k, v: jnp.sum(fn(q, k, v).astype(jnp.float32)
                                           ** 2)

        g_truth = jax.grad(loss(lambda *a: attention_ref(
            *a, causal=True, softcap=20.0)), argnums=(0, 1, 2))(qf, kf, vf)
        g_ref = jax.grad(loss(lambda *a: attention_ref(
            *a, causal=True, softcap=20.0)), argnums=(0, 1, 2))(q, k, v)
        g_ker = jax.grad(loss(lambda *a: attention(
            *a, causal=True, softcap=20.0, mode="pallas_interpret")),
            argnums=(0, 1, 2))(q, k, v)
        for t, r, kk in zip(g_truth, g_ref, g_ker):
            t = np.asarray(t, np.float32)
            ref_err = np.abs(np.asarray(r, np.float32) - t).max()
            ker_err = np.abs(np.asarray(kk, np.float32) - t).max()
            assert ker_err <= 2.0 * ref_err + 1e-3, (ker_err, ref_err)


# ---------------------------------------------------------------------------
# Prefill-side fused QKV plan ladder
# ---------------------------------------------------------------------------

def _cfg(rope_style="half", hkv=2, norm="rmsnorm", **kw):
    return ModelConfig(name="t", family="lm", num_layers=2, d_model=256,
                       num_heads=2, num_kv_heads=hkv, d_ff=512,
                       vocab_size=512, head_dim=128, mlp_act="swiglu",
                       norm=norm, rope_style=rope_style, max_seq_len=256,
                       compute_dtype="float32", **kw)


def _attn_params(cfg, key=0):
    defs = dict(attn_defs(cfg, "attn"))
    defs.update(norm_defs(cfg, "ln1"))
    return init_params(defs, jax.random.PRNGKey(key))


class TestFusedPrefillParity:
    @pytest.mark.parametrize("rope_style", ["half", "partial", "none"])
    @pytest.mark.parametrize("hkv", [2, 1])
    def test_ladder_matches_standalone(self, rope_style, hkv):
        """The cached k (and v) coming out of the fused plan ladder must
        match the standalone norm+project+rope path — the cache stores
        ROTATED k, so whichever rung fires has to hand back the same
        heads."""
        cfg = _cfg(rope_style=rope_style, hkv=hkv)
        p = _attn_params(cfg)
        x = _rand(9, (2, 128, 256))
        positions = jnp.arange(128)
        prenorm = norm_params(p, "ln1")

        hn = apply_prenorm(cfg, x, prenorm)
        q0, k0, v0 = project_qkv(cfg, p["attn"], hn)
        q0, k0 = _apply_rope(cfg, q0, k0, positions, "reference")
        q1, k1, v1 = project_qkv_heads(cfg, p["attn"], x, positions,
                                       mode="pallas_interpret",
                                       prenorm=prenorm)
        for a, b in ((q0, q1), (k0, k1), (v0, v1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4)

    def test_windowed_prefill_cache_parity(self):
        """Ring-cache prefill through the fused ladder vs reference."""
        from repro.models.lm import lm_init_cache, lm_prefill
        from repro.models.lm import lm_param_defs
        cfg = _cfg(block_pattern=("local",), attn_window=64)
        params = init_params(lm_param_defs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, 512)
        cache = lm_init_cache(cfg, 2, 128)
        c_r, l_r = lm_prefill(cfg, params, toks, cache, mode="reference")
        c_p, l_p = lm_prefill(cfg, params, toks, cache,
                              mode="pallas_interpret")
        for a, b in zip(jax.tree.leaves(c_r), jax.tree.leaves(c_p)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=5e-3)
        np.testing.assert_allclose(np.asarray(l_r), np.asarray(l_p),
                                   atol=5e-3)

    def test_dense_and_paged_fused_prefill_caches_agree(self):
        """block_prefill and block_prefill_paged route through the same
        fused-QKV ladder: the k/v they cache must agree (dense slots vs
        gathered pages)."""
        from repro.models.lm import (lm_init_cache, lm_init_paged_cache,
                                     lm_param_defs, lm_prefill,
                                     lm_prefill_paged)
        from repro.serve import kv_cache as kvc
        cfg = _cfg()
        params = init_params(lm_param_defs(cfg), jax.random.PRNGKey(0))
        s, page, mp = 64, 16, 4
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, 512)

        dc = lm_init_cache(cfg, 1, s)
        dc, dlog = lm_prefill(cfg, params, toks, dc, mode="pallas_interpret")
        pc = lm_init_paged_cache(cfg, 1, 1 + mp, page)
        page_rows = jnp.arange(1, 1 + mp, dtype=jnp.int32)
        pc, plog = lm_prefill_paged(cfg, params, toks, pc, page_rows, 0, s,
                                    mode="pallas_interpret")

        d_leaves = jax.tree.leaves(dc)  # stacked dense cache leaves (k, v)
        p_leaves = jax.tree.leaves(pc)
        assert len(d_leaves) == len(p_leaves) == 2  # k and v stacks
        pt = page_rows[None]
        for dense, pages in zip(d_leaves, p_leaves):
            for layer in range(cfg.num_layers):
                gathered = kvc.gather_pages(pages[layer], pt)
                np.testing.assert_allclose(
                    np.asarray(gathered[:, :, :s], np.float32),
                    np.asarray(dense[layer][:, :, :s], np.float32),
                    atol=2e-4)
        np.testing.assert_allclose(np.asarray(dlog), np.asarray(plog),
                                   atol=5e-3)

    def test_softcap_threads_through_model(self):
        """configs/base.py attn_logit_softcap reaches the kernels: the same
        params produce different logits with the cap on, and ref/pallas
        stay in parity with it on."""
        from repro.models.lm import lm_forward, lm_param_defs
        cfg = _cfg(attn_logit_softcap=1.0)
        params = init_params(lm_param_defs(cfg), jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 512)
        l_cap, _ = lm_forward(cfg, params, toks, mode="reference")
        l_ref, _ = lm_forward(dataclasses.replace(cfg,
                                                  attn_logit_softcap=None),
                              params, toks, mode="reference")
        assert float(jnp.max(jnp.abs(l_cap - l_ref))) > 1e-3
        l_pk, _ = lm_forward(cfg, params, toks, mode="pallas_interpret")
        np.testing.assert_allclose(np.asarray(l_pk), np.asarray(l_cap),
                                   atol=5e-3)

    def test_decode_layer_honors_softcap(self):
        from repro.models.attention import (decode_attention_layer,
                                            init_attn_cache,
                                            prefill_attn_cache)
        cfg = _cfg(attn_logit_softcap=1.0)
        p = _attn_params(cfg)
        cache = init_attn_cache(cfg, 2, 32, None, jnp.float32)
        # real context in the cache — with an empty cache the softmax has a
        # single logit and capping is invisible by construction
        k = _rand(6, (2, cfg.num_kv_heads, 16, cfg.head_dim))
        v = _rand(7, (2, cfg.num_kv_heads, 16, cfg.head_dim))
        cache = prefill_attn_cache(cfg, cache, k, v, 16, None)
        x = _rand(5, (2, 1, 256))
        o_cap, _ = decode_attention_layer(cfg, p["attn"], x, cache, 16,
                                          mode="pallas_interpret")
        cfg0 = dataclasses.replace(cfg, attn_logit_softcap=None)
        o_ref, _ = decode_attention_layer(cfg0, p["attn"], x, cache, 16,
                                          mode="pallas_interpret")
        assert float(jnp.max(jnp.abs(o_cap - o_ref))) > 1e-5


# ---------------------------------------------------------------------------
# Launch counts: a decoder attention sublayer is ~3 fused kernels
# ---------------------------------------------------------------------------

class TestLaunchCounts:
    """DESIGN.md §12 counts through the telemetry journal (obs.capture is
    the sanctioned replacement for monkeypatch counting): every kernel
    entry point journals one LaunchEvent per Python call, and the eager
    norm/rope fallbacks bump ``model.standalone_*`` counters."""

    def test_attention_sublayer_is_three_fused_launches_forward(self):
        """Default llama-style decoder block, forward: the attention
        sublayer traces to exactly 2 fused GEMM launches (packed q|k with
        norm+rope folded in, v) + 1 flash launch — no standalone norm, no
        standalone rope."""
        cfg = _cfg()
        p = _attn_params(cfg)
        x = _rand(9, (2, 128, 256))
        with obs.capture() as cap:
            mattn.attention_layer(cfg, p["attn"], x, causal=True,
                                  mode="pallas_interpret",
                                  prenorm=norm_params(p, "ln1"))
        counts = cap.launch_counts()
        assert cap.count("gemm_fused") == 2, counts
        assert cap.count("attention_fwd") == 1, counts
        assert cap.counter("model.standalone_norm") == 0, cap.counters
        assert cap.counter("model.standalone_rope") == 0, cap.counters

    def test_attention_sublayer_backward_launches(self):
        """jax.grad over the sublayer: 1 flash bwd launch + the fused bwd
        GEMM pair per fwd GEMM (dA+dB for the packed q|k GEMM and the v
        GEMM) — no oracle recompute."""
        cfg = _cfg()
        p = _attn_params(cfg)
        x = _rand(9, (2, 128, 256))

        def loss(x):
            return jnp.sum(mattn.attention_layer(
                cfg, p["attn"], x, causal=True, mode="pallas_interpret",
                prenorm=norm_params(p, "ln1")) ** 2)

        with obs.capture() as cap:
            jax.grad(loss)(x)
        counts = cap.launch_counts()
        assert cap.count("attention_bwd") == 1, counts
        assert cap.count("gemm_bwd_da") == 2, counts
        assert cap.count("gemm_bwd_db") == 2, counts

    def test_gqa_backward_launches(self):
        cfg = _cfg(hkv=1)
        p = _attn_params(cfg)
        x = _rand(9, (2, 128, 256))

        def loss(x):
            return jnp.sum(mattn.attention_layer(
                cfg, p["attn"], x, causal=True, mode="pallas_interpret",
                prenorm=norm_params(p, "ln1")) ** 2)

        with obs.capture() as cap:
            jax.grad(loss)(x)
        assert cap.count("attention_bwd") == 1, cap.launch_counts()


# ---------------------------------------------------------------------------
# Fusion plans from modeled dma_bytes
# ---------------------------------------------------------------------------

class TestAttentionFusionPlans:
    def test_fused_plan_wins_from_bytes_alone(self):
        plan = autotune.select_fusion("attention", (2, 4, 2, 1024, 1024, 64),
                                      "bfloat16", causal=True)
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]

    def test_headline_cells_reduction(self):
        """HipKittens' headline cells: d=64 forward and GQA backward must
        model >= 1.2x traffic reduction (the unfused/fused ratio ~ 4S/d)."""
        d64 = autotune.select_fusion("attention",
                                     (16, 16, 16, 2048, 2048, 64),
                                     "bfloat16", causal=True)
        assert d64["plan"] == "fused"
        assert d64["traffic_reduction"] >= 1.2, d64["traffic_reduction"]
        gqa_bwd = autotune.select_fusion("attention",
                                         (16, 64, 8, 2048, 2048, 128),
                                         "bfloat16", causal=True,
                                         backward=True)
        assert gqa_bwd["plan"] == "fused"
        assert gqa_bwd["traffic_reduction"] >= 1.2, \
            gqa_bwd["traffic_reduction"]

    def test_softcap_widens_unfused_side(self):
        base = autotune.select_fusion("attention", (2, 4, 4, 512, 512, 64),
                                      "bfloat16", causal=True)
        capped = autotune.select_fusion("attention", (2, 4, 4, 512, 512, 64),
                                        "bfloat16", causal=True, softcap=True)
        assert capped["unfused_bytes"] > base["unfused_bytes"]
        assert capped["fused_bytes"] == base["fused_bytes"]

    def test_qkv_kind_needs_the_norm_to_win(self):
        """Rope-free packed QKV only beats the eager two-GEMM path through
        the folded pre-norm."""
        shape = (4096, 1024, 8, 8, 128)
        plain = autotune.select_fusion("qkv", shape, "bfloat16")
        normed = autotune.select_fusion("qkv", shape, "bfloat16",
                                        prenorm="rmsnorm")
        assert plain["plan"] == "unfused"
        assert normed["plan"] == "fused"

    def test_attention_op_honors_plan(self):
        """attention() consults the plan; the fused plan routes the flash
        kernel (journaled), never the eager reference."""
        q, k, v = _qkv(s=128)
        with obs.capture() as cap:
            attention(q, k, v, causal=True, mode="pallas_interpret")
        assert cap.count("attention_fwd") == 1, cap.launch_counts()
