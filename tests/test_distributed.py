"""Sharding rules + multi-device (subprocess) distribution tests."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestSpecRules:
    def test_divisible_shards(self):
        mesh = FakeMesh({"data": 16, "model": 16})
        assert spec_for((152064, 8192), ("vocab", "embed"), mesh) == \
            P("model", None)
        assert spec_for((8192, 29568), ("embed", "ffn"), mesh) == \
            P(None, "model")

    def test_indivisible_replicates(self):
        mesh = FakeMesh({"data": 16, "model": 16})
        report = []
        spec = spec_for((51865, 512), ("vocab", "embed"), mesh, report=report)
        assert spec == P(None, None)
        assert report  # the fallback is reported, not silent

    def test_batch_axes_compose(self):
        mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
        assert spec_for((256, 4096), ("batch", None), mesh) == \
            P(("pod", "data"), None)


class TestZero1Fsdp:
    """ZeRO-1/FSDP shard the largest free divisible dim (not just dim0) —
    required for stacked MoE tensors like (24, 128, 5120, 8192)."""

    def test_shard_free_dim_picks_largest(self):
        from repro.distributed.sharding import _shard_free_dim
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P(None, "model", None, None))
        out = _shard_free_dim(sh, (24, 128, 5120, 8192), mesh, "data")
        assert out is not None
        assert out.spec[3] == "data"          # largest free dim
        assert out.spec[1] == "model"         # existing sharding kept

    def test_vocab_padding_config(self):
        import dataclasses
        from repro.configs import get_config
        cfg = dataclasses.replace(get_config("minicpm-2b"),
                                  vocab_pad_multiple=128)
        assert cfg.padded_vocab() % 128 == 0
        assert cfg.padded_vocab() >= cfg.vocab_size
        assert cfg.padded_vocab() - cfg.vocab_size < 128


class TestMultiDevice:
    def test_dp_tp_train_step(self, subproc):
        """2x4 mesh: sharded init + sharded train step run and give finite
        loss; params stay sharded."""
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.train import make_train_step, sharded_init
from repro.optim import AdamWConfig, constant_schedule
from repro.data.pipeline import DataConfig, DataIterator
mesh = jax.make_mesh((2, 4), ('data', 'model'))
cfg = get_config('qwen2-72b', smoke=True)
model = build_model(cfg, mode='reference', mesh=mesh)
state = sharded_init(model, jax.random.PRNGKey(0), mesh, zero1=True)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
it = DataIterator(dcfg, mesh=mesh)
step = make_train_step(model, AdamWConfig(schedule=constant_schedule(1e-3)), mesh=mesh, zero1=True)
s2, m = step(state, next(it))
print('loss', float(m['loss']))
assert np.isfinite(float(m['loss']))
# a TP-sharded leaf really is distributed
leaf = s2['params']['blocks']['attn']['wqk']
assert len(leaf.sharding.device_set) > 1
print('OK')
""")
        assert "OK" in out

    def test_moe_ep_multidevice(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_forward, moe_defs, moe_dense
from repro.models.common import init_params
cfg = ModelConfig(name='t', family='lm', num_layers=1, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  block_pattern=('moe',),
                  moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0))
params = init_params(moe_defs(cfg, 'moe'), jax.random.PRNGKey(0))['moe']
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
o_ep, _ = moe_forward(cfg, params, x, mesh=mesh)
o_d, _ = moe_dense(cfg, params, x)
assert float(jnp.abs(o_ep - o_d).max()) < 1e-4
print('OK')
""")
        assert "OK" in out

    def test_elastic_checkpoint_reshard(self, subproc):
        """Save on a 4-device data mesh, restore onto a 2x2 mesh (different
        sharding) — values must round-trip exactly."""
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.configs import get_config
from repro.models import build_model
from repro.train import init_state, state_shardings, checkpoint as ckpt
cfg = get_config('granite-8b', smoke=True)
with tempfile.TemporaryDirectory() as d:
    mesh1 = jax.make_mesh((4, 2), ('data', 'model'))
    model1 = build_model(cfg, mode='reference', mesh=mesh1)
    state = init_state(model1, jax.random.PRNGKey(0))
    ckpt.save(state, d, 7)
    mesh2 = jax.make_mesh((2, 4), ('data', 'model'))
    model2 = build_model(cfg, mode='reference', mesh=mesh2)
    tpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    sh = state_shardings(model2, mesh2, zero1=True)
    restored, step = ckpt.restore(d, tpl, shardings=sh)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('OK')
""")
        assert "OK" in out

    @pytest.mark.slow
    def test_mini_dryrun_512(self, subproc):
        """The real thing: 512 fake devices, production meshes, one arch ×
        shape on both meshes, roofline terms extracted."""
        out = subproc("""
from repro.launch.dryrun import run_cell
for mesh in ('single', 'multi'):
    rec = run_cell('mamba2-130m', 'train_4k', mesh, verbose=False)
    assert rec['status'] == 'ok', rec
    assert rec['roofline']['flops_per_chip'] > 0
    assert rec['roofline']['collective_bytes_per_chip'] > 0
print('OK')
""", devices=512, timeout=900)
        assert "OK" in out


# ---------------------------------------------------------------------------
# ShardSpec: sharding as a first-class plan dimension (DESIGN.md §16)
# ---------------------------------------------------------------------------
class TestShardSpec:
    def test_construction_and_describe(self):
        from repro.distributed.sharding import ShardSpec
        sp = ShardSpec(mesh=(("model", 4),),
                       partition=(("expert", "model"),),
                       collective="all_to_all")
        assert sp.n_shards == 4
        assert sp.axis_size("model") == 4
        assert sp.describe() == "model=4|expert@model|all_to_all"
        assert hash(sp) == hash(ShardSpec(
            mesh=(("model", 4),), partition=(("expert", "model"),),
            collective="all_to_all"))

    def test_validation(self):
        from repro.distributed.sharding import ShardSpec
        with pytest.raises(ValueError):
            ShardSpec(collective="broadcast")
        with pytest.raises(ValueError):
            ShardSpec(mesh=(("model", 4),),
                      partition=(("ffn", "tensor"),))  # axis not in mesh
        with pytest.raises(ValueError):
            ShardSpec(mesh=(("model", 0),))

    def test_for_axis_from_live_mesh(self):
        from repro.distributed.sharding import ShardSpec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sp = ShardSpec.for_axis(mesh, "model", dim="ffn",
                                collective="all_reduce")
        assert sp.mesh == (("model", 1),) and sp.n_shards == 1

    def test_train_shard_spec_dispatch(self):
        from repro.distributed.sharding import train_shard_spec
        from repro.configs.base import ModelConfig, MoEConfig
        mesh = FakeMesh({"data": 2, "model": 4})
        ep_cfg = ModelConfig(name="t", family="lm", num_layers=1, d_model=64,
                             num_heads=4, num_kv_heads=2, d_ff=128,
                             vocab_size=64, block_pattern=("moe",),
                             moe=MoEConfig(num_experts=8, top_k=2))
        sp = train_shard_spec(ep_cfg, mesh)
        assert sp.collective == "all_to_all" and sp.n_shards == 4
        tp_cfg = ModelConfig(name="t", family="lm", num_layers=1, d_model=64,
                             num_heads=4, num_kv_heads=2, d_ff=128,
                             vocab_size=64, block_pattern=("moe",),
                             moe=MoEConfig(num_experts=3, top_k=2))
        assert train_shard_spec(tp_cfg, mesh).collective == "all_reduce"
        dense = ModelConfig(name="t", family="lm", num_layers=1, d_model=64,
                            num_heads=4, num_kv_heads=2, d_ff=128,
                            vocab_size=64)
        assert train_shard_spec(dense, mesh).collective == "all_reduce"
        assert train_shard_spec(dense, FakeMesh({"data": 8})) is None
        assert train_shard_spec(dense, None) is None


# ---------------------------------------------------------------------------
# Shared sharding helpers (S1/S3): divisibility, sizing, free-dim edge cases
# ---------------------------------------------------------------------------
class TestShardingHelpers:
    def test_divisible_axes(self):
        from repro.distributed.sharding import divisible_axes
        mesh = FakeMesh({"pod": 2, "data": 4, "model": 2})
        assert divisible_axes(16, mesh, ("pod", "data")) == ("pod", "data")
        assert divisible_axes(12, mesh, ("pod", "data")) is None  # 12 % 8
        assert divisible_axes(12, mesh, ("data",)) == ("data",)
        # axes missing from the mesh are filtered, not fatal
        assert divisible_axes(16, FakeMesh({"model": 2}),
                              ("pod", "data")) is None

    def test_leaf_nbytes(self):
        from repro.distributed.sharding import leaf_nbytes
        assert leaf_nbytes(jnp.zeros((4, 8), jnp.float32)) == 128
        assert leaf_nbytes(jax.ShapeDtypeStruct((4, 8), jnp.bfloat16)) == 64

    def test_shard_free_dim_axis_already_used(self):
        from repro.distributed.sharding import _shard_free_dim
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P("data", None))
        assert _shard_free_dim(sh, (8, 8), mesh, "data") is None
        # axis inside a tuple entry also counts as used
        sh2 = NamedSharding(mesh, P(("data", "model"), None))
        assert _shard_free_dim(sh2, (8, 8), mesh, "data") is None

    def test_shard_free_dim_no_divisible_dim(self):
        from repro.distributed.sharding import _shard_free_dim
        from jax.sharding import NamedSharding

        class _Sh:   # minimal stand-in with a .spec (no device checks hit)
            spec = P(None, None)
        mesh = FakeMesh({"data": 3})
        assert _shard_free_dim(_Sh(), (4, 5), mesh, "data") is None
        # dims smaller than the axis extent don't shard either
        assert _shard_free_dim(_Sh(), (2, 1), mesh, "data") is None

    def test_fsdp_min_bytes_cutoff(self):
        from repro.distributed.sharding import fsdp_shardings
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P(None, None))
        small = jnp.zeros((4, 4), jnp.float32)          # 64 B < min_bytes
        big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)   # 4 MiB
        out = fsdp_shardings({"a": sh, "b": sh}, {"a": small, "b": big},
                             mesh, min_bytes=2**20)
        assert out["a"] is sh                            # untouched
        assert out["b"].spec != sh.spec                  # resharded
        assert "data" in jax.tree.leaves(tuple(out["b"].spec))

    def test_fsdp_without_data_axis_is_identity(self):
        from repro.distributed.sharding import fsdp_shardings
        mesh = FakeMesh({"model": 4})
        tree = {"a": object()}
        assert fsdp_shardings(tree, {"a": jnp.zeros((8, 8))}, mesh) is tree

    def test_batch_specs_fallback_replicates(self):
        from repro.distributed.sharding import batch_specs
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        out = batch_specs({"x": jnp.zeros((4, 8))}, mesh)
        assert out["x"].spec in (P("data", None), P(("data",), None))

    def test_spec_for_reports_fallback(self):
        from repro.distributed.sharding import shardings_for_tree
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        report = []
        # vocab 51865 is indivisible by any >1 axis; on the 1x1 mesh it
        # shards, so force the fallback with a fake 16-way mesh via spec_for
        spec = spec_for((51865, 512), ("vocab", "embed"),
                        FakeMesh({"model": 16}), report=report)
        assert spec == P(None, None)
        assert report[0][1] == "vocab" and report[0][3] == 16


# ---------------------------------------------------------------------------
# Collective chain models (perf_model §16)
# ---------------------------------------------------------------------------
class TestCollectiveModels:
    def test_wire_bytes(self):
        from repro.core import perf_model as pm
        nb = 1 << 20
        assert pm.collective_wire_bytes("all_gather", nb, 4) == nb * 3 // 4
        assert pm.collective_wire_bytes("reduce_scatter", nb, 4) == nb * 3 // 4
        assert pm.collective_wire_bytes("all_reduce", nb, 4) == 2 * nb * 3 // 4
        assert pm.collective_wire_bytes("all_gather", nb, 1) == 0
        assert pm.collective_wire_bytes("none", nb, 8) == 0

    def test_chain_model_overlap_fields(self):
        from repro.core import perf_model as pm
        chain = pm.mlp_chain_model(tokens=4096, d_model=2048, d_ff=8192,
                                   gated=True, dtype_bytes=2, fused=True)
        out = pm.collective_chain_model(chain, collective="all_to_all",
                                        nbytes=4096 * 2048 * 2, n_shards=4)
        assert out["collective"] == "all_to_all"
        assert out["collective_bytes"] > 0
        assert 0.0 <= out["overlap_fraction"] <= 1.0
        assert out["dma_bytes"] > out["hbm_dma_bytes"]   # wire folded in
        assert out["time_s"] >= chain["time_s"]

    def test_collective_gemm_ring_beats_gather(self):
        from repro.core import perf_model as pm
        ring = pm.collective_gemm_model(m=4096, n=4096, k=4096, n_shards=4,
                                        fused=True)
        gath = pm.collective_gemm_model(m=4096, n=4096, k=4096, n_shards=4,
                                        fused=False)
        assert ring["dma_bytes"] < gath["dma_bytes"]
        assert ring["overlap_fraction"] > 0.0
        assert gath["overlap_fraction"] == 0.0
        assert ring["ring_steps"] == 4
        assert ring["time_s"] <= gath["time_s"]

    def test_partial_softmax_allreduce(self):
        from repro.core import perf_model as pm
        out = pm.partial_softmax_allreduce_model(rows=4096, head_dim=128,
                                                 n_shards=4)
        assert out["kind"] == "all_reduce"
        # rows * (head_dim + 2) fp32 values, 2(n-1)/n wire factor
        want = 2 * 4096 * 130 * 4 * 3 // 4
        assert out["wire_bytes"] == want


# ---------------------------------------------------------------------------
# Sharded plan selection: memo keys, journaling, pretuned keys
# ---------------------------------------------------------------------------
class TestShardedPlans:
    def _spec(self, collective="all_to_all", dim="expert"):
        from repro.distributed.sharding import ShardSpec
        return ShardSpec(mesh=(("model", 4),), partition=((dim, "model"),),
                         collective=collective)

    def test_select_fusion_sharded_plan_journaled(self):
        from repro import obs
        from repro.core import autotune
        sp = self._spec()
        with obs.capture() as rec:
            plan = autotune.select_fusion("mlp", (4096, 2048, 2048, 1),
                                          "bfloat16", residual=False,
                                          shard=sp)
        assert plan["plan"] == "fused"
        assert plan["shard"] == sp.describe()
        assert plan["overlap_fraction"] > 0.0
        evs = [e for e in rec.plans if e.kind == "fusion"
               and e.chosen.get("shard") == sp.describe()]
        assert evs, "sharded fusion verdict must be plan-audit journaled"

    def test_shard_joins_memo_key(self):
        from repro.core import autotune
        shape = (2048, 1024, 4096, 1)
        plain = autotune.select_fusion("mlp", shape, "bfloat16",
                                       residual=False)
        sharded = autotune.select_fusion("mlp", shape, "bfloat16",
                                         residual=False, shard=self._spec())
        assert "shard" not in plain
        assert sharded["shard"] and sharded is not plain

    def test_pretuned_fusion_key_shard_token(self):
        from repro.core import autotune
        base = autotune.pretuned_fusion_key(
            "mlp", (4096, 2048, 8192, 1), "bfloat16", residual=False,
            prenorm="none", backward=False, causal=False, softcap=False,
            sink=False)
        sharded = autotune.pretuned_fusion_key(
            "mlp", (4096, 2048, 8192, 1), "bfloat16", residual=False,
            prenorm="none", backward=False, causal=False, softcap=False,
            sink=False, shard=self._spec())
        assert "shard=" not in base          # shipped tables stay valid
        assert sharded == base + "|shard=model=4|expert@model|all_to_all"

    def test_signature_bucket_carries_shard(self):
        from repro.core.autotune import OpSignature
        sig = OpSignature(op="gemm", shape=(128, 128, 128),
                          dtype="bfloat16", shard=self._spec())
        assert sig.bucket()[-1] == self._spec()
        assert OpSignature(op="gemm", shape=(128, 128, 128),
                           dtype="bfloat16").bucket()[-1] is None

    def test_gemm_collective_kind_requires_shard(self):
        from repro.core import autotune
        with pytest.raises(ValueError):
            autotune.select_fusion("gemm_collective", (4096, 4096, 4096),
                                   "bfloat16")
        plan = autotune.select_fusion(
            "gemm_collective", (4096, 4096, 4096), "bfloat16",
            shard=self._spec(collective="all_gather", dim="rows"))
        assert plan["plan"] == "fused" and plan["overlap_fraction"] > 0

    def test_policies_for_model_sharded(self):
        from repro.core import autotune
        from repro.configs import get_config
        cfg = get_config("mixtral-8x7b", smoke=True)
        pols = autotune.policies_for_model(cfg, batch=2, seq_len=128,
                                           shard=self._spec())
        assert pols  # resolves without error, sharded cells included


class TestMultiDeviceFused:
    """Fused shard_map experts + ring collective GEMM: bitwise contracts on
    the 8-forced-host-device harness (DESIGN.md §16)."""

    def test_moe_fused_bitwise_ep_and_tp(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_forward, moe_defs
from repro.models.common import init_params
mesh = jax.make_mesh((2, 4), ('data', 'model'))
for impl, n_exp in (('ep', 8), ('tp', 8)):
    cfg = ModelConfig(name='t', family='lm', num_layers=1, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=64,
                      block_pattern=('moe',),
                      moe=MoEConfig(num_experts=n_exp, top_k=2,
                                    capacity_factor=4.0, impl=impl,
                                    shard='expert' if impl == 'ep' else 'ffn'))
    params = init_params(moe_defs(cfg, 'moe'), jax.random.PRNGKey(0))['moe']
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 64, 128)) * 0.1
         ).astype(jnp.float32)
    prenorm = (jnp.ones((128,)) * 1.5, None)
    o_ref, _ = moe_forward(cfg, params, x, mesh=mesh, mode='reference',
                           prenorm=prenorm)
    o_fus, _ = moe_forward(cfg, params, x, mesh=mesh,
                           mode='pallas_interpret', prenorm=prenorm)
    diff = float(jnp.abs(o_ref - o_fus).max())
    print(impl, 'bitwise', diff)
    assert diff == 0.0, (impl, diff)
print('OK')
""")
        assert "OK" in out

    def test_moe_collective_mode_fallback_observable(self, subproc):
        """pallas_tpu inside shard_map is gated to reference — the fallback
        must hit the counter AND the plan-audit journal (satellite S2)."""
        out = subproc("""
import jax, jax.numpy as jnp
from repro import obs
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_forward, moe_defs
from repro.models.common import init_params
cfg = ModelConfig(name='t', family='lm', num_layers=1, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  block_pattern=('moe',),
                  moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0))
params = init_params(moe_defs(cfg, 'moe'), jax.random.PRNGKey(0))['moe']
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
with obs.capture() as rec:
    o, _ = moe_forward(cfg, params, x, mesh=mesh, mode='pallas_tpu')
assert rec.counters.get('moe.collective_mode_fallback', 0) >= 1
evs = [e for e in rec.plans if e.kind == 'collective_mode']
assert evs and evs[0].chosen['requested'] == 'pallas_tpu'
assert evs[0].chosen['mode'] == 'reference'
print('OK')
""")
        assert "OK" in out

    def test_gemm_collective_ring_bitwise(self, subproc):
        """Ring == gather-then-gemm == jnp oracle, bitwise, both variants,
        reference and pallas_interpret (acceptance gate)."""
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.kernels.gemm import (gemm_collective_sharded,
                                gemm_collective_oracle)
mesh = jax.make_mesh((4,), ('model',))
M, K, N = 64, 128, 96
x = (jax.random.normal(jax.random.PRNGKey(0), (M, K)) * 0.1
     ).astype(jnp.float32)
w = (jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
     ).astype(jnp.float32)
for variant in ('all_gather', 'reduce_scatter'):
    oracle = gemm_collective_oracle(x, w, variant=variant, axis_size=4)
    if variant == 'reduce_scatter':
        oracle = oracle.reshape(-1, N)
    for mode in ('reference', 'pallas_interpret'):
        ring = gemm_collective_sharded(x, w, mesh=mesh, variant=variant,
                                       mode=mode, plan='ring')
        gather = gemm_collective_sharded(x, w, mesh=mesh, variant=variant,
                                         mode=mode, plan='gather')
        assert jnp.array_equal(ring, gather), (variant, mode, 'ring!=gather')
        assert jnp.array_equal(ring, oracle), (variant, mode, 'ring!=oracle')
        print(variant, mode, 'bitwise OK')
print('OK')
""", devices=4)
        assert "OK" in out

    def test_gemm_collective_autotuned_plan(self, subproc):
        """plan=None consults select_fusion with the interconnect term; on
        square train shapes the ring must win and be journaled."""
        out = subproc("""
import jax, jax.numpy as jnp
from repro import obs
from repro.kernels.gemm import gemm_collective_sharded
mesh = jax.make_mesh((4,), ('model',))
x = (jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 0.1
     ).astype(jnp.float32)
w = (jax.random.normal(jax.random.PRNGKey(1), (128, 96)) * 0.1
     ).astype(jnp.float32)
with obs.capture() as rec:
    gemm_collective_sharded(x, w, mesh=mesh, variant='all_gather',
                            mode='pallas_interpret', plan=None)
assert rec.counters.get('gemm_collective.all_gather.ring', 0) >= 1
print('OK')
""", devices=4)
        assert "OK" in out

    def test_train_loop_sharded_plan_pins(self, subproc):
        """train_loop on a dp×tp mesh pins bucket policies through the
        sharded plan path (train_shard_spec) without breaking the step."""
        out = subproc("""
import jax, numpy as np
from repro import obs
from repro.configs import get_config
from repro.models import build_model
from repro.train import train_loop
from repro.optim import AdamWConfig, constant_schedule
from repro.data.pipeline import DataConfig, DataIterator
cfg = get_config('mixtral-8x7b', smoke=True)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
model = build_model(cfg, mode='reference', mesh=mesh)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
it = DataIterator(dcfg, mesh=mesh)
res = train_loop(model, it, 2, AdamWConfig(schedule=constant_schedule(1e-3)),
                 mesh=mesh, log=lambda *a, **k: None)
assert len(res.losses) == 2 and all(np.isfinite(l) for l in res.losses)
assert res.policies, 'bucket policies must be pinned'
print('OK')
""")
        assert "OK" in out


class TestShardedPagedEngine:
    """Per-host page-pool topology (serve/topology.py)."""

    def _setup(self):
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("granite-8b", smoke=True)
        model = build_model(cfg, mode="reference")
        params = model.init(jax.random.PRNGKey(0))
        return cfg, model, params

    def _reqs(self, cfg, n=4, max_new=4):
        from repro.serve import Request
        out = []
        for i in range(n):
            prompt = jax.random.randint(jax.random.PRNGKey(100 + i),
                                        (6 + i,), 0, cfg.vocab_size)
            out.append(Request(uid=i, prompt=prompt,
                               max_new_tokens=max_new))
        return out

    def test_parity_with_single_engine(self):
        from repro.serve import Engine, ShardedPagedEngine
        cfg, model, params = self._setup()
        reqs = self._reqs(cfg)
        eng = ShardedPagedEngine(model, params, n_hosts=2, batch_slots=2,
                                 page_size=8, max_pages_per_seq=4)
        for r in reqs:
            eng.submit(r)
        results = eng.run()
        golden = Engine(model, params, max_len=64)
        for r in reqs:
            want = golden.generate(r.prompt[None, :],
                                   r.max_new_tokens).tokens[0]
            assert jnp.array_equal(jnp.asarray(results[r.uid]),
                                   jnp.asarray(want)), r.uid

    def test_placement_and_report(self):
        from repro.serve import ShardedPagedEngine
        cfg, model, params = self._setup()
        reqs = self._reqs(cfg, n=4)
        eng = ShardedPagedEngine(model, params, n_hosts=2, batch_slots=2,
                                 page_size=8, max_pages_per_seq=4)
        for r in reqs:
            eng.submit(r)
        eng.run()
        rep = eng.report()
        assert rep["n_hosts"] == 2
        assert sum(rep["admissions_by_host"]) == 4
        # deterministic least-loaded admission spreads the 4 requests 2/2
        assert rep["admissions_by_host"] == [2, 2]
        assert rep["completed"] == 4
        assert set(rep["placements"]) == {0, 1, 2, 3}
        assert len(rep["per_host"]) == 2
        assert rep["page_pool_size"] == 2 * rep["per_host"][0]["page_pool_size"]

    def test_duplicate_uid_rejected(self):
        from repro.serve import ShardedPagedEngine
        cfg, model, params = self._setup()
        (req,) = self._reqs(cfg, n=1)
        eng = ShardedPagedEngine(model, params, n_hosts=2, batch_slots=2,
                                 page_size=8, max_pages_per_seq=4)
        eng.submit(req)
        with pytest.raises(ValueError):
            eng.submit(req)

    def test_bad_host_count_rejected(self):
        from repro.serve import ShardedPagedEngine
        cfg, model, params = self._setup()
        with pytest.raises(ValueError):
            ShardedPagedEngine(model, params, n_hosts=0)
