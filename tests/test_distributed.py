"""Sharding rules + multi-device (subprocess) distribution tests."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import spec_for


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestSpecRules:
    def test_divisible_shards(self):
        mesh = FakeMesh({"data": 16, "model": 16})
        assert spec_for((152064, 8192), ("vocab", "embed"), mesh) == \
            P("model", None)
        assert spec_for((8192, 29568), ("embed", "ffn"), mesh) == \
            P(None, "model")

    def test_indivisible_replicates(self):
        mesh = FakeMesh({"data": 16, "model": 16})
        report = []
        spec = spec_for((51865, 512), ("vocab", "embed"), mesh, report=report)
        assert spec == P(None, None)
        assert report  # the fallback is reported, not silent

    def test_batch_axes_compose(self):
        mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
        assert spec_for((256, 4096), ("batch", None), mesh) == \
            P(("pod", "data"), None)


class TestZero1Fsdp:
    """ZeRO-1/FSDP shard the largest free divisible dim (not just dim0) —
    required for stacked MoE tensors like (24, 128, 5120, 8192)."""

    def test_shard_free_dim_picks_largest(self):
        from repro.distributed.sharding import _shard_free_dim
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P(None, "model", None, None))
        out = _shard_free_dim(sh, (24, 128, 5120, 8192), mesh, "data")
        assert out is not None
        assert out.spec[3] == "data"          # largest free dim
        assert out.spec[1] == "model"         # existing sharding kept

    def test_vocab_padding_config(self):
        import dataclasses
        from repro.configs import get_config
        cfg = dataclasses.replace(get_config("minicpm-2b"),
                                  vocab_pad_multiple=128)
        assert cfg.padded_vocab() % 128 == 0
        assert cfg.padded_vocab() >= cfg.vocab_size
        assert cfg.padded_vocab() - cfg.vocab_size < 128


class TestMultiDevice:
    def test_dp_tp_train_step(self, subproc):
        """2x4 mesh: sharded init + sharded train step run and give finite
        loss; params stay sharded."""
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.train import make_train_step, sharded_init
from repro.optim import AdamWConfig, constant_schedule
from repro.data.pipeline import DataConfig, DataIterator
mesh = jax.make_mesh((2, 4), ('data', 'model'))
cfg = get_config('qwen2-72b', smoke=True)
model = build_model(cfg, mode='reference', mesh=mesh)
state = sharded_init(model, jax.random.PRNGKey(0), mesh, zero1=True)
dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
it = DataIterator(dcfg, mesh=mesh)
step = make_train_step(model, AdamWConfig(schedule=constant_schedule(1e-3)), mesh=mesh, zero1=True)
s2, m = step(state, next(it))
print('loss', float(m['loss']))
assert np.isfinite(float(m['loss']))
# a TP-sharded leaf really is distributed
leaf = s2['params']['blocks']['attn']['wqk']
assert len(leaf.sharding.device_set) > 1
print('OK')
""")
        assert "OK" in out

    def test_moe_ep_multidevice(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_forward, moe_defs, moe_dense
from repro.models.common import init_params
cfg = ModelConfig(name='t', family='lm', num_layers=1, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  block_pattern=('moe',),
                  moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0))
params = init_params(moe_defs(cfg, 'moe'), jax.random.PRNGKey(0))['moe']
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
o_ep, _ = moe_forward(cfg, params, x, mesh=mesh)
o_d, _ = moe_dense(cfg, params, x)
assert float(jnp.abs(o_ep - o_d).max()) < 1e-4
print('OK')
""")
        assert "OK" in out

    def test_elastic_checkpoint_reshard(self, subproc):
        """Save on a 4-device data mesh, restore onto a 2x2 mesh (different
        sharding) — values must round-trip exactly."""
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.configs import get_config
from repro.models import build_model
from repro.train import init_state, state_shardings, checkpoint as ckpt
cfg = get_config('granite-8b', smoke=True)
with tempfile.TemporaryDirectory() as d:
    mesh1 = jax.make_mesh((4, 2), ('data', 'model'))
    model1 = build_model(cfg, mode='reference', mesh=mesh1)
    state = init_state(model1, jax.random.PRNGKey(0))
    ckpt.save(state, d, 7)
    mesh2 = jax.make_mesh((2, 4), ('data', 'model'))
    model2 = build_model(cfg, mode='reference', mesh=mesh2)
    tpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    sh = state_shardings(model2, mesh2, zero1=True)
    restored, step = ckpt.restore(d, tpl, shardings=sh)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('OK')
""")
        assert "OK" in out

    @pytest.mark.slow
    def test_mini_dryrun_512(self, subproc):
        """The real thing: 512 fake devices, production meshes, one arch ×
        shape on both meshes, roofline terms extracted."""
        out = subproc("""
from repro.launch.dryrun import run_cell
for mesh in ('single', 'multi'):
    rec = run_cell('mamba2-130m', 'train_4k', mesh, verbose=False)
    assert rec['status'] == 'ok', rec
    assert rec['roofline']['flops_per_chip'] > 0
    assert rec['roofline']['collective_bytes_per_chip'] > 0
print('OK')
""", devices=512, timeout=900)
        assert "OK" in out
