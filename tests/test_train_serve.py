"""Trainer (checkpoint/restart/failure/straggler) + serving integration."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.data.pipeline import DataConfig, DataIterator
from repro.optim import AdamWConfig, cosine_schedule
from repro.train import (train_loop, FailureInjector, StragglerWatchdog,
                         init_state, checkpoint as ckpt)
from repro.serve import Engine, Request, RequestQueue


def tiny_model():
    cfg = get_config("granite-8b", smoke=True)
    return build_model(cfg, mode="reference"), cfg


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        model, _ = tiny_model()
        state = init_state(model, jax.random.PRNGKey(0))
        ckpt.save(state, str(tmp_path), 5)
        tpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                           state)
        restored, step = ckpt.restore(str(tmp_path), tpl)
        assert step == 5
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected(self, tmp_path):
        model, _ = tiny_model()
        state = init_state(model, jax.random.PRNGKey(0))
        ckpt.save(state, str(tmp_path), 1)
        ckpt.save(state, str(tmp_path), 2)
        # corrupt the newest payload: restore must fall back to step 1
        with open(tmp_path / "step_00000002" / "arrays.npz", "r+b") as f:
            f.seek(100)
            f.write(b"garbage")
        assert ckpt.available_steps(str(tmp_path)) == [1]

    def test_keep_n(self, tmp_path):
        model, _ = tiny_model()
        state = init_state(model, jax.random.PRNGKey(0))
        for s in range(6):
            ckpt.save(state, str(tmp_path), s, keep=2)
        assert ckpt.available_steps(str(tmp_path)) == [4, 5]

    def test_async_checkpointer(self, tmp_path):
        model, _ = tiny_model()
        state = init_state(model, jax.random.PRNGKey(0))
        ac = ckpt.AsyncCheckpointer(str(tmp_path))
        ac.save(state, 3)
        ac.wait()
        assert ckpt.available_steps(str(tmp_path)) == [3]


def _loop(tmp_path, steps, fail_at=(), ckpt_every=10, microbatches=1,
          grad_compress=False):
    model, cfg = tiny_model()
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      noise=0.05)
    opt = AdamWConfig(schedule=cosine_schedule(3e-3, 10, steps))
    return train_loop(
        model, DataIterator(dcfg), steps, opt,
        ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
        failure_injector=FailureInjector(tuple(fail_at)),
        watchdog=StragglerWatchdog(), microbatches=microbatches,
        grad_compress=grad_compress, log_every=0)


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        res = _loop(tmp_path / "a", 40)
        assert res.losses[-1] < res.losses[0] - 0.5

    def test_failure_recovery_resumes(self, tmp_path):
        res = _loop(tmp_path / "b", 30, fail_at=(17,))
        assert res.restarts == 1
        assert len(res.losses) > 30  # replayed steps after restore

    def test_restart_trajectory_matches(self, tmp_path):
        """Recovery must be *exact*: a failed+restored run ends with the
        same loss trajectory as an uninterrupted one (stateless data +
        checkpointed state)."""
        r1 = _loop(tmp_path / "c1", 30)
        r2 = _loop(tmp_path / "c2", 30, fail_at=(25,), ckpt_every=10)
        np.testing.assert_allclose(r1.losses[-5:], r2.losses[-5:], atol=1e-5)

    def test_microbatch_equivalence(self, tmp_path):
        """Grad accumulation over k microbatches ≈ the full-batch step."""
        r1 = _loop(tmp_path / "d1", 10, microbatches=1)
        r2 = _loop(tmp_path / "d2", 10, microbatches=2)
        np.testing.assert_allclose(r1.losses, r2.losses, atol=5e-2)

    def test_grad_compress_trains(self, tmp_path):
        res = _loop(tmp_path / "e", 40, grad_compress=True)
        assert res.losses[-1] < res.losses[0] - 0.4

    def test_straggler_watchdog(self):
        wd = StragglerWatchdog(factor=2.0, warmup=3)
        for i in range(10):
            wd.observe(i, 0.1)
        assert not wd.events
        assert wd.observe(10, 1.0)
        assert wd.events[0][0] == 10


class TestServe:
    def test_greedy_deterministic(self):
        model, cfg = tiny_model()
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_len=64)
        p = np.array([[1, 2, 3, 4]], np.int32)
        r1 = eng.generate(p, 8)
        r2 = eng.generate(p, 8)
        assert (r1.tokens == r2.tokens).all()
        assert r1.tokens.shape == (1, 12)

    def test_decode_matches_rescoring(self):
        """Greedy decode emits exactly the argmax of a full re-scoring
        forward over the generated prefix."""
        model, cfg = tiny_model()
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_len=64)
        p = np.array([[5, 6, 7, 8, 9, 10]], np.int32)
        out = eng.generate(p, 4).tokens
        logits, _ = model.forward(params, jnp.asarray(out[:, :-1]))
        for i in range(out.shape[1] - p.shape[1]):
            pos = p.shape[1] - 1 + i
            assert out[0, pos + 1] == int(jnp.argmax(logits[0, pos]))

    def test_queue_buckets_and_serves_all(self):
        model, cfg = tiny_model()
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_len=96)
        q = RequestQueue(eng, batch_size=2, buckets=(8, 16))
        rng = np.random.default_rng(0)
        for uid in range(5):
            plen = int(rng.integers(4, 16))
            q.submit(Request(uid, rng.integers(0, cfg.vocab_size, plen)
                             .astype(np.int32), 4))
        q.flush(force=True)
        assert set(q.results) == set(range(5))

    def test_sampling_respects_temperature(self):
        model, cfg = tiny_model()
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_len=64)
        p = np.array([[1, 2, 3, 4]], np.int32)
        r1 = eng.generate(p, 8, temperature=1.0, rng=jax.random.PRNGKey(1))
        r2 = eng.generate(p, 8, temperature=1.0, rng=jax.random.PRNGKey(2))
        assert (r1.tokens != r2.tokens).any()
