"""Calibration subsystem tests (DESIGN.md §15): pretuned-table round-trip
through select_policy, schema/arch fallback with logged counters,
coefficient-fit determinism, and the drift gate on clean vs. perturbed
reports."""
import copy
import json
import math

import pytest

from repro import obs
from repro.core import autotune
from repro.core import calibrate as cal
from repro.core import perf_model as pm
from repro.core.autotune import OpSignature
from repro.core.policy import policy_from_spec


@pytest.fixture(autouse=True)
def _clean_caches():
    autotune.clear_policy_cache()
    autotune.clear_pretuned()
    yield
    autotune.clear_policy_cache()
    autotune.clear_pretuned()


@pytest.fixture(scope="module")
def smoke_report():
    autotune.clear_policy_cache()
    return cal.calibrate(smoke=True, seed=0, arch="cpu")


# ---------------------------------------------------------------------------
# Report shape and determinism
# ---------------------------------------------------------------------------


class TestCalibrate:
    def test_report_covers_sweep(self, smoke_report):
        r = smoke_report
        assert r["schema_version"] == autotune.PRETUNED_SCHEMA_VERSION
        assert r["arch"] == "cpu"
        ops = {c["sig"]["op"] for c in r["cells"].values()}
        assert {"gemm", "attention_fwd", "attention_decode",
                "fused_norm", "rope"} <= ops
        assert r["fusion"]  # chain plans pinned too
        for cell in r["cells"].values():
            assert cell["candidates"], "every cell measures candidates"
            # candidate 0 is the analytic winner by construction
            best = min(c["analytic_time_s"] for c in cell["candidates"])
            assert cell["candidates"][0]["analytic_time_s"] == best

    def test_report_is_json_serializable(self, smoke_report):
        json.loads(json.dumps(smoke_report))

    def test_deterministic_under_fixed_seed(self, smoke_report):
        again = cal.calibrate(smoke=True, seed=0, arch="cpu")
        assert again == smoke_report

    def test_jittered_rig_is_deterministic(self):
        rig = cal.CalibrationRig(jitter=0.2, seed=7)
        sig = OpSignature("gemm", (512, 512, 512))
        pol = autotune.candidate_policies(sig)[0]
        assert rig.time(sig, pol) == rig.time(sig, pol)
        base = cal.CalibrationRig().time(sig, pol)
        assert rig.time(sig, pol) != base  # the jitter actually perturbs
        assert math.isclose(rig.time(sig, pol), base, rel_tol=0.25)


class TestFit:
    def test_recovers_additive_coefficients(self):
        # Samples built from a known additive law must be recovered
        # near-exactly: t = F/a + V/b + B/c + S*d.
        a, b, c, d = 150e12, 9e12, 700e9, 2e-6
        feats = [
            dict(mxu_flops=1e12, vector_ops=1e9, dma_bytes=1e9,
                 grid_steps=64),
            dict(mxu_flops=4e12, vector_ops=8e9, dma_bytes=2e9,
                 grid_steps=256),
            dict(mxu_flops=2e11, vector_ops=5e10, dma_bytes=8e9,
                 grid_steps=16),
            dict(mxu_flops=9e12, vector_ops=2e8, dma_bytes=5e8,
                 grid_steps=1024),
            dict(mxu_flops=3e12, vector_ops=3e9, dma_bytes=6e9,
                 grid_steps=128),
        ]
        samples = [(f, f["mxu_flops"] / a + f["vector_ops"] / b
                    + f["dma_bytes"] / c + f["grid_steps"] * d)
                   for f in feats]
        chip, info = cal.fit_chip(samples, [], arch="cpu")
        assert chip["name"] == "cpu_calibrated"
        assert math.isclose(chip["peak_flops_bf16"], a, rel_tol=1e-6)
        assert math.isclose(chip["vector_flops"], b, rel_tol=1e-6)
        assert math.isclose(chip["hbm_bw"], c, rel_tol=1e-6)
        assert math.isclose(chip["step_overhead_s"], d, rel_tol=1e-6)

    def test_recovers_decode_ramp(self):
        # Decode samples generated with ramp=12 and the default bw/step
        # (no linear samples, so the lstsq stage keeps defaults).
        bw, step, ramp = pm.V5E.hbm_bw, 1e-6, 12
        ds = []
        for steps, kv in [(2, 1 << 20), (6, 1 << 22), (12, 1 << 23),
                          (24, 1 << 24), (32, 1 << 24)]:
            f = dict(grid_steps=steps, kv_bytes=float(kv),
                     other_bytes=float(kv // 16))
            util = min(1.0, steps / ramp)
            ds.append((f, f["kv_bytes"] / (bw * util)
                       + f["other_bytes"] / bw + steps * step))
        chip, _ = cal.fit_chip([], ds, arch="cpu")
        assert chip["decode_saturation_steps"] == ramp

    def test_empty_sweep_falls_back_to_analytic_defaults(self):
        chip, info = cal.fit_chip([], [], arch="cpu")
        assert chip["peak_flops_bf16"] == pm.V5E.peak_flops_bf16
        assert chip["hbm_bw"] == pm.V5E.hbm_bw
        assert info["n_samples"] == 0

    def test_fitted_chip_installs_as_chipspec(self, smoke_report):
        chip = autotune.chip_from_dict(smoke_report["chip"])
        assert isinstance(chip, pm.ChipSpec)
        assert chip.name == "cpu_calibrated"
        assert chip.peak_flops_bf16 > 0 and chip.hbm_bw > 0
        assert chip.vector_throughput() > 0


# ---------------------------------------------------------------------------
# Pretuned table round-trip through select_policy
# ---------------------------------------------------------------------------


class TestPretunedRoundTrip:
    def test_write_load_select_returns_pinned_winner(self, smoke_report,
                                                     tmp_path):
        path = tmp_path / "CALIB_cpu.json"
        cal.save_report(smoke_report, path)
        assert autotune.load_pretuned(path, arch="cpu")

        sig = OpSignature("gemm", (512, 512, 512))
        key = autotune.pretuned_cell_key(sig)
        cell = smoke_report["cells"][key]
        expected = policy_from_spec(cell["policy"])
        with obs.capture() as rec:
            got = autotune.select_policy("gemm", (512, 512, 512))
        # bitwise: frozen-dataclass equality over every schedule/swizzle
        # field, not just the block shape
        assert got == expected
        assert rec.counter("autotune.pretuned_hit") == 1

    def test_pinned_winner_rides_chains(self, smoke_report, tmp_path):
        from repro.kernels.gemm.epilogue import Epilogue
        cal.save_report(smoke_report, tmp_path / "t.json")
        assert autotune.load_pretuned(tmp_path / "t.json", arch="cpu")
        ep = Epilogue(activation="silu", gate=True)
        got = autotune.select_policy("gemm", (1024, 2048, 1024),
                                     epilogue=ep)
        sig = OpSignature("gemm", (1024, 2048, 1024), epilogue=ep)
        cell = smoke_report["cells"][autotune.pretuned_cell_key(sig)]
        assert got == policy_from_spec(cell["policy"], epilogue=ep)
        assert got.epilogue is ep  # live chain object re-attached

    def test_cell_miss_falls_through_to_analytic(self, smoke_report):
        assert autotune.install_pretuned(smoke_report, arch="cpu")
        shape = (768, 768, 768)  # not in the smoke sweep
        with obs.capture() as rec:
            got = autotune.select_policy("gemm", shape)
        assert rec.counter("autotune.pretuned_cell_miss") == 1
        autotune.clear_pretuned()
        autotune.clear_policy_cache()
        assert got == autotune.select_policy("gemm", shape)

    def test_install_invalidates_memoized_selection(self, smoke_report):
        # Satellite fix: the memo key carries the table generation, so a
        # cached analytic pick cannot shadow a freshly installed table.
        sig = OpSignature("gemm", (512, 512, 512))
        analytic = autotune.select_policy("gemm", (512, 512, 512))
        assert autotune.policy_cache_stats()["size"] >= 1
        gen = autotune.pretuned_generation()
        assert autotune.install_pretuned(smoke_report, arch="cpu")
        assert autotune.pretuned_generation() == gen + 1
        cell = smoke_report["cells"][autotune.pretuned_cell_key(sig)]
        pinned = policy_from_spec(cell["policy"])
        got = autotune.select_policy("gemm", (512, 512, 512))
        assert got == pinned
        # (analytic may coincide with pinned; the point is the re-lookup)
        autotune.clear_pretuned()
        assert autotune.select_policy("gemm", (512, 512, 512)) == analytic

    def test_pinning_skipped_for_pinned_swizzle_and_cache_sim(
            self, smoke_report):
        assert autotune.install_pretuned(smoke_report, arch="cpu")
        from repro.core.policy import ROW_MAJOR
        got = autotune.select_policy("gemm", (512, 512, 512),
                                     swizzle=ROW_MAJOR)
        assert got.swizzle == ROW_MAJOR


class TestPretunedRejection:
    def test_schema_mismatch_falls_back_with_counter(self, smoke_report):
        bad = copy.deepcopy(smoke_report)
        bad["schema_version"] = autotune.PRETUNED_SCHEMA_VERSION + 1
        with obs.capture() as rec:
            assert not autotune.install_pretuned(bad, arch="cpu")
        assert rec.counter("autotune.pretuned_rejected_schema") == 1
        assert autotune.active_pretuned() is None
        # selection still works, purely analytic
        assert autotune.select_policy("gemm", (512, 512, 512)) is not None

    def test_arch_mismatch_falls_back_with_counter(self, smoke_report):
        other = copy.deepcopy(smoke_report)
        other["arch"] = "mi355x"
        with obs.capture() as rec:
            assert not autotune.install_pretuned(other, arch="cpu")
        assert rec.counter("autotune.pretuned_rejected_arch") == 1
        assert autotune.active_pretuned() is None

    def test_rejection_keeps_previous_table(self, smoke_report):
        assert autotune.install_pretuned(smoke_report, arch="cpu")
        gen = autotune.pretuned_generation()
        bad = copy.deepcopy(smoke_report)
        bad["schema_version"] = 999
        assert not autotune.install_pretuned(bad, arch="cpu")
        assert autotune.active_pretuned() is smoke_report
        assert autotune.pretuned_generation() == gen

    def test_fitted_chip_drives_analytic_fallback(self, smoke_report):
        # On a cell miss the analytic ranking runs with the *fitted* chip.
        assert autotune.install_pretuned(smoke_report, arch="cpu")
        assert autotune.active_chip().name == "cpu_calibrated"
        autotune.clear_pretuned()
        assert autotune.active_chip() is pm.V5E


# ---------------------------------------------------------------------------
# The drift gate
# ---------------------------------------------------------------------------


class TestDriftGate:
    def test_clean_report_passes(self, smoke_report):
        res = cal.check_drift(smoke_report)
        assert res["ok"], res["violations"]
        assert res["n_cells"] == len(smoke_report["cells"])
        for fam in res["families"].values():
            assert fam["mean_spearman"] >= 0.8

    def test_perturbed_report_fails(self):
        # A hand-built report where measurement contradicts the model: the
        # measured winner carries 2x the analytic best, and the rankings
        # anti-correlate.
        report = {"cells": {"gemm|synthetic": {
            "sig": {"op": "gemm"},
            "candidates": [
                {"blocks": [128, 128, 128], "measured_time_s": 3.0,
                 "analytic_time_s": 1.0},
                {"blocks": [256, 256, 256], "measured_time_s": 2.0,
                 "analytic_time_s": 2.0},
                {"blocks": [512, 512, 512], "measured_time_s": 1.0,
                 "analytic_time_s": 3.0},
            ]}}}
        res = cal.check_drift(report)
        assert not res["ok"]
        assert any("measured winner" in v for v in res["violations"])
        assert any("Spearman" in v for v in res["violations"])

    def test_perturbing_real_report_trips_gate(self, smoke_report):
        bad = copy.deepcopy(smoke_report)
        # invert every measured ranking
        for cell in bad["cells"].values():
            times = sorted(c["measured_time_s"] for c in cell["candidates"])
            for c, t in zip(cell["candidates"], reversed(times)):
                c["measured_time_s"] = t
        assert not cal.check_drift(bad)["ok"]

    def test_top1_tolerance_absorbs_near_ties(self):
        # The top two swap (a 4% modeled near-tie); the tail agrees, so
        # rank correlation stays high (rho = 0.9 over 5 candidates) and
        # only the top-1 tolerance decides the gate.
        cands = [
            {"blocks": [1], "measured_time_s": 1.01, "analytic_time_s": 1.00},
            {"blocks": [2], "measured_time_s": 1.00, "analytic_time_s": 1.04},
            {"blocks": [3], "measured_time_s": 2.00, "analytic_time_s": 2.00},
            {"blocks": [4], "measured_time_s": 3.00, "analytic_time_s": 3.00},
            {"blocks": [5], "measured_time_s": 4.00, "analytic_time_s": 4.00},
        ]
        report = {"cells": {"gemm|tie": {"sig": {"op": "gemm"},
                                         "candidates": cands}}}
        assert cal.check_drift(report, top1_tol=0.05)["ok"]
        assert not cal.check_drift(report, top1_tol=0.01)["ok"]


class TestSpearman:
    def test_perfect_and_reversed(self):
        assert cal.spearman([1, 2, 3, 4],
                            [10, 20, 30, 40]) == pytest.approx(1.0)
        assert cal.spearman([1, 2, 3, 4],
                            [40, 30, 20, 10]) == pytest.approx(-1.0)

    def test_ties_average(self):
        rho = cal.spearman([1, 1, 2], [1, 2, 3])
        assert -1.0 <= rho <= 1.0
        assert cal.spearman([5, 5, 5], [1, 2, 3]) == 1.0  # all-tied: agree


# ---------------------------------------------------------------------------
# Execution path: obs journal carries real launches
# ---------------------------------------------------------------------------


class TestExecute:
    def test_executed_cells_journal_launches(self):
        cells = [OpSignature("gemm", (256, 256, 256), dtype="float32")]
        with obs.capture() as rec:
            report = cal.calibrate(cells=cells, execute=True, arch="cpu")
        [cell] = report["cells"].values()
        assert cell["executed_launches"] >= 1
        assert rec.counter("calibrate.executed_launches") >= 1
