"""Bench timing is consolidated: every bench module measures through
``benchmarks.common.measure_cell`` — no stray ``time.perf_counter`` loops,
so methodology changes (trimming, counter bracketing) land everywhere at
once."""
import pathlib

import pytest

BENCH_DIR = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
BENCH_MODULES = sorted(p for p in BENCH_DIR.glob("bench_*.py"))


def test_bench_modules_exist():
    assert len(BENCH_MODULES) >= 8


@pytest.mark.parametrize("path", BENCH_MODULES, ids=lambda p: p.stem)
def test_no_stray_timing_loops(path):
    src = path.read_text()
    assert "perf_counter" not in src, (
        f"{path.name} rolls its own timing loop; use "
        "benchmarks.common.measure_cell")
    assert "time_fn" not in src, (
        f"{path.name} uses the removed time_fn; use measure_cell")


@pytest.mark.parametrize("path", BENCH_MODULES, ids=lambda p: p.stem)
def test_timing_goes_through_measure_cell(path):
    src = path.read_text()
    times_something = "import time" in src or "measure_cell" in src
    if times_something:
        assert "measure_cell" in src


def test_only_common_touches_the_clock():
    offenders = [p.name for p in BENCH_DIR.glob("*.py")
                 if p.name != "common.py" and "perf_counter" in p.read_text()]
    assert not offenders


class TestMeasureCell:
    def test_median_path(self):
        from benchmarks.common import measure_cell

        calls = []
        res = measure_cell(lambda: calls.append(1), warmup=2, iters=5)
        assert len(calls) == 7
        assert res["iters"] == 5
        assert res["us"] >= res["min_us"] >= 0
        assert res["seconds"] == pytest.approx(res["us"] / 1e6)

    def test_one_shot_path(self):
        from benchmarks.common import measure_cell

        calls = []
        res = measure_cell(lambda: calls.append(1), warmup=0, iters=1)
        assert len(calls) == 1  # side-effectful cells run exactly once
        assert res["iters"] == 1
