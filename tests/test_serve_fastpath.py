"""Serving fast paths (DESIGN.md §14): refcounted pages, prefix cache,
chunked prefill, speculative decoding — each against the dense engine's
greedy output, plus the sampling contract and the multi-token verify
kernel against the einsum oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.attention import attention_decode_paged
from repro.models.api import build_model
from repro.serve import kv_cache as kvc
from repro.serve.engine import Engine, PagedEngine, Request

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# allocator refcounts + prefix trie units
# ---------------------------------------------------------------------------
class TestRefcounts:
    def test_retain_defers_free(self):
        alloc = kvc.PageAllocator(4)
        a, b_ = alloc.alloc(2)
        assert alloc.refcount(a) == 1
        assert alloc.retain(a) == 2
        alloc.free([a, b_])            # drops one ref each
        assert alloc.refcount(a) == 1  # still held
        assert alloc.refcount(b_) == 0
        assert alloc.free_pages == 2   # b_ + the never-allocated 3rd page
        alloc.free([a])
        assert alloc.free_pages == 3
        with pytest.raises(ValueError):
            alloc.free([a])            # double free
        with pytest.raises(ValueError):
            alloc.retain(b_)           # retain of an unallocated page

    def test_retain_rejects_invalid_ids(self):
        alloc = kvc.PageAllocator(4)
        for bad in (0, -1, 4):
            with pytest.raises(ValueError):
                alloc.retain(bad)


class TestPrefixCache:
    def test_match_stops_before_final_token(self):
        """COW rule: the page holding the final prompt token is never
        shared, so admission always has fresh logits to sample from."""
        alloc = kvc.PageAllocator(8)
        trie = kvc.PrefixCache(page_size=4)
        toks = list(range(8))                      # exactly 2 full pages
        pages = alloc.alloc(2)
        trie.insert(toks, pages, alloc)
        assert trie.pages_held == 1                # (8-1)//4 = 1 shareable
        assert trie.match(toks, alloc) == pages[:1]
        alloc.free(pages[:1])                      # drop match's retain
        # a 9-token prompt may share 2 full pages, but page 2 was never
        # inserted (it held toks[7], the 8-token prompt's final token)
        assert trie.match(toks + [9], alloc) == pages[:1]
        alloc.free(pages[:1])
        pages3 = alloc.alloc(1)
        trie.insert(toks + [9], pages + pages3, alloc)
        got = trie.match(toks + [9, 10], alloc)
        assert got == pages                        # both pages now cached
        alloc.free(got)

    def test_divergent_tails_share_common_prefix_only(self):
        alloc = kvc.PageAllocator(16)
        trie = kvc.PrefixCache(page_size=4)
        a = [1, 2, 3, 4, 5, 6, 7, 8, 9]
        b = [1, 2, 3, 4, 9, 9, 9, 9, 9]
        pa, pb = alloc.alloc(3), alloc.alloc(3)
        trie.insert(a, pa, alloc)
        trie.insert(b, pb, alloc)
        assert trie.pages_held == 3            # shared head + 2 tails
        got = trie.match([1, 2, 3, 4, 5, 6, 7, 8, 0, 0], alloc)
        assert got == pa[:2]
        alloc.free(got)

    def test_evict_leaf_first_and_respects_refs(self):
        alloc = kvc.PageAllocator(8)
        trie = kvc.PrefixCache(page_size=2)
        toks = [1, 2, 3, 4, 5]                 # two shareable pages
        pages = alloc.alloc(3)
        trie.insert(toks, pages, alloc)
        alloc.free(pages)                      # the inserting seq retires
        held = trie.match(toks, alloc)         # simulate an active borrower
        assert trie.evict(alloc, 2) == 0       # all pages referenced
        alloc.free(held)
        assert trie.evict(alloc, 1) == 1       # leaf (deepest) goes first
        assert trie.pages_held == 1
        assert alloc.refcount(pages[0]) == 1   # interior survives


# ---------------------------------------------------------------------------
# multi-token (verify) decode kernel vs the einsum oracle
# ---------------------------------------------------------------------------
class TestMultiTokenKernel:
    @pytest.mark.parametrize("window", [None, 8])
    def test_paged_verify_matches_reference(self, window):
        rng = np.random.default_rng(5)
        P, hkv, page, d, h, b, mp, t = 9, 2, 16, 32, 4, 2, 4, 3
        kp = jnp.asarray(rng.normal(size=(P, hkv, page, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, hkv, page, d)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
        pt = jnp.array([[3, 1, 7, 0], [2, 5, 0, 0]], jnp.int32)
        lens = jnp.array([55, 20], jnp.int32)   # lengths AFTER the t appends
        ref = attention_decode_paged(q, kp, vp, pt, lens, window=window,
                                     mode="reference")
        ker = attention_decode_paged(q, kp, vp, pt, lens, window=window,
                                     mode="pallas_interpret")
        assert ref.shape == (b, h, t, d)
        np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                   atol=5e-6)

    def test_verify_rows_match_serial_single_token(self):
        """Row t of a T-token verify equals a 1-token decode at the same
        position — the property speculative acceptance relies on."""
        rng = np.random.default_rng(6)
        P, hkv, page, d, h, t = 6, 2, 8, 16, 4, 3
        kp = jnp.asarray(rng.normal(size=(P, hkv, page, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, hkv, page, d)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(1, h, t, d)), jnp.float32)
        pt = jnp.array([[2, 4, 1, 0]], jnp.int32)
        multi = attention_decode_paged(q, kp, vp, pt,
                                       jnp.array([14], jnp.int32),
                                       mode="reference")
        for i in range(t):
            one = attention_decode_paged(q[:, :, i:i + 1], kp, vp, pt,
                                         jnp.array([12 + i], jnp.int32),
                                         mode="reference")
            np.testing.assert_array_equal(np.asarray(multi[:, :, i]),
                                          np.asarray(one[:, :, 0]))


# ---------------------------------------------------------------------------
# engine fast paths: bitwise greedy parity vs the dense engine
# ---------------------------------------------------------------------------
ARCHS = ["granite-8b", "mixtral-8x7b"]          # GQA and windowed+moe


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, mode="reference")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _shared_prefix_reqs(cfg, n=3, prefix_len=17, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    return [Request(uid, np.concatenate(
        [head, rng.integers(0, cfg.vocab_size, 5 + uid).astype(np.int32)]),
        max_new) for uid in range(n)]


def _check_parity(model, params, reqs, max_len=64, **engine_kw):
    eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                      max_pages_per_seq=4, **engine_kw)
    for r in reqs:
        eng.submit(Request(r.uid, r.prompt, r.max_new_tokens))
    results = eng.run()
    fixed = Engine(model, params, max_len=max_len)
    for r in reqs:
        want = fixed.generate(r.prompt[None, :], r.max_new_tokens).tokens[0]
        np.testing.assert_array_equal(results[r.uid], want)
    return eng


class TestFastPathParity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_prefix_cached_matches_dense(self, arch):
        cfg, model, params = _setup(arch)
        eng = _check_parity(model, params, _shared_prefix_reqs(cfg),
                            prefix_cache=True)
        rep = eng.report()["prefix_cache"]
        assert rep["hits"] >= 1 and rep["matched_tokens"] >= 8
        # retiring every sequence returns its refs; the trie keeps its own
        assert eng.alloc.free_pages == eng.n_pages - 1 - rep["pages_held"]

    @pytest.mark.parametrize("arch", ARCHS)
    def test_chunked_prefill_matches_dense(self, arch):
        cfg, model, params = _setup(arch)
        eng = _check_parity(model, params, _shared_prefix_reqs(cfg),
                            chunk_tokens=8)
        assert eng.report()["chunked_prefill"]["chunks"] >= 3
        assert eng.alloc.free_pages == eng.n_pages - 1

    @pytest.mark.parametrize("arch", ARCHS)
    def test_speculative_selfdraft_matches_dense(self, arch):
        cfg, model, params = _setup(arch)
        eng = _check_parity(model, params, _shared_prefix_reqs(cfg),
                            draft_model=model, draft_params=params,
                            spec_tokens=3)
        rep = eng.report()["speculative"]
        assert rep["accept_rate"] == 1.0           # draft == target
        assert rep["mean_tokens_per_round"] == 3.0

    def test_speculative_divergent_draft_matches_dense(self):
        """A draft with different weights proposes wrong tokens; rejection
        must still leave exactly the target's greedy output."""
        cfg, model, params = _setup("granite-8b")
        draft_params = model.init(jax.random.PRNGKey(7))
        eng = _check_parity(model, params, _shared_prefix_reqs(cfg),
                            draft_model=model, draft_params=draft_params,
                            spec_tokens=3)
        rep = eng.report()["speculative"]
        assert 0.0 <= rep["accept_rate"] < 1.0
        assert 1.0 <= rep["mean_tokens_per_round"] <= 3.0

    def test_all_fast_paths_stacked(self):
        cfg, model, params = _setup("granite-8b")
        _check_parity(model, params, _shared_prefix_reqs(cfg),
                      prefix_cache=True, chunk_tokens=8,
                      draft_model=model, draft_params=params, spec_tokens=3)

    def test_spec_rejects_sampled_requests(self):
        cfg, model, params = _setup("granite-8b")
        eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                          max_pages_per_seq=4, draft_model=model,
                          draft_params=params, spec_tokens=2)
        with pytest.raises(ValueError):
            eng.submit(Request(0, np.arange(4, dtype=np.int32), 2,
                               temperature=0.7))
        with pytest.raises(ValueError):
            PagedEngine(model, params, temperature=0.5, draft_model=model,
                        draft_params=params, spec_tokens=2)

    def test_recurrent_arch_rejects_fast_paths(self):
        cfg, model, params = _setup("mamba2-130m")
        for kw in ({"prefix_cache": True}, {"chunk_tokens": 8},
                   {"draft_model": model, "draft_params": params,
                    "spec_tokens": 2}):
            with pytest.raises(ValueError):
                PagedEngine(model, params, page_size=8, **kw)


# ---------------------------------------------------------------------------
# preemption + shared pages
# ---------------------------------------------------------------------------
class TestPreemptionSharing:
    def test_preempted_slot_does_not_free_shared_pages(self):
        """Forced preemption under a tiny pool with an active prefix trie:
        the victim's frees are ref drops, so pages a neighbour (or the
        trie) still references survive, and every result stays exact."""
        cfg, model, params = _setup("granite-8b")
        rng = np.random.default_rng(11)
        head = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        reqs = [Request(u, np.concatenate(
            [head, rng.integers(0, cfg.vocab_size, 2 + u).astype(np.int32)]),
            10) for u in range(2)]
        eng = PagedEngine(model, params, batch_slots=2, page_size=4,
                          max_pages_per_seq=6, n_pages=8,   # 7-page pool
                          prefix_cache=True)
        for r in reqs:
            eng.submit(Request(r.uid, r.prompt, r.max_new_tokens))
        results = eng.run()
        assert eng.preemptions > 0
        held = eng.report()["prefix_cache"]["pages_held"]
        assert held >= 1
        assert eng.alloc.free_pages == eng.n_pages - 1 - held
        fixed = Engine(model, params, max_len=64)
        for r in reqs:
            want = fixed.generate(r.prompt[None, :],
                                  r.max_new_tokens).tokens[0]
            np.testing.assert_array_equal(results[r.uid], want)

    def test_trie_eviction_unblocks_admission(self):
        """A full trie must yield unreferenced pages back to admissions
        instead of deadlocking the pool."""
        cfg, model, params = _setup("granite-8b")
        eng = PagedEngine(model, params, batch_slots=1, page_size=4,
                          max_pages_per_seq=4, n_pages=6,   # 5-page pool
                          prefix_cache=True)
        rng = np.random.default_rng(12)
        for uid in range(3):        # distinct prompts: the trie fills up
            eng.submit(Request(uid, rng.integers(
                0, cfg.vocab_size, 9).astype(np.int32), 3))
        results = eng.run()
        assert sorted(results) == [0, 1, 2]


# ---------------------------------------------------------------------------
# sampling contract (per-request temperature + seed)
# ---------------------------------------------------------------------------
class TestSamplingContract:
    def test_seeded_request_invariant_to_batchmates(self):
        """Same (seed, temperature) request produces the same tokens no
        matter what shares its batch — the fold_in(position) contract."""
        cfg, model, params = _setup("granite-8b")
        probe = Request(0, np.arange(1, 7, dtype=np.int32), 5,
                        temperature=0.8, seed=123)

        def run_with(extra):
            eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                              max_pages_per_seq=4)
            eng.submit(Request(0, probe.prompt, probe.max_new_tokens,
                               temperature=0.8, seed=123))
            for r in extra:
                eng.submit(r)
            return eng.run()[0]

        alone = run_with([])
        rng = np.random.default_rng(13)
        crowd = run_with([Request(9, rng.integers(
            0, cfg.vocab_size, 11).astype(np.int32), 7)])
        np.testing.assert_array_equal(alone, crowd)

    def test_greedy_rider_unaffected_by_sampled_neighbour(self):
        cfg, model, params = _setup("granite-8b")
        greedy = Request(0, np.arange(2, 9, dtype=np.int32), 4)
        eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                          max_pages_per_seq=4, temperature=0.0)
        eng.submit(Request(0, greedy.prompt, 4))
        eng.submit(Request(1, np.arange(1, 5, dtype=np.int32), 4,
                           temperature=1.0, seed=5))
        results = eng.run()
        fixed = Engine(model, params, max_len=32)
        want = fixed.generate(greedy.prompt[None, :], 4).tokens[0]
        np.testing.assert_array_equal(results[0], want)

    def test_seeded_sampling_survives_preemption(self):
        """Recompute preemption replays the same fold_in positions, so a
        seeded request's output is preemption-invariant."""
        cfg, model, params = _setup("granite-8b")
        prompt = np.arange(1, 5, dtype=np.int32)
        big = PagedEngine(model, params, batch_slots=2, page_size=4,
                          max_pages_per_seq=6)
        big.submit(Request(0, prompt, 10, temperature=0.9, seed=42))
        want = big.run()[0]
        rng = np.random.default_rng(14)
        tight = PagedEngine(model, params, batch_slots=2, page_size=4,
                            max_pages_per_seq=6, n_pages=7)   # forces preempt
        tight.submit(Request(0, prompt, 10, temperature=0.9, seed=42))
        tight.submit(Request(1, rng.integers(
            0, cfg.vocab_size, 4).astype(np.int32), 10))
        got = tight.run()[0]
        assert tight.preemptions > 0
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# unified bucket LRU
# ---------------------------------------------------------------------------
class TestUnifiedLRU:
    def test_paged_engine_bucket_kinds_share_one_lru(self):
        cfg, model, params = _setup("granite-8b")
        eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                          max_pages_per_seq=4, chunk_tokens=8,
                          draft_model=model, draft_params=params,
                          spec_tokens=3)
        eng.submit(Request(0, np.arange(1, 11, dtype=np.int32), 4))
        eng.run()
        kinds = {k[0] if isinstance(k[0], str) else "decode"
                 for k in eng.bucket_policies}
        # the target's k-token verify step replaces its 1-token decode
        assert {"chunk", "draft_chunk", "verify", "draft_decode"} <= kinds

    def test_dense_engine_decode_in_shared_lru(self):
        cfg, model, params = _setup("granite-8b")
        eng = Engine(model, params, max_len=32, max_cached_buckets=3)
        eng.generate(np.ones((1, 4), np.int32), 2)
        assert ("decode", 1) in eng.bucket_policies
        assert (1, 4) in eng.bucket_policies
