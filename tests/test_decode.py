"""Decode subsystem: split-KV flash-decode kernel, paged KV cache, engines.

Coverage per the acceptance bar (DESIGN.md §8):
  * kernel vs einsum reference across MHA / GQA / sliding-window /
    ring-buffer wrap-around, per-dtype tolerances, split-count invariance;
  * paged cache: page-boundary-crossing appends, prefill page writes,
    allocator lifecycle, paged kernel vs gathered reference;
  * model-level paged-vs-dense decode parity (reference numerics are
    bitwise identical by construction);
  * continuous batching end-to-end: mixed-length prompts joining and
    leaving mid-generation, greedy continuity vs the fixed-batch engine,
    per-bucket policy pinning, LRU bucket caps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import autotune
from repro.core.policy import make_policy
from repro.kernels.attention import (attention_decode, attention_decode_paged,
                                     decode_ref, resolve_decode_policy,
                                     ring_positions)
from repro.models import build_model
from repro.serve import Engine, PagedEngine, Request, kv_cache as kvc

_TOL = {jnp.float32: 5e-6, jnp.bfloat16: 2e-2}


def _qkv(rng, b, h, hkv, s, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(b, h, 1, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, k, v


def _check(q, k, v, lengths, *, window=None, atol=None):
    atol = atol if atol is not None else _TOL[q.dtype.type]
    ref = attention_decode(q, k, v, lengths, window=window, mode="reference")
    ker = attention_decode(q, k, v, lengths, window=window,
                           mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(ker, np.float32),
                               np.asarray(ref, np.float32), atol=atol)
    return ref


class TestDecodeKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_mha_matches_reference(self, dtype):
        rng = np.random.default_rng(0)
        q, k, v = _qkv(rng, 2, 4, 4, 64, 32, dtype)
        _check(q, k, v, jnp.array([17, 64], jnp.int32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_gqa_matches_reference(self, dtype):
        rng = np.random.default_rng(1)
        q, k, v = _qkv(rng, 2, 8, 2, 64, 32, dtype)
        _check(q, k, v, jnp.array([5, 48], jnp.int32))

    def test_sliding_window(self):
        rng = np.random.default_rng(2)
        q, k, v = _qkv(rng, 2, 4, 2, 64, 16)
        _check(q, k, v, jnp.array([30, 64], jnp.int32), window=8)

    def test_ring_buffer_wraparound(self):
        """lengths > slots: the cache holds the last ``slots`` positions."""
        rng = np.random.default_rng(3)
        q, k, v = _qkv(rng, 2, 4, 2, 32, 16)
        out = _check(q, k, v, jnp.array([100, 33], jnp.int32))
        # wrapped rows attend to every slot: all slots valid
        _, valid = ring_positions(jnp.array([100, 33], jnp.int32), 32)
        assert bool(valid.all())

    def test_ring_window_composition(self):
        rng = np.random.default_rng(4)
        q, k, v = _qkv(rng, 1, 2, 2, 32, 16)
        _check(q, k, v, jnp.array([77], jnp.int32), window=12)

    def test_empty_sequence_returns_zeros(self):
        rng = np.random.default_rng(5)
        q, k, v = _qkv(rng, 2, 4, 2, 32, 16)
        out = attention_decode(q, k, v, jnp.array([0, 9], jnp.int32),
                               mode="pallas_interpret")
        assert float(jnp.max(jnp.abs(out[0]))) == 0.0
        assert float(jnp.max(jnp.abs(out[1]))) > 0.0

    def test_split_count_invariance(self):
        """The LSE combine is exact: any split size gives the same output."""
        rng = np.random.default_rng(6)
        q, k, v = _qkv(rng, 1, 4, 2, 64, 16)
        lengths = jnp.array([50], jnp.int32)
        outs = []
        for bkv in (16, 32, 64):
            pol = make_policy("attention_decode", block_m=2, block_n=bkv,
                              block_k=16, in_dtype="float32")
            outs.append(np.asarray(attention_decode(
                q, k, v, lengths, policy=pol, mode="pallas_interpret")))
        np.testing.assert_allclose(outs[0], outs[1], atol=2e-6)
        np.testing.assert_allclose(outs[0], outs[2], atol=2e-6)

    def test_scalar_length_broadcasts(self):
        rng = np.random.default_rng(7)
        q, k, v = _qkv(rng, 2, 4, 2, 32, 16)
        a = attention_decode(q, k, v, 20, mode="pallas_interpret")
        b = attention_decode(q, k, v, jnp.array([20, 20]),
                             mode="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDecodePolicy:
    def test_autotuned_policy_is_legal_and_tiles(self):
        pol = autotune.select_policy("attention_decode", (4, 8, 4, 4096, 128))
        assert pol.is_legal()
        assert 4096 % pol.block_kv == 0

    def test_small_grid_prefers_splits(self):
        """With batch*kv_heads == 1 the bandwidth model must manufacture
        grid parallelism by splitting KV (the reason the kernel exists)."""
        pol = autotune.select_policy("attention_decode", (1, 1, 8, 8192, 128))
        assert 8192 // pol.block_kv > 1

    def test_paged_policy_fixes_split_to_page(self):
        pol = resolve_decode_policy(2, 4, 2, 256, 64, "bfloat16",
                                    page_size=32)
        assert pol.block_kv == 32

    def test_policies_for_model_includes_decode(self):
        cfg = get_config("granite-8b", smoke=True)
        pols = autotune.policies_for_model(cfg, batch=2, seq_len=128,
                                           decode_len=256)
        assert "attention_decode" in pols
        assert pols["attention_decode"].op == "attention_decode"


class TestPagedCache:
    def _pool(self, rng, P=8, hkv=2, page=8, d=16):
        pool = kvc.init_page_pool(P, hkv, page, d, jnp.float32)
        return pool["k_pages"], pool["v_pages"]

    def test_append_crosses_page_boundary(self):
        rng = np.random.default_rng(0)
        k_pages, v_pages = self._pool(rng)
        page = 8
        pt = jnp.array([[3, 5, 0, 0]], jnp.int32)
        toks = [np.asarray(rng.normal(size=(1, 2, 1, 16)), np.float32)
                for _ in range(12)]       # 12 tokens > one 8-slot page
        for i, t in enumerate(toks):
            k_pages, v_pages = kvc.append_paged_kv(
                k_pages, v_pages, jnp.asarray(t), jnp.asarray(t), pt,
                jnp.array([i], jnp.int32))
        got = np.asarray(kvc.gather_pages(k_pages, pt))   # (1, 2, 32, 16)
        want = np.concatenate(toks, axis=2)               # (1, 2, 12, 16)
        np.testing.assert_array_equal(got[:, :, :12], want)

    def test_prefill_write_then_append_matches_dense(self):
        rng = np.random.default_rng(1)
        k_pages, v_pages = self._pool(rng)
        page, s_true = 8, 11
        k = jnp.asarray(rng.normal(size=(1, 2, s_true, 16)), jnp.float32)
        rows = jnp.array([2, 6, 0, 0], jnp.int32)
        k_pages, v_pages = kvc.write_prefill_pages(k_pages, v_pages, k, k,
                                                   rows)
        # append 3 more tokens, starting mid-page-2 and crossing into page 3
        pt = jnp.array([[2, 6, 7, 0]], jnp.int32)
        extra = [np.asarray(rng.normal(size=(1, 2, 1, 16)), np.float32)
                 for _ in range(6)]
        kp2, vp2 = k_pages, v_pages
        for i, t in enumerate(extra):
            kp2, vp2 = kvc.append_paged_kv(kp2, vp2, jnp.asarray(t),
                                           jnp.asarray(t), pt,
                                           jnp.array([s_true + i], jnp.int32))
        got = np.asarray(kvc.gather_pages(kp2, pt))
        want = np.concatenate([np.asarray(k)] + extra, axis=2)
        np.testing.assert_array_equal(got[:, :, : s_true + 6], want)

    def test_paged_kernel_matches_reference(self):
        rng = np.random.default_rng(2)
        P, hkv, page, d, h, b, mp = 9, 2, 16, 32, 4, 2, 4
        kp = jnp.asarray(rng.normal(size=(P, hkv, page, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, hkv, page, d)), jnp.float32)
        q = jnp.asarray(rng.normal(size=(b, h, 1, d)), jnp.float32)
        pt = jnp.array([[3, 1, 7, 0], [2, 5, 0, 0]], jnp.int32)
        lens = jnp.array([55, 20], jnp.int32)
        for window in (None, 8):
            ref = attention_decode_paged(q, kp, vp, pt, lens, window=window,
                                         mode="reference")
            ker = attention_decode_paged(q, kp, vp, pt, lens, window=window,
                                         mode="pallas_interpret")
            np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                       atol=5e-6)

    def test_allocator_lifecycle(self):
        alloc = kvc.PageAllocator(5)       # pages 1..4 usable
        a = alloc.alloc(2)
        b = alloc.alloc(2)
        assert set(a) | set(b) == {1, 2, 3, 4}
        assert not alloc.can_alloc(1)
        with pytest.raises(MemoryError):
            alloc.alloc(1)
        alloc.free(a)
        assert alloc.can_alloc(2)
        with pytest.raises(ValueError):
            alloc.free(a)                  # double free
        with pytest.raises(ValueError):
            alloc.free([0])                # null page is not freeable


class TestPagedModelParity:
    def test_paged_decode_matches_dense(self):
        """Dense-bucket and paged decode paths agree bitwise in reference
        mode, including across a page-boundary-crossing append."""
        cfg = get_config("granite-8b", smoke=True)
        model = build_model(cfg, mode="reference")
        params = model.init(jax.random.PRNGKey(0))
        prompt = np.array([[5, 6, 7, 8, 9, 10]], np.int32)
        page, mp, n_pages = 4, 4, 12     # prompt needs 2 pages; crossing soon

        dc, dlog = model.prefill(params, jnp.asarray(prompt),
                                 model.init_cache(1, 32))
        cache = model.init_paged_cache(2, n_pages, page)
        alloc = kvc.PageAllocator(n_pages)
        state = kvc.init_page_state(2, mp)
        pages = alloc.alloc(2)
        state = kvc.assign_slot(state, 0, pages, 6)
        n_alloc = 2
        toks = np.zeros((1, 8), np.int32)
        toks[0, :6] = prompt[0]
        cache, plog = model.prefill_paged(params, jnp.asarray(toks), cache,
                                          state["page_table"][0], 0, 6)
        np.testing.assert_array_equal(np.asarray(dlog), np.asarray(plog))

        tok = jnp.argmax(dlog, -1)[:, None]
        for i in range(4):
            if int(state["lengths"][0]) + 1 > n_alloc * page:
                new = alloc.alloc(1)[0]
                state["page_table"] = \
                    state["page_table"].at[0, n_alloc].set(new)
                n_alloc += 1
            dc, dlog = model.decode_step(params, tok, dc, 6 + i)
            tok2 = jnp.concatenate([tok, jnp.zeros((1, 1), jnp.int32)], 0)
            cache, plog = model.decode_step_paged(
                params, tok2, cache, state["page_table"], state["lengths"])
            state["lengths"] = state["lengths"].at[0].add(1)
            np.testing.assert_array_equal(np.asarray(dlog[0]),
                                          np.asarray(plog[0]))
            tok = jnp.argmax(dlog, -1)[:, None]


class TestPagedEngine:
    def _model(self):
        cfg = get_config("granite-8b", smoke=True)
        model = build_model(cfg, mode="reference")
        return model, model.init(jax.random.PRNGKey(0)), cfg

    def test_continuous_batching_matches_fixed_engine(self):
        """Mixed-length prompts join and leave mid-generation; every
        result must equal the fixed-batch engine's greedy decode."""
        model, params, cfg = self._model()
        eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                          max_pages_per_seq=4)
        rng = np.random.default_rng(0)
        reqs = []
        for uid in range(4):
            plen = int(rng.integers(3, 14))
            reqs.append(Request(uid, rng.integers(
                0, cfg.vocab_size, plen).astype(np.int32),
                int(rng.integers(2, 7))))
            eng.submit(reqs[-1])
        results = eng.run()
        assert sorted(results) == [0, 1, 2, 3]
        assert eng.alloc.free_pages == eng.n_pages - 1   # all pages freed
        fixed = Engine(model, params, max_len=64)
        for r in reqs:
            want = fixed.generate(r.prompt[None, :], r.max_new_tokens)
            np.testing.assert_array_equal(results[r.uid], want.tokens[0])

    def test_decode_policies_pinned_per_bucket(self):
        model, params, cfg = self._model()
        eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                          max_pages_per_seq=4)
        eng.submit(Request(0, np.arange(3, dtype=np.int32), 3))
        eng.run()
        decode_keys = [k for k in eng.bucket_policies
                       if isinstance(k[0], int)]
        assert decode_keys, eng.bucket_policies
        for k in decode_keys:
            pol = eng.bucket_policies[k]["attention_decode"]
            assert pol.block_kv == 8     # split size == page size

    @pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-2b"])
    def test_recurrent_arch_parity(self, arch):
        """Regression: prompts whose length is NOT a page multiple must not
        contaminate recurrent (ssm/rglru) slot state — the engine prefills
        at exact length, so every generated token matches the dense path."""
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg, mode="reference")
        params = model.init(jax.random.PRNGKey(0))
        eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                          max_pages_per_seq=4)
        prompt = np.arange(1, 6, dtype=np.int32)     # len 5: partial page
        eng.submit(Request(0, prompt, 6))
        results = eng.run()
        fixed = Engine(model, params, max_len=32)
        want = fixed.generate(prompt[None, :], 6).tokens[0]
        np.testing.assert_array_equal(results[0], want)

    def test_pool_exhaustion_preempts_and_completes(self):
        """Regression: just-in-time page growth over an exhausted pool must
        preempt (recompute policy), not crash — and the preempted request
        still finishes with exactly the fixed-batch engine's output."""
        model, params, cfg = self._model()
        eng = PagedEngine(model, params, batch_slots=2, page_size=4,
                          max_pages_per_seq=4, n_pages=5)   # 4-page pool
        rng = np.random.default_rng(3)
        reqs = [Request(u, rng.integers(0, cfg.vocab_size, 4)
                        .astype(np.int32), 12) for u in range(2)]
        for r in reqs:
            eng.submit(r)
        results = eng.run()
        assert eng.preemptions > 0
        assert eng.alloc.free_pages == eng.n_pages - 1
        # the run report carries the same story: forced preemption, a pool
        # that actually filled, and the bucket-LRU stats block
        rep = eng.report()
        assert rep["preemptions"] == eng.preemptions > 0
        assert rep["admissions"] >= len(reqs)   # re-admits count too
        assert 0 < rep["peak_pages_in_use"] <= rep["page_pool_size"] == 4
        assert rep["tokens_generated"] >= sum(r.max_new_tokens
                                              for r in reqs)
        assert set(rep["bucket_lru"]) == {"hits", "misses", "evictions"}
        assert rep["completed"] == len(reqs)
        fixed = Engine(model, params, max_len=64)
        for r in reqs:
            want = fixed.generate(r.prompt[None, :], r.max_new_tokens)
            np.testing.assert_array_equal(results[r.uid], want.tokens[0])

    def test_rejects_oversized_request(self):
        model, params, cfg = self._model()
        eng = PagedEngine(model, params, batch_slots=2, page_size=4,
                          max_pages_per_seq=2)
        with pytest.raises(ValueError):
            eng.submit(Request(0, np.arange(7, dtype=np.int32), 5))

    def test_engine_bucket_lru_cap(self):
        model, params, cfg = self._model()
        eng = Engine(model, params, max_len=32, max_cached_buckets=2)
        for s in (4, 8, 12):
            eng.generate(np.ones((1, s), np.int32), 2)
        assert len(eng.bucket_policies) == 2
        assert (1, 4) not in eng.bucket_policies   # LRU evicted


class TestKernelModeEndToEnd:
    def test_paged_engine_kernel_mode_matches_reference(self):
        """The full serve loop over the Pallas (interpret) decode kernel
        produces the same greedy tokens as the einsum reference path."""
        cfg = get_config("granite-8b", smoke=True)
        params = build_model(cfg, mode="reference").init(jax.random.PRNGKey(0))
        outs = {}
        for mode in ("reference", "pallas_interpret"):
            model = build_model(cfg, mode=mode)
            eng = PagedEngine(model, params, batch_slots=2, page_size=8,
                              max_pages_per_seq=2)
            eng.submit(Request(0, np.arange(1, 6, dtype=np.int32), 4))
            eng.submit(Request(1, np.arange(2, 12, dtype=np.int32), 3))
            outs[mode] = eng.run()
        for uid in (0, 1):
            np.testing.assert_array_equal(outs["reference"][uid],
                                          outs["pallas_interpret"][uid])
