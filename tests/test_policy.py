"""KernelPolicy + analytic autotuner subsystem tests (no hypothesis needed)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, tiles
from repro.core.autotune import (OpSignature, candidate_policies,
                                 clear_policy_cache, gemm_traffic_bytes,
                                 policy_cache_stats, score_policy,
                                 select_policy)
from repro.core.grid_swizzle import ROW_MAJOR, SwizzleConfig, is_permutation
from repro.core.policy import KernelPolicy, make_policy
from repro.core.schedule import PINGPONG, Schedule

KEY = jax.random.PRNGKey(0)


class TestPolicyLegality:
    def test_vmem_overflow_rejected(self):
        """Tab. 2's feasibility rule: a policy whose pipelined working set
        blows the VMEM budget is illegal and check() raises."""
        huge = make_policy("gemm", block_m=8192, block_n=8192, block_k=8192)
        assert not huge.is_legal()
        with pytest.raises(ValueError, match="VMEM"):
            huge.check()
        ok = KernelPolicy("gemm", PINGPONG)
        assert ok.is_legal()
        assert ok.check() > 0

    def test_candidates_are_all_legal_and_fit(self):
        sig = OpSignature("gemm", (1024, 768, 1280))
        cands = candidate_policies(sig)
        assert cands, "candidate set must be non-empty"
        for pol in cands:
            assert pol.is_legal()
            assert pol.fits(1024, 768, 1280)

    def test_attention_bwd_budget_larger_than_fwd(self):
        """The bwd kind accounts the dk+dv accumulator pair, so at equal
        blocks its working set is at least the fwd's."""
        fwd = make_policy("attention_fwd", block_m=256, block_n=256,
                          block_k=128)
        bwd = make_policy("attention_bwd", block_m=256, block_n=256,
                          block_k=128)
        assert bwd.vmem_bytes() >= fwd.vmem_bytes()

    def test_producer_tax_rejects_under_shrunk_budget(self):
        """Same mechanism as the paper's Tab. 2 negative result: shrink the
        fast-memory budget (producer tax / LDS scale) and the big-tile
        policy stops being legal (PINGPONG's working set is 3 MiB)."""
        pol = KernelPolicy("gemm", PINGPONG)
        assert pol.is_legal()                      # 128 MiB VMEM: fine
        assert not pol.is_legal(budget=2 * 2**20)  # taxed budget: rejected


class TestAutotune:
    def test_deterministic(self):
        clear_policy_cache()
        p1 = select_policy("gemm", (2048, 1024, 2048))
        clear_policy_cache()
        p2 = select_policy("gemm", (2048, 1024, 2048))
        assert p1 == p2

    def test_cache_hits(self):
        clear_policy_cache()
        p1 = select_policy("attention_fwd", (2, 8, 1024, 1024, 128),
                           causal=True)
        stats = policy_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 0
        p2 = select_policy("attention_fwd", (2, 8, 1024, 1024, 128),
                           causal=True)
        stats = policy_cache_stats()
        assert stats["hits"] == 1
        assert p1 is p2  # memoized object, not a re-derivation

    def test_batch_dims_share_bucket(self):
        """Batch/head counts bucket to powers of two; tile-constrained dims
        stay exact (a block must divide them)."""
        clear_policy_cache()
        a = select_policy("attention_fwd", (3, 8, 512, 512, 64))
        b = select_policy("attention_fwd", (4, 8, 512, 512, 64))
        assert a is b
        sig_a = OpSignature("attention_fwd", (3, 8, 512, 512, 64))
        sig_b = OpSignature("attention_fwd", (4, 8, 512, 512, 64))
        assert sig_a.bucket() == sig_b.bucket()
        sig_c = OpSignature("attention_fwd", (3, 8, 384, 512, 64))
        assert sig_c.bucket() != sig_a.bucket()  # seq stays exact

    def test_selected_blocks_tile_the_shape(self):
        for shape in [(512, 512, 512), (2048, 256, 1024), (384, 384, 256)]:
            pol = select_policy("gemm", shape)
            assert pol.fits(*shape)
        pol = select_policy("fused_norm", (4096, 1024))
        assert 4096 % pol.block_rows == 0

    def test_modeled_best_beats_row_major_on_nonsquare_gemm(self):
        """Acceptance: for a tall non-square GEMM the tuned policy's
        traversal moves fewer modeled HBM bytes than ROW_MAJOR with the
        default (PINGPONG 512^3) blocks — the Tab. 4 effect through the
        Pallas-revisit DMA model."""
        m, n, k = 4096, 1024, 4096
        best = select_policy("gemm", (m, n, k))
        default = KernelPolicy("gemm", PINGPONG, ROW_MAJOR)
        dtype_bytes = 2
        best_traffic = gemm_traffic_bytes(best, m, n, k, dtype_bytes)
        default_traffic = gemm_traffic_bytes(default, m, n, k, dtype_bytes)
        assert best_traffic < default_traffic, (best_traffic, default_traffic)
        # and the score agrees (the ranking actually used the DMA model)
        sig = OpSignature("gemm", (m, n, k))
        assert (score_policy(sig, best).rank_key(best)
                < score_policy(sig, default).rank_key(default))

    def test_infeasible_candidates_score_inf(self):
        sig = OpSignature("gemm", (8192, 8192, 8192))
        bad = make_policy("gemm", block_m=8192, block_n=8192, block_k=512)
        import math
        assert math.isinf(score_policy(sig, bad).time_s)


class TestSwizzlePolicyInvariant:
    # fixed table replaces the hypothesis sweep: the policy's traversal must
    # visit every output block exactly once for any (W, C, n_xcd)
    CASES = [(rows, cols, w, c, x)
             for rows in (1, 3, 8, 13, 40)
             for cols in (1, 5, 16, 37)
             for (w, c, x) in ((1, 1, 2), (2, 4, 4), (8, 64, 8), (7, 25, 8),
                               (16, 3, 4))]

    def test_policy_swizzles_are_permutations(self):
        for rows, cols, w, c, x in self.CASES:
            cfg = SwizzleConfig(window=w, chunk=c, n_xcd=x)
            assert is_permutation(cfg, rows, cols), (rows, cols, w, c, x)

    def test_autotuned_gemm_swizzle_is_permutation(self):
        pol = select_policy("gemm", (4096, 1024, 4096))
        assert is_permutation(pol.swizzle, 4096 // pol.block_m,
                              1024 // pol.block_n)


class TestDeprecationShims:
    def test_gemm_legacy_kwargs_match_explicit_policy(self):
        from repro.kernels.gemm.kernel import gemm_pallas
        a = jax.random.normal(KEY, (256, 256), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
        explicit = make_policy("gemm", block_m=128, block_n=128, block_k=128)
        out_pol = gemm_pallas(a, b, policy=explicit, out_dtype=jnp.float32)
        with pytest.warns(DeprecationWarning):
            out_legacy = gemm_pallas(a, b, block_m=128, block_n=128,
                                     block_k=128, out_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(out_pol),
                                      np.asarray(out_legacy))

    def test_attention_legacy_kwargs_match_explicit_policy(self):
        from repro.kernels.attention import flash_attention_fwd
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        explicit = make_policy("attention_fwd", block_m=128, block_n=128,
                               block_k=64)
        o_pol, l_pol = flash_attention_fwd(q, k, v, causal=True,
                                           policy=explicit)
        with pytest.warns(DeprecationWarning):
            o_leg, l_leg = flash_attention_fwd(q, k, v, causal=True,
                                               block_q=128, block_kv=128)
        np.testing.assert_array_equal(np.asarray(o_pol), np.asarray(o_leg))
        np.testing.assert_array_equal(np.asarray(l_pol), np.asarray(l_leg))

    def test_attention_swizzled_policy_bitwise_matches_row_major(self):
        """Algorithm 1 on the fused (head, q-block) grid dim is a pure
        scheduling transform — outputs are bitwise identical."""
        from repro.kernels.attention import flash_attention_fwd
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 4, 256, 64))
        k = jax.random.normal(ks[1], (2, 2, 256, 64))
        v = jax.random.normal(ks[2], (2, 2, 256, 64))
        base = make_policy("attention_fwd", block_m=128, block_n=128,
                           block_k=64)
        swz = make_policy("attention_fwd", block_m=128, block_n=128,
                          block_k=64,
                          swizzle=SwizzleConfig(window=2,
                                                enable_chiplet=False))
        o1, l1 = flash_attention_fwd(q, k, v, causal=True, policy=base)
        o2, l2 = flash_attention_fwd(q, k, v, causal=True, policy=swz)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


class TestModelResolution:
    def test_policies_for_model(self):
        from repro.configs import get_config
        cfg = get_config("granite-8b", smoke=True)
        pols = autotune.policies_for_model(cfg, batch=4, seq_len=256)
        assert {"attention_fwd", "attention_bwd", "fused_norm"} <= set(pols)
        for pol in pols.values():
            assert pol.is_legal()

    def test_attention_free_arch_gets_no_attention_policy(self):
        from repro.configs import get_config
        cfg = get_config("mamba2-130m", smoke=True)
        pols = autotune.policies_for_model(cfg, batch=2, seq_len=256)
        assert "attention_fwd" not in pols
        assert "fused_norm" in pols
