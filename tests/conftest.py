"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
1 device; multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys

import pytest


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N fake devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
