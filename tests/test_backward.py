"""Chain-transpose backward (DESIGN.md §11): the kernel-side fused bwd vs
the oracle-recompute VJP vs an f32-compute ground truth.

Three anchors, per the subsystem invariant (oracles own numerics):
  1. the *declarative transpose rules* (`Epilogue.transpose_tile` /
     `operand_grads`, `Prologue.transpose`, assembled by
     `gemm_fused_bwd_ref`) must agree with jax autodiff of the fwd oracle —
     the rules may never drift from the forward math;
  2. `jax.grad` through `gemm_fused(bwd_mode="kernel")` — the fused Pallas
     dA/dB launches — must match the f32 truth at least as well as the
     oracle VJP (`bwd_mode="reference"`) does, per leaf;
  3. the full training loop must walk the same loss curve on both bwd
     paths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.grid_swizzle import SwizzleConfig
from repro.core.policy import make_policy
from repro.kernels.gemm import (Epilogue, Prologue, default_bwd_mode,
                                gemm_fused, gemm_fused_bwd_ref,
                                gemm_fused_ref)
from repro.kernels.gemm import backward as gemm_backward
from repro.kernels.rope import rope_tables


def _rand(key, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * 0.5
    return x.astype(dtype)


def _chain_cases(m, k, n, dtype):
    """The ISSUE's chain matrix: every fused path the model layers train
    through, as (name, epilogue, prologue, operand dict)."""
    a = _rand(0, (m, k), dtype)
    b = _rand(1, (k, n), dtype)
    b2 = _rand(2, (k, n), dtype)
    res = _rand(3, (m, n), jnp.float32)
    gamma = _rand(4, (k,), jnp.float32) * 0.2 + 1.0
    beta = _rand(5, (k,), jnp.float32) * 0.2
    sin, cos = rope_tables(jnp.arange(m), 64)
    af = a.astype(jnp.float32)
    fast = Prologue(norm="rmsnorm", precomputed_stats=True)
    lnfast = Prologue(norm="layernorm", beta=True, precomputed_stats=True)
    return a, b, [
        ("mlp_dual_swiglu", Epilogue(activation="silu", gate=True),
         Prologue(), {"b2": b2}),
        ("down_residual", Epilogue(residual=True, scale=True), Prologue(),
         {"residual": res, "scale": jnp.asarray(0.7)}),
        ("qkv_rope", Epilogue(rope=True, head_dim=64, bias=True),
         Prologue(), {"sin": sin, "cos": cos,
                      "bias": _rand(6, (n,), jnp.float32)}),
        ("norm_recompute", Epilogue(activation="silu", gate=True),
         Prologue(norm="rmsnorm"), {"b2": b2, "gamma": gamma}),
        ("norm_qkv_rope", Epilogue(rope=True, head_dim=64, bias=True),
         Prologue(norm="rmsnorm"),
         {"sin": sin, "cos": cos, "bias": _rand(8, (n,), jnp.float32),
          "gamma": gamma}),
        ("norm_precomputed_rstd", Epilogue(activation="silu", gate=True),
         fast, {"b2": b2, "gamma": gamma, **fast.compute_stats(af)}),
        ("layernorm_fast_scaled", Epilogue(residual=True, scale=True),
         lnfast, {"gamma": gamma, "beta": beta, "residual": res,
                  "scale": jnp.asarray(0.9), **lnfast.compute_stats(af)}),
        ("fp8_style_col_scale", Epilogue(scale=True, scale_kind="col",
                                         gate=True, activation="silu"),
         Prologue(), {"b2": b2,
                      "scale": _rand(7, (n,), jnp.float32) * 0.1 + 1.0}),
    ]


def _loss(a, b, vals, names, ep, pro, *, bwd=None, mode="pallas_interpret",
          policy=None):
    out = gemm_fused(a, b, epilogue=ep, prologue=pro, out_dtype=jnp.float32,
                     bwd_mode=bwd, mode=mode, policy=policy,
                     **dict(zip(names, vals)))
    w = jnp.cos(jnp.arange(out.size, dtype=jnp.float32).reshape(out.shape)
                * 0.01)
    return jnp.sum(out * w)


class TestTransposeRuleOracle:
    """Anchor 1: the declarative rules vs jax autodiff of the fwd oracle."""

    def test_bwd_ref_matches_autodiff(self):
        m, k, n = 64, 128, 128
        a, b, cases = _chain_cases(m, k, n, jnp.float32)
        g = _rand(99, (m, n), jnp.float32)
        for name, ep, pro, ops in cases:
            names = list(ops)

            def ref(a_, b_, vals):
                return gemm_fused_ref(a_, b_, epilogue=ep, prologue=pro,
                                      out_dtype=jnp.float32,
                                      **dict(zip(names, vals)))

            out, vjp = jax.vjp(ref, a, b, tuple(ops.values()))
            da_t, db_t, dops_t = vjp(g)
            da, db, grads = gemm_fused_bwd_ref(a, b, g, epilogue=ep,
                                               prologue=pro, out=out, **ops)
            np.testing.assert_allclose(np.asarray(da), np.asarray(da_t),
                                       rtol=1e-4, atol=1e-4, err_msg=name)
            np.testing.assert_allclose(np.asarray(db), np.asarray(db_t),
                                       rtol=1e-4, atol=1e-4, err_msg=name)
            for op_name, truth in zip(names, dops_t):
                got = np.asarray(grads[op_name]).reshape(
                    np.asarray(truth).shape)
                np.testing.assert_allclose(got, np.asarray(truth),
                                           rtol=1e-4, atol=1e-4,
                                           err_msg=f"{name}:{op_name}")


class TestKernelBackward:
    """Anchor 2: the fused Pallas dA/dB launches, per chain × dtype."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                             ids=["fp32", "bf16"])
    def test_grad_parity_vs_truth(self, dtype):
        """Per-leaf grad error of the kernel bwd vs the f32 truth must be
        no worse than the oracle VJP's (x2 slack + eps, the same criterion
        the model-level parity tests use)."""
        m, k, n = 128, 256, 256
        a, b, cases = _chain_cases(m, k, n, dtype)
        af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
        for name, ep, pro, ops in cases:
            names = list(ops)
            vals = tuple(ops.values())
            valsf = tuple(v.astype(jnp.float32)
                          if v.dtype == jnp.bfloat16 else v for v in vals)
            argnums = (0, 1, 2)
            g_kern = jax.grad(
                lambda *x: _loss(*x, names, ep, pro, bwd="kernel"),
                argnums)(a, b, vals)
            g_orac = jax.grad(
                lambda *x: _loss(*x, names, ep, pro, bwd="reference"),
                argnums)(a, b, vals)
            g_true = jax.grad(
                lambda *x: _loss(*x, names, ep, pro, mode="reference"),
                argnums)(af, bf, valsf)

            def leaves(tree):
                return [tree[0], tree[1], *tree[2]]

            for leaf, kk, rr, tt in zip(["da", "db"] + names,
                                        leaves(g_kern), leaves(g_orac),
                                        leaves(g_true)):
                kk, rr, tt = (np.asarray(x, np.float32)
                              for x in (kk, rr, tt))
                kern_err = np.abs(kk - tt).max()
                orac_err = np.abs(rr - tt).max()
                assert kern_err <= 2.0 * orac_err + 1e-3, \
                    (name, leaf, float(kern_err), float(orac_err))

    def test_default_path_runs_fused_launches(self):
        """jax.grad on the default path traces BOTH bwd GEMMs through the
        fused Pallas launches — no jnp-oracle recompute. Counted through
        the telemetry journal (obs.capture), which records one
        gemm_bwd_da/gemm_bwd_db event per fused bwd dispatch."""
        from repro import obs

        a = _rand(0, (128, 128))
        b2 = _rand(2, (128, 128))
        ep = Epilogue(activation="silu", gate=True)
        with obs.capture() as cap:
            jax.grad(lambda a_: _loss(a_, a, (b2,), ["b2"], ep,
                                      Prologue()))(a)
        counts = cap.launch_counts()
        assert cap.count("gemm_bwd_da") == 1, counts
        assert cap.count("gemm_bwd_db") == 1, counts

    def test_swizzle_invariance_of_gradients(self):
        """Grid order must never change gradients either: the bwd launches
        inherit the fwd policy's traversal, and every swizzle is BITWISE
        identical to row-major — through fwd AND bwd."""
        m = k = n = 256
        a = _rand(0, (m, k))
        b = _rand(1, (k, n))
        b2 = _rand(2, (k, n))
        gamma = _rand(3, (k,)) + 1.0
        ep = Epilogue(activation="silu", gate=True)
        pro = Prologue(norm="rmsnorm")
        grads = []
        for window in (1, 2):
            pol = make_policy("gemm", block_m=128, block_n=128, block_k=k,
                              swizzle=SwizzleConfig(window=window,
                                                    enable_chiplet=False),
                              epilogue=ep, prologue=pro)
            g = jax.grad(lambda *x: _loss(*x, ["b2", "gamma"], ep, pro,
                                          policy=pol),
                         (0, 1, 2))(a, b, (b2, gamma))
            grads.append(g)
        for x, y in zip(jax.tree.leaves(grads[0]),
                        jax.tree.leaves(grads[1])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_fast_path_stats_grads_flow_to_x(self):
        """precomputed-rstd: the (M, 1) stats are *graph inputs* computed
        from x, so their cotangents must chain back into dx exactly — the
        whole point of giving mean/rstd first-class transpose rules."""
        m, k, n = 128, 256, 128
        b = _rand(1, (k, n))
        gamma = _rand(2, (k,)) + 1.0
        pro = Prologue(norm="rmsnorm", precomputed_stats=True)

        def loss(x, bwd, mode="pallas_interpret"):
            out = gemm_fused(x, b, prologue=pro, gamma=gamma,
                             out_dtype=jnp.float32, bwd_mode=bwd, mode=mode,
                             **pro.compute_stats(x))
            return jnp.sum(out ** 2)

        x = _rand(0, (m, k))
        g_kern = jax.grad(lambda x_: loss(x_, "kernel"))(x)
        g_true = jax.grad(lambda x_: loss(x_, None, "reference"))(x)
        np.testing.assert_allclose(np.asarray(g_kern), np.asarray(g_true),
                                   rtol=2e-3, atol=2e-3)

    def test_falls_back_to_oracle_when_no_legal_bwd_policy(self):
        """The bwd must handle every shape the fwd legally engaged: at huge
        feature dims the norm transpose's full-K fp32 tiles can be
        VMEM-illegal while the fwd's bf16 tiles were legal — the kernel
        path then falls back to the oracle-recompute VJP instead of
        crashing jax.grad at trace time. (eval_shape: trace only.)"""
        m, k = 4096, 65536
        n = 4 * k
        ep = Epilogue(activation="silu", gate=True)
        pro = Prologue(norm="rmsnorm")
        fwd = autotune.select_policy("gemm", (m, n, k), "bfloat16",
                                     epilogue=ep, prologue=pro)  # legal
        with pytest.raises(ValueError, match="no legal policy"):
            gemm_backward.resolve_bwd_policies(fwd, m, n, k, "bfloat16",
                                               ep, pro)

        def loss(a, b, b2, gamma):
            out = gemm_fused(a, b, b2=b2, gamma=gamma, epilogue=ep,
                             prologue=pro, out_dtype=jnp.bfloat16)
            return jnp.sum(out.astype(jnp.float32))

        args = [jax.ShapeDtypeStruct(s, jnp.bfloat16)
                for s in [(m, k), (k, n), (k, n)]]
        args.append(jax.ShapeDtypeStruct((k,), jnp.float32))
        shapes = jax.eval_shape(jax.grad(loss, argnums=(0, 1, 2, 3)), *args)
        assert [s.shape for s in shapes] == [(m, k), (k, n), (k, n), (k,)]

    def test_bwd_policies_resolve_as_gemm_bwd(self):
        """The bwd launches resolve their own chain-aware gemm_bwd policies
        (full-K pinning for the norm transpose, whole-head contraction for
        rope) with the fwd traversal pinned."""
        ep = Epilogue(activation="silu", gate=True)
        pro = Prologue(norm="rmsnorm")
        fwd = autotune.select_policy("gemm", (512, 512, 384), "bfloat16",
                                     epilogue=ep, prologue=pro)
        da, db = gemm_backward.resolve_bwd_policies(
            fwd, 512, 512, 384, "bfloat16", ep, pro)
        assert da.op == "gemm_bwd" and db.op == "gemm_bwd"
        assert da.swizzle == fwd.swizzle and db.swizzle == fwd.swizzle
        # dA: out (M, K), the norm transpose pins the out-col block to K
        assert da.block_n == 384
        # dB: out (K, N), the recompute-path renorm pins the out-row block
        assert db.block_m == 384
        rope_ep = Epilogue(rope=True, head_dim=64)
        da_r = autotune.select_policy("gemm_bwd", (256, 128, 256),
                                      "float32", epilogue=rope_ep,
                                      variant="da")
        assert da_r.block_k % 64 == 0   # g tiles rotate whole heads


class TestBwdPlanModel:
    """select_fusion(backward=True): fused bwd vs oracle-recompute, from
    modeled dma_bytes alone (the ISSUE acceptance bar)."""

    def test_mlp_bwd_plan_beats_oracle_recompute(self):
        plan = autotune.select_fusion("mlp", (4096, 2048, 8192, True),
                                      backward=True)
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]
        assert plan["traffic_reduction"] >= 1.3

    def test_norm_mlp_bwd_plan(self):
        plan = autotune.select_fusion("mlp", (4096, 2048, 8192, True),
                                      backward=True, prenorm="rmsnorm")
        assert plan["plan"] == "fused"
        assert plan["traffic_reduction"] >= 1.3

    def test_qkv_bwd_plan(self):
        plan = autotune.select_fusion("qkv_rope", (4096, 2048, 16, 4, 128),
                                      backward=True)
        assert plan["plan"] == "fused"
        assert plan["fused_bytes"] < plan["unfused_bytes"]

    def test_bwd_dma_strictly_below_oracle_on_train_cells(self):
        """The acceptance criterion: modeled bwd dma_bytes strictly below
        the oracle-recompute path on every train-shaped bench cell."""
        for seq in (2048, 8192):
            for d in (1024, 2048, 4096):
                for prenorm in ("none", "rmsnorm"):
                    plan = autotune.select_fusion(
                        "mlp", (seq, d, 4 * d, True), backward=True,
                        prenorm=prenorm)
                    assert plan["fused_bytes"] < plan["unfused_bytes"], \
                        (seq, d, prenorm, plan)


class TestTrainerSmoke:
    """Anchor 3: the training loop walks the same loss curve on the fused
    kernel bwd and the oracle bwd."""

    def test_loss_curve_parity_kernel_vs_oracle_bwd(self):
        from repro.configs import get_config
        from repro.data.pipeline import DataConfig, DataIterator
        from repro.models import build_model
        from repro.optim import AdamWConfig, cosine_schedule
        from repro.train import train_loop

        cfg = get_config("llama-100m")
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=2, d_ff=256,
                                  vocab_size=256,
                                  compute_dtype="float32")
        steps = 8

        def run(bwd):
            with default_bwd_mode(bwd):
                model = build_model(cfg, mode="pallas_interpret")
                dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=4, noise=0.05)
                opt = AdamWConfig(schedule=cosine_schedule(1e-2, 2, steps))
                return train_loop(model, DataIterator(dcfg), steps, opt,
                                  log_every=0)

        kern = run("kernel")
        orac = run("reference")
        lk = np.asarray(kern.losses, np.float64)
        lo = np.asarray(orac.losses, np.float64)
        assert np.isfinite(lk).all() and np.isfinite(lo).all()
        # f32 compute: the two bwd paths differ only by blocked-accumulation
        # reassociation — bitwise-tiny per step, amplified chaotically by
        # the optimizer over steps (the same reason test_system anchors
        # train parity against a truth curve). Tight early, bounded late.
        np.testing.assert_allclose(lk[:4], lo[:4], rtol=2e-3, atol=2e-3)
        assert np.abs(lk - lo).max() < 0.2, (lk.tolist(), lo.tolist())


class TestBwdRouting:
    """``bwd_mode="auto"`` (docs/autotuning.md): plan-aware routing between
    the fused kernel backward and the oracle VJP, asserted through the
    ``bwd_route`` plan-audit journal — no monkeypatching."""

    def setup_method(self):
        autotune.clear_policy_cache()

    @staticmethod
    def _routes(cap):
        return cap.plans_of("bwd_route")

    def test_auto_routes_oracle_on_degenerate_shape(self):
        """Tiny contraction dim with an activation epilogue: the saved
        preacts dominate the kernel path's traffic + peak memory, so auto
        picks the oracle VJP — and its grads are bitwise the reference
        path's."""
        from repro import obs

        a = _rand(0, (512, 128))
        b = _rand(1, (128, 512))
        b2 = _rand(2, (128, 512))
        ep = Epilogue(activation="silu", gate=True)
        with obs.capture() as cap:
            g_auto = jax.grad(lambda a_: _loss(a_, b, (b2,), ["b2"], ep,
                                               Prologue(), bwd="auto"))(a)
        routes = self._routes(cap)
        assert routes and routes[0].chosen["mode"] == "reference", routes
        assert cap.count("gemm_bwd_da") == 0
        assert cap.count("gemm_bwd_db") == 0
        g_ref = jax.grad(lambda a_: _loss(a_, b, (b2,), ["b2"], ep,
                                          Prologue(), bwd="reference"))(a)
        np.testing.assert_array_equal(np.asarray(g_auto),
                                      np.asarray(g_ref))

    def test_auto_routes_kernel_on_train_shape(self):
        """Train-shaped contraction dim: the fused chain transpose wins the
        roofline, and the journal shows both fused bwd GEMM launches."""
        from repro import obs

        a = _rand(0, (256, 1024))
        b = _rand(1, (1024, 256))
        b2 = _rand(2, (1024, 256))
        ep = Epilogue(activation="silu", gate=True)
        with obs.capture() as cap:
            jax.grad(lambda a_: _loss(a_, b, (b2,), ["b2"], ep,
                                      Prologue(), bwd="auto"))(a)
        routes = self._routes(cap)
        assert routes and routes[0].chosen["mode"] == "kernel", routes
        assert cap.count("gemm_bwd_da") == 1
        assert cap.count("gemm_bwd_db") == 1

    def test_auto_as_session_default(self):
        """default_bwd_mode("auto") routes every layer that doesn't pass
        bwd_mode — the model-level lever."""
        from repro import obs

        a = _rand(0, (512, 128))
        b = _rand(1, (128, 512))
        b2 = _rand(2, (128, 512))
        ep = Epilogue(activation="silu", gate=True)
        with default_bwd_mode("auto"):
            with obs.capture() as cap:
                jax.grad(lambda a_: _loss(a_, b, (b2,), ["b2"], ep,
                                          Prologue()))(a)
        routes = self._routes(cap)
        assert routes and routes[0].chosen["mode"] == "reference"

    def test_route_decision_is_memoized_and_replayed(self):
        from repro import obs

        with obs.capture() as cap:
            first = autotune.select_bwd_mode(512, 512, 128, dtype="float32",
                                             epilogue=Epilogue(
                                                 activation="silu"))
            second = autotune.select_bwd_mode(512, 512, 128,
                                              dtype="float32",
                                              epilogue=Epilogue(
                                                  activation="silu"))
        assert first == second == "reference"
        routes = self._routes(cap)
        assert len(routes) == 2
        assert not routes[0].cached and routes[1].cached

    def test_route_model_crossover(self):
        """The analytic route model itself: reference wins only while the
        contraction dim is small relative to the save-stream traffic."""
        from repro.core import perf_model as pm

        small = pm.gemm_bwd_route_model(m=2048, n=512, k=8, n_saved=1)
        big = pm.gemm_bwd_route_model(m=4096, n=4096, k=2048, n_saved=1)
        assert small["route"] == "reference"
        assert big["route"] == "kernel"
        assert small["peak_save_bytes"] > 0
        assert big["kernel_score"] < big["reference_score"]
