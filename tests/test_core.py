"""Unit + property tests for the core tile framework.

Property tests use hypothesis when installed (requirements-dev.txt) and fall
back to a fixed deterministic case table otherwise (_hypothesis_compat).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import tiles
from repro.core.grid_swizzle import (SwizzleConfig, ROW_MAJOR, dma_bytes,
                                     is_permutation, schedule_order,
                                     best_window, chiplet_transform_chunked)
from repro.core.cache_model import CacheHW, simulate_gemm_schedule
from repro.core.schedule import PINGPONG, INTERLEAVE, WAVE_SPECIALIZED, get_schedule
from repro.core import perf_model as pm


class TestTiles:
    def test_native_tiling(self):
        assert tiles.native_tiling("float32") == (8, 128)
        assert tiles.native_tiling("bfloat16") == (16, 128)
        assert tiles.native_tiling("int8") == (32, 128)

    def test_tile_legality(self):
        tiles.TileSpec(256, 256, "bfloat16")
        with pytest.raises(ValueError):
            tiles.TileSpec(100, 256, "bfloat16")   # rows not sublane-aligned
        with pytest.raises(ValueError):
            tiles.TileSpec(256, 100, "bfloat16")   # cols not lane-aligned

    def test_vmem_budget(self):
        used = tiles.check_vmem_budget(
            [((512, 512), "bfloat16"), ((512, 512), "bfloat16")],
            n_buffers=2, scratch_bytes=512 * 512 * 4)
        assert used == 2 * 2 * 512 * 512 * 2 + 512 * 512 * 4
        with pytest.raises(ValueError):
            tiles.check_vmem_budget([((8192, 8192), "float32")], n_buffers=4)

    @given(st.integers(1, 64), st.integers(1, 64))
    def test_padded_bytes_at_least_exact(self, r, c):
        exact = r * c * 2
        assert tiles.padded_tile_bytes((r, c), "bfloat16") >= exact


class TestSwizzle:
    @given(rows=st.integers(1, 40), cols=st.integers(1, 40),
           window=st.integers(1, 16), chunk=st.integers(1, 64),
           n_xcd=st.sampled_from([2, 4, 8]))
    @settings(max_examples=200, deadline=None)
    def test_algorithm1_is_permutation(self, rows, cols, window, chunk, n_xcd):
        cfg = SwizzleConfig(window=window, chunk=chunk, n_xcd=n_xcd)
        assert is_permutation(cfg, rows, cols)

    @given(blocks=st.integers(1, 512), chunk=st.integers(1, 32),
           n_xcd=st.sampled_from([2, 4, 8]))
    @settings(max_examples=100, deadline=None)
    def test_chiplet_transform_bijective(self, blocks, chunk, n_xcd):
        xy = np.arange(blocks)
        out = chiplet_transform_chunked(xy, blocks, n_xcd, chunk)
        assert sorted(out.tolist()) == list(range(blocks))

    def test_traced_remap_matches_numpy(self):
        import jax
        import jax.numpy as jnp
        cfg = SwizzleConfig(window=8, chunk=64)
        order = schedule_order(cfg, 36, 36)
        f = jax.jit(lambda t: cfg.remap(t, 36, 36))
        for i in (0, 17, 500, 36 * 36 - 1):
            r, c = f(jnp.int32(i))
            assert (int(r), int(c)) == tuple(order[i])

    def test_dma_model_row_major_reuses_a(self):
        # row-major keeps the A row-block for num_cols consecutive steps
        b = dma_bytes(ROW_MAJOR, 16, 16, 1000, 1000)
        assert b == (16 + 256) * 1000

    def test_best_window_picks_larger_operand(self):
        # much bigger B blocks => column-runs (large W) should win
        cfg = best_window(16, 16, 10, 100000, candidates=(1, 16))
        assert cfg.window == 16
        cfg = best_window(16, 16, 100000, 10, candidates=(1, 16))
        assert cfg.window == 1


class TestCacheModel:
    def test_l2_llc_tradeoff(self):
        """Paper Tab. 4: maximizing L2 alone (huge chunk) degrades LLC."""
        base = simulate_gemm_schedule(ROW_MAJOR, m=9216, n=9216, k=9216,
                                      block_m=192, block_n=256, block_k=64)
        l2_greedy = simulate_gemm_schedule(
            SwizzleConfig(window=7, chunk=216), m=9216, n=9216, k=9216,
            block_m=192, block_n=256, block_k=64)
        assert l2_greedy.l2_hit > base.l2_hit
        assert l2_greedy.llc_hit < base.llc_hit

    def test_hit_rates_are_rates(self):
        r = simulate_gemm_schedule(SwizzleConfig(window=5, chunk=25),
                                   m=2304, n=2304, k=2304,
                                   block_m=192, block_n=256, block_k=64)
        assert 0 <= r.l2_hit <= 1 and 0 <= r.llc_hit <= 1
        assert r.l2_hit + r.llc_hit <= 1 + 1e-9
        assert r.modeled_tflops > 0


class TestPerfModel:
    def test_output_tile_dominates(self):
        """Paper Tab. 2's conclusion, on the TPU model: bigger output tile →
        higher arithmetic intensity → more modeled TFLOPs."""
        small = pm.gemm_step_model(INTERLEAVE, k_total=8192)
        big = pm.gemm_step_model(PINGPONG, k_total=8192)
        assert big["modeled_tflops"] > small["modeled_tflops"]
        assert big["arithmetic_intensity"] > small["arithmetic_intensity"]

    def test_producer_tax_shrinks_best_tile(self):
        """Wave specialization's VMEM tax shrinks the feasible output tile
        (the paper's Tab. 2 negative result)."""
        full = pm.best_output_tile(tiles.VMEM_BYTES, 2, 512)
        taxed = pm.best_output_tile(WAVE_SPECIALIZED.vmem_budget(), 2, 512)
        assert taxed[0] * taxed[1] <= full[0] * full[1]

    def test_ridge_point(self):
        # 512x512 tiles are compute bound on v5e; 256x256 are not
        assert pm.gemm_step_model(PINGPONG, k_total=4096)["bound"] == "compute"
        s = get_schedule("interleave")
        assert pm.gemm_step_model(s, k_total=4096)["bound"] == "memory"

    def test_roofline_terms(self):
        r = pm.roofline(1e15, 1e12, 1e11, n_chips=256)
        assert r.compute_s > 0 and r.memory_s > 0 and r.collective_s > 0
        assert r.bound in ("compute", "memory", "collective")
