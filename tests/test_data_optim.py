"""Data pipeline determinism/packing + optimizer/schedule/compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import DataConfig, DataIterator, batch_at, batch_rows
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, wsd_schedule, clip_by_global_norm,
                         ef_compress, ef_init)


class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
        b1, b2 = batch_at(cfg, 7), batch_at(cfg, 7)
        for k in b1:
            assert (b1[k] == b2[k]).all()

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
        assert not (batch_at(cfg, 0)["inputs"] == batch_at(cfg, 1)["inputs"]).all()

    def test_shard_independence(self):
        """Row r of the global batch is identical no matter how rows are
        grouped into shards — required for elastic restart."""
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8)
        full = batch_rows(cfg, 3, range(8))
        lo = batch_rows(cfg, 3, range(0, 4))
        hi = batch_rows(cfg, 3, range(4, 8))
        assert (full["inputs"][:4] == lo["inputs"]).all()
        assert (full["inputs"][4:] == hi["inputs"]).all()

    def test_packing_mask(self):
        cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=2,
                         mean_doc_len=32)
        b = batch_at(cfg, 0)
        # some doc boundaries must exist, and they are masked out
        assert 0 < b["loss_mask"].mean() < 1

    def test_iterator_restart(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        it = DataIterator(cfg)
        next(it); next(it)
        saved = it.state_dict()
        b3 = next(it)
        it2 = DataIterator(cfg)
        it2.load_state_dict(saved)
        b3b = next(it2)
        assert (b3["inputs"] == np.asarray(b3b["inputs"])).all()

    @given(step=st.integers(0, 1000), row=st.integers(0, 63))
    @settings(max_examples=20, deadline=None)
    def test_tokens_in_range(self, step, row):
        cfg = DataConfig(vocab_size=977, seq_len=64, global_batch=64)
        b = batch_rows(cfg, step, range(row, row + 1))
        assert (b["inputs"] >= 0).all() and (b["inputs"] < 977).all()


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        cfg = AdamWConfig(schedule=lambda s: 0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}   # d/dw w^2
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(schedule=lambda s: 0.1, weight_decay=0.5)
        params = {"w": jnp.asarray([4.0])}
        state = adamw_init(params)
        for _ in range(50):
            params, state, _ = adamw_update(cfg, {"w": jnp.zeros(1)}, state,
                                            params)
        assert float(params["w"][0]) < 4.0

    def test_clip(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
        assert float(norm) > 1.0

    @given(peak=st.floats(1e-5, 1e-2), warmup=st.integers(1, 100),
           total=st.integers(200, 10000))
    @settings(max_examples=30, deadline=None)
    def test_wsd_schedule_shape(self, peak, warmup, total):
        """Property: warmup is increasing, plateau constant at peak, decay
        ends at min_ratio·peak."""
        lr = wsd_schedule(peak, warmup, total, decay_frac=0.1)
        assert float(lr(0)) <= float(lr(warmup)) + 1e-12
        mid = (warmup + int(total * 0.9)) // 2
        assert abs(float(lr(mid)) - peak) < 1e-9
        assert float(lr(total)) == pytest.approx(0.01 * peak, rel=1e-3)

    def test_cosine_monotone_decay(self):
        lr = cosine_schedule(1e-3, 10, 100)
        vals = [float(lr(s)) for s in range(10, 101, 10)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))


class TestCompression:
    def test_ef_reduces_bias(self):
        """With error feedback, the *accumulated* quantized sum tracks the
        true sum far better than independent quantization."""
        rng = np.random.default_rng(0)
        g_seq = [jnp.asarray(rng.normal(size=256) * 0.01) for _ in range(50)]
        tree = lambda g: {"w": g}
        ef = ef_init(tree(g_seq[0]))
        acc_ef, acc_nf, acc_true = np.zeros(256), np.zeros(256), np.zeros(256)
        for g in g_seq:
            dq, ef = ef_compress(tree(g), ef)
            acc_ef += np.asarray(dq["w"])
            dq2, _ = ef_compress(tree(g), ef_init(tree(g)))
            acc_nf += np.asarray(dq2["w"])
            acc_true += np.asarray(g)
        err_ef = np.abs(acc_ef - acc_true).max()
        err_nf = np.abs(acc_nf - acc_true).max()
        assert err_ef < err_nf

    def test_quant_error_bounded(self):
        g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=1024))}
        dq, ef = ef_compress(g, ef_init(g))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127
        assert float(jnp.abs(dq["w"] - g["w"]).max()) <= scale * 0.5 + 1e-6

    def test_compressed_psum_multidevice(self, subproc):
        out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.optim import compressed_psum
mesh = jax.make_mesh((8,), ('data',))
x = jnp.linspace(-1, 1, 512)
out = compressed_psum(x, mesh, 'data')
np.testing.assert_allclose(np.asarray(out), np.asarray(8*x), atol=8*2/127)
print('OK')
""")
        assert "OK" in out
